"""Batched serving example: prefill a prompt batch, stream-decode tokens.

  PYTHONPATH=src:. python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.exit(serve_main(["--model-smoke", "--arch", "llama3.2-1b",
                         "--smoke", "--batch", "4", "--prompt-len", "32",
                         "--gen", "16"]))
