"""Paper Listing 3: SNP calling — map (align) + repartitionBy (chromosome)
+ map (call) + reduce (concat).

  PYTHONPATH=src:. python examples/snp_calling.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.apps import make_library, snp_calling


def main():
    reads = make_library(8_192, seed=3)
    chrom, score, read_id = snp_calling(reads)
    n = len(np.asarray(read_id))
    print(f"called {n} variants across "
          f"{len(set(np.asarray(chrom).tolist()))} chromosomes")
    by_chrom = {}
    for c in np.asarray(chrom).tolist():
        by_chrom[c] = by_chrom.get(c, 0) + 1
    top = sorted(by_chrom.items(), key=lambda kv: -kv[1])[:5]
    for c, k in top:
        print(f"  chr{c:<3} {k} variants")
    assert n > 0
    print("OK")


if __name__ == "__main__":
    main()
