"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

  PYTHONPATH=src:. python examples/train_lm.py [--steps 300]

Uses the production stack end to end: config registry (smollm-135m family,
width-reduced to fit CPU time), synthetic data pipeline with prefetch,
AdamW + cosine schedule, checkpoint/restart manager, MaRe-tree gradient
sync when multiple devices are present.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke_config
from repro.data import Prefetcher, SyntheticText, lm_batches
from repro.models import build_model, param_count
from repro.optim import adamw
from repro.optim.schedule import cosine_warmup
from repro.train import (StepConfig, Trainer, TrainerConfig,
                         init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config("smollm-135m").scaled(
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512)
    model = build_model(cfg)
    opt = adamw()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    print(f"params: {param_count(state.params)/1e6:.2f}M "
          f"(reduced {cfg.name} family)")

    src = SyntheticText(cfg.vocab_size, doc_len=512, seed=0)
    pf = Prefetcher(lambda: lm_batches(src, args.batch, args.seq,
                                       cfg.vocab_size),
                    capacity=4, deadline_s=5.0)
    cached = [next(pf) for _ in range(32)]
    pf.close()

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in cached[i % 32].items()}

    step = jax.jit(make_train_step(
        model, opt, cosine_warmup(3e-3, 20, args.steps), StepConfig()))
    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(step, state, None, CheckpointManager(d),
                          TrainerConfig(total_steps=args.steps,
                                        checkpoint_every=100,
                                        log_every=20),
                          batch_fn=batch_fn)
        trainer.run()
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "training failed to reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
