"""k-mer statistics — keyed aggregation over a genome (reduce_by_key demo).

  PYTHONPATH=src python examples/kmer_stats.py             # batch
  PYTHONPATH=src python examples/kmer_stats.py --follow    # live dashboard

The canonical grouped-aggregation genomics workload (arXiv:1807.01566
collects k-mer statistics at scale with exactly this shape): a FASTA
genome is ingested through repro.io, the ``kmer-stats`` container maps
each sequence record to packed 2-bit k-mer keys, and
``MaRe.reduce_by_key`` folds equal keys with a map-side combiner — the
whole chain compiles to ONE shard_map program, and shuffle volume scales
with distinct k-mers, not k-mer occurrences (see
``report().diagnostics["stage1.exchanged_records"]``).

``--follow`` runs the same aggregation as a *live* query
(docs/streaming.md): a sequencer drops FASTA files into an inbox, a
tenant ``Session`` maintains the k-mer table incrementally — each new
file batch runs only the delta through the compiled plan and folds it
into the persisted aggregate — and the dashboard refreshes per epoch.

Note the FASTA reader frames each sequence *line* as one record, so
k-mers spanning a line boundary are not counted — the reference below
mirrors that framing (exact for the chunked statistic, as with GC count).
"""
import argparse
import os
import queue
import sys
import tempfile
from collections import Counter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import MaRe
from repro.io import fasta_source

K = 6
LINE = 70


def write_genome(path: str, n_bases: int = 50_000, seed: int = 7):
    """Random ATGC genome as FASTA; return its sequence lines."""
    rng = np.random.default_rng(seed)
    seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, size=n_bases)])
    lines = [seq[i:i + LINE] for i in range(0, len(seq), LINE)]
    with open(path, "w") as f:
        f.write(">chr1 kmer-stats demo\n")
        for ln in lines:
            f.write(ln + "\n")
    return lines


def reference_counts(lines) -> Counter:
    """Per-line k-mer counts (the FASTA record framing)."""
    counts: Counter = Counter()
    code = {"A": 0, "C": 1, "G": 2, "T": 3}
    for ln in lines:
        for i in range(len(ln) - K + 1):
            key = 0
            for ch in ln[i:i + K]:
                key = key * 4 + code[ch]
            counts[key] += 1
    return counts


def decode(key: int) -> str:
    bases = "ACGT"
    return "".join(bases[(key >> (2 * (K - 1 - i))) & 3] for i in range(K))


def key_of(recs):
    return recs[0]


def ones_of(recs):
    return (recs[1],)


def build_kmer_table(m: MaRe) -> MaRe:
    """The aggregation both modes share: map to k-mer keys, fold by key.

    Module-level on purpose — an IncrementalQuery requires the SAME plan
    suffix every epoch (stage signatures key on callable identity)."""
    return (m.map(image="kmer-stats", k=K)
            .reduce_by_key(key_of, value_by=ones_of, op="sum",
                           num_keys=4 ** K))


def top_kmers(table, n: int = 3):
    keys, (occurrences,), _ = table
    got = {int(k): int(c) for k, c in zip(keys, occurrences)}
    top = sorted(got.items(), key=lambda kv: (-kv[1], kv[0]))[:n]
    return got, top


def follow(epochs: int = 4, bases_per_epoch: int = 10_000):
    """Live k-mer dashboard: a sequencer drops FASTA chunks into an
    inbox while a tenant Session maintains the table incrementally."""
    import jax

    from repro import compat
    from repro.serve import QueryService
    from repro.stream import ContinuousSource, LiveQuery

    inbox = tempfile.mkdtemp(prefix="mare_kmer_inbox_")
    stage = tempfile.mkdtemp(prefix="mare_kmer_stage_")
    mesh = compat.make_mesh((jax.device_count(),), ("data",))

    with QueryService() as svc:
        sess = svc.session("genomics")
        cont = ContinuousSource(fasta_source(inbox, split_bytes=1 << 13),
                                mesh, capacity=256)
        query = sess.stream(cont, build_kmer_table, label="genomics/kmers")
        print(query.describe())

        refreshes: queue.Queue = queue.Queue()
        all_lines = []
        # the LiveQuery thread polls the inbox; files appear atomically
        # (written in a staging dir, renamed in) so a half-written chunk
        # is never ingested
        with LiveQuery(query, interval_s=0.05, on_refresh=refreshes.put):
            for epoch in range(epochs):
                name = f"chunk{epoch:03d}.fa"
                all_lines += write_genome(os.path.join(stage, name),
                                          n_bases=bases_per_epoch,
                                          seed=100 + epoch)
                os.rename(os.path.join(stage, name),
                          os.path.join(inbox, name))
                upd = refreshes.get(timeout=120)
                got, top = top_kmers(query.collect())
                print(f"[watermark {upd.watermark}] +{upd.new_splits} "
                      f"splits, fold {upd.fold_s * 1e3:.1f} ms, "
                      f"{sum(got.values())} windows | top: "
                      + "  ".join(f"{decode(k)} x{c}" for k, c in top))

        # every refresh routed one report through the session stream
        reports = sess.follow(0, timeout=30)
        assert len(reports) == epochs
        assert all(r.tenant == "genomics" for r in reports)
        assert reports[-1].counters["stream.watermark"] == epochs - 1
        print(query.describe())

        got, _ = top_kmers(query.collect())
        expected = reference_counts(all_lines)
        assert got == dict(expected), \
            "followed k-mer table mismatch vs host reference"
        print(f"followed {epochs} epochs: {len(got)} distinct {K}-mers "
              f"over {sum(got.values())} windows, exact vs host reference")
        print("OK")


def main():
    tmp = tempfile.mkdtemp(prefix="mare_kmer_")
    fasta = os.path.join(tmp, "genome.fa")
    lines = write_genome(fasta)

    base = MaRe.from_source(fasta_source(fasta, split_bytes=1 << 13))
    stats = build_kmer_table(base)
    # describe() shows the inferred schema + capacity at every stage
    # boundary: the kmer-stats manifest's capacity transfer sizes the
    # window buffer (cap * (W - k + 1)) and declares key_space = 4**k,
    # so num_keys above could equally be omitted and inferred:
    inferred = (base
                .map(image="kmer-stats", k=K)
                .reduce_by_key(key_of, value_by=ones_of, op="sum"))
    assert inferred.plan.stages[-1].num_keys == 4 ** K
    print(stats.describe())

    keys, (occurrences, ), record_counts = stats.collect()
    got = {int(k): int(c) for k, c in zip(keys, occurrences)}
    expected = reference_counts(lines)
    assert got == dict(expected), "k-mer table mismatch vs host reference"
    assert np.array_equal(occurrences, record_counts)  # value is 1/record

    top = sorted(got.items(), key=lambda kv: (-kv[1], kv[0]))[:5]
    print(f"{len(got)} distinct {K}-mers over {sum(got.values())} windows")
    for key, cnt in top:
        print(f"  {decode(key)}  x{cnt}")
    diag = stats.report().diagnostics
    print(f"combiner exchange volume: {diag['stage1.exchanged_records']} "
          f"records (vs {sum(got.values())} k-mer occurrences)")

    # Interactive sessions persist the expensive map prefix once; every
    # later query sharing it starts from the cached materialization and
    # only executes its own aggregation (runtime lineage cache):
    base.map(image="kmer-stats", k=K).persist()
    followup = (base
                .map(image="kmer-stats", k=K)
                .reduce_by_key(key_of, value_by=ones_of, op="max"))
    assert "[cached]" in followup.describe()
    followup.collect()
    report = followup.report()
    assert report.cached_stages == 1
    print(f"persisted prefix reused: cached {report.cached_stages}/"
          f"{report.total_stages} stages from {report.cache_tier} tier")
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--follow", action="store_true",
                    help="live dashboard over a polled FASTA inbox")
    args = ap.parse_args()
    follow() if args.follow else main()
