"""k-mer statistics — keyed aggregation over a genome (reduce_by_key demo).

  PYTHONPATH=src python examples/kmer_stats.py

The canonical grouped-aggregation genomics workload (arXiv:1807.01566
collects k-mer statistics at scale with exactly this shape): a FASTA
genome is ingested through repro.io, the ``kmer-stats`` container maps
each sequence record to packed 2-bit k-mer keys, and
``MaRe.reduce_by_key`` folds equal keys with a map-side combiner — the
whole chain compiles to ONE shard_map program, and shuffle volume scales
with distinct k-mers, not k-mer occurrences (see
``report().diagnostics["stage1.exchanged_records"]``).

Note the FASTA reader frames each sequence *line* as one record, so
k-mers spanning a line boundary are not counted — the reference below
mirrors that framing (exact for the chunked statistic, as with GC count).
"""
import os
import sys
import tempfile
from collections import Counter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import MaRe
from repro.io import fasta_source

K = 6
LINE = 70


def write_genome(path: str, n_bases: int = 50_000, seed: int = 7):
    """Random ATGC genome as FASTA; return its sequence lines."""
    rng = np.random.default_rng(seed)
    seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, size=n_bases)])
    lines = [seq[i:i + LINE] for i in range(0, len(seq), LINE)]
    with open(path, "w") as f:
        f.write(">chr1 kmer-stats demo\n")
        for ln in lines:
            f.write(ln + "\n")
    return lines


def reference_counts(lines) -> Counter:
    """Per-line k-mer counts (the FASTA record framing)."""
    counts: Counter = Counter()
    code = {"A": 0, "C": 1, "G": 2, "T": 3}
    for ln in lines:
        for i in range(len(ln) - K + 1):
            key = 0
            for ch in ln[i:i + K]:
                key = key * 4 + code[ch]
            counts[key] += 1
    return counts


def decode(key: int) -> str:
    bases = "ACGT"
    return "".join(bases[(key >> (2 * (K - 1 - i))) & 3] for i in range(K))


def key_of(recs):
    return recs[0]


def ones_of(recs):
    return (recs[1],)


def main():
    tmp = tempfile.mkdtemp(prefix="mare_kmer_")
    fasta = os.path.join(tmp, "genome.fa")
    lines = write_genome(fasta)

    base = MaRe.from_source(fasta_source(fasta, split_bytes=1 << 13))
    stats = (base
             .map(image="kmer-stats", k=K)
             .reduce_by_key(key_of, value_by=ones_of, op="sum",
                            num_keys=4 ** K))
    # describe() shows the inferred schema + capacity at every stage
    # boundary: the kmer-stats manifest's capacity transfer sizes the
    # window buffer (cap * (W - k + 1)) and declares key_space = 4**k,
    # so num_keys above could equally be omitted and inferred:
    inferred = (base
                .map(image="kmer-stats", k=K)
                .reduce_by_key(key_of, value_by=ones_of, op="sum"))
    assert inferred.plan.stages[-1].num_keys == 4 ** K
    print(stats.describe())

    keys, (occurrences, ), record_counts = stats.collect()
    got = {int(k): int(c) for k, c in zip(keys, occurrences)}
    expected = reference_counts(lines)
    assert got == dict(expected), "k-mer table mismatch vs host reference"
    assert np.array_equal(occurrences, record_counts)  # value is 1/record

    top = sorted(got.items(), key=lambda kv: (-kv[1], kv[0]))[:5]
    print(f"{len(got)} distinct {K}-mers over {sum(got.values())} windows")
    for key, cnt in top:
        print(f"  {decode(key)}  x{cnt}")
    diag = stats.report().diagnostics
    print(f"combiner exchange volume: {diag['stage1.exchanged_records']} "
          f"records (vs {sum(got.values())} k-mer occurrences)")

    # Interactive sessions persist the expensive map prefix once; every
    # later query sharing it starts from the cached materialization and
    # only executes its own aggregation (runtime lineage cache):
    base.map(image="kmer-stats", k=K).persist()
    followup = (base
                .map(image="kmer-stats", k=K)
                .reduce_by_key(key_of, value_by=ones_of, op="max"))
    assert "[cached]" in followup.describe()
    followup.collect()
    report = followup.report()
    assert report.cached_stages == 1
    print(f"persisted prefix reused: cached {report.cached_stages}/"
          f"{report.total_stages} stages from {report.cache_tier} tier")
    print("OK")


if __name__ == "__main__":
    main()
