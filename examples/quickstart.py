"""Quickstart: the paper's Listing 1 (GC count), line for line.

  PYTHONPATH=src:. python examples/quickstart.py

A DNA sequence is a record stream over {A,T,G,C} (int codes 0..3).  The
`ubuntu` image's command grammar maps the paper's POSIX pipeline:
  grep -o '[GC]' /dna | wc -l   ->  grep-count 2 3
  awk '{s+=$1} END {print s}'   ->  awk-sum
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import MaRe, TextFile


def main():
    rng = np.random.default_rng(42)
    genome = rng.integers(0, 4, size=100_000).astype(np.int32)  # A T G C

    gc_count = (
        MaRe((genome,)).map(
            inputMountPoint=TextFile("/dna"),
            outputMountPoint=TextFile("/count"),
            image="ubuntu",
            command="grep-count 2 3",
        ).reduce(
            inputMountPoint=TextFile("/counts"),
            outputMountPoint=TextFile("/sum"),
            image="ubuntu",
            command="awk-sum",
        ))

    (total,) = gc_count.collect_first_shard()
    expected = int(np.sum((genome == 2) | (genome == 3)))
    print(f"GC count: {int(total[0])} (expected {expected})")
    assert int(total[0]) == expected
    print("OK")


if __name__ == "__main__":
    main()
