"""Quickstart: the paper's Listing 1 (GC count), line for line — now fed
from an on-disk FASTA file through the repro.io ingestion subsystem.

  PYTHONPATH=src:. python examples/quickstart.py

A genome is written as FASTA, ingested via a pluggable storage backend
(LocalFS here; swap in ``backend="s3"`` for the emulated remote tier), and
the POSIX pipeline of Listing 1 runs over byte records:
  grep -o '[GC]' /dna | wc -l   ->  grep-chars GC
  awk '{s+=$1} END {print s}'   ->  awk-sum
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import MaRe, PlanTypeError, TextFile, DEFAULT_CACHE
from repro.io import fasta_source


def write_genome(path: str, n_bases: int = 100_000, seed: int = 42) -> str:
    """Write a random ATGC genome as FASTA; return the sequence string."""
    rng = np.random.default_rng(seed)
    seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, size=n_bases)])
    with open(path, "w") as f:
        f.write(">chr1 quickstart genome\n")
        for i in range(0, len(seq), 70):
            f.write(seq[i:i + 70] + "\n")
    return seq


def main():
    tmp = tempfile.mkdtemp(prefix="mare_quickstart_")
    fasta = os.path.join(tmp, "genome.fa")
    seq = write_genome(fasta)

    gc_count = (
        MaRe.from_source(fasta_source(fasta, split_bytes=1 << 14)).map(
            input_mount=TextFile("/dna"),
            output_mount=TextFile("/count"),
            image="ubuntu",
            command="grep-chars GC",
        ).reduce(
            input_mount=TextFile("/counts"),
            output_mount=TextFile("/sum"),
            image="ubuntu",
            command="awk-sum",
        ))

    # The chain above is lazy: nothing has executed yet.  describe() shows
    # the pending stage DAG that the planner will fuse into ONE program.
    print(gc_count.describe())

    (total,) = gc_count.collect(shard=0)
    expected = seq.count("G") + seq.count("C")
    print(f"GC count: {int(total[0])} (expected {expected})")
    assert int(total[0]) == expected

    # Interactive re-execution (paper Fig. 6): building the same pipeline
    # again hits the compile cache — zero re-trace, zero re-compile.
    before = DEFAULT_CACHE.stats()
    rerun = (
        MaRe.from_source(fasta_source(fasta, split_bytes=1 << 14)).map(
            input_mount=TextFile("/dna"),
            output_mount=TextFile("/count"),
            image="ubuntu",
            command="grep-chars GC",
        ).reduce(
            input_mount=TextFile("/counts"),
            output_mount=TextFile("/sum"),
            image="ubuntu",
            command="awk-sum",
        ))
    (total2,) = rerun.collect(shard=0)
    after = DEFAULT_CACHE.stats()
    assert int(total2[0]) == expected
    assert after["misses"] == before["misses"], "re-run must not recompile"
    print(f"re-run hit the compile cache: {after}")

    # Typed image manifests: a mistyped pipeline fails while BUILDING the
    # chain — grep-count emits (i32) count records, grep-chars requires
    # byte records — instead of a shape error from inside the fused trace.
    try:
        (MaRe((np.arange(64, dtype=np.int32) % 4,))
         .map(image="ubuntu", command="grep-count 2 3")
         .map(image="ubuntu", command="grep-chars GC"))
        raise AssertionError("mistyped chain must not build")
    except PlanTypeError as e:
        print(f"plan-time type check: {e}")
    print("OK")


if __name__ == "__main__":
    main()
