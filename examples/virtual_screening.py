"""Paper Listing 2: Virtual Screening — map (docking) + reduce (top-30).

  PYTHONPATH=src:. python examples/virtual_screening.py

The FRED docking stage is a surrogate scorer ContainerOp; the sdsorter
top-k combiner is the `toolbox/topk` image (Pallas topk_reduce kernel on
TPU).  Results are validated against the single-core oracle, mirroring the
paper's own 1K-molecule correctness check.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.apps import make_library, virtual_screening, vs_reference


def main():
    library = make_library(20_000, seed=7)
    scores, mol_ids = virtual_screening(library, top=30)
    ref_scores, ref_ids = vs_reference(library, top=30)
    print("top-5 poses (score, molecule):")
    order = np.argsort(-np.asarray(scores))
    for i in order[:5]:
        print(f"  {float(scores[i]):8.3f}  mol {int(mol_ids[i])}")
    assert set(np.asarray(mol_ids).tolist()) == set(ref_ids.tolist()), \
        "parallel top-30 differs from single-core oracle"
    print("OK: matches single-core FRED+sdsorter oracle")


if __name__ == "__main__":
    main()
