"""Streaming benchmark: incremental update cost vs full recomputation.

The claim (docs/streaming.md): once a keyed aggregate is maintained
incrementally, the per-epoch update cost scales with the *delta* size,
not the history size — each epoch ingests only the new splits, runs the
same compiled plan suffix (zero recompiles after epoch 0), and folds the
delta table into the persisted state with one shard-local segment
reduce.  A full recomputation re-ingests and re-reduces everything.

Protocol: drop one file of ``lines_per_epoch`` records per epoch and
time ``IncrementalQuery.update()`` for every epoch.  From the epoch
where history >= 10x the epoch size onward, also time a *warm* one-shot
``reduce_by_key`` over the union (pinned full-size capacity, so the
one-shot program compiles once and every timed run is a compile-cache
hit — the comparison is compute-vs-compute, not compile-vs-compute).

In-script guards (full scale):
  - incremental result == one-shot result, exactly, at the final epoch
  - speedup = full_s / update_s >= 5 once history >= 10x epoch size
  - zero plan-cache misses after epoch 0; exactly one fold compile

Usage:  python benchmarks/stream.py [--small] [--out BENCH_stream.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np                                          # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                  # noqa: E402
from repro import compat                                    # noqa: E402
from repro.core import MaRe, PlanCache                      # noqa: E402
from repro.io import text_source                            # noqa: E402
from repro.runtime import Executor, MaterializationCache    # noqa: E402
from repro.stream import ContinuousSource, IncrementalQuery  # noqa: E402

NUM_KEYS = 256
SPEEDUP_AT = 10         # assert once history >= this many epochs
SPEEDUP_FLOOR = 5.0


def _key(recs):
    # two leading bases -> key in [0, 256): a k-mer-ish bounded key space
    d = recs["data"].astype(np.int32)
    return (d[:, 0] * 16 + d[:, 1]) % NUM_KEYS


def _val(recs):
    return (recs["len"].astype(np.int32),)


def _build(m: MaRe) -> MaRe:
    return m.reduce_by_key(_key, value_by=_val, op="sum",
                           num_keys=NUM_KEYS)


FILES_PER_EPOCH = 4     # spread each epoch's splits over several shards


def _write_epoch(root: str, epoch: int, lines: int,
                 rng: np.random.Generator) -> None:
    per_file = -(-lines // FILES_PER_EPOCH)
    for part in range(FILES_PER_EPOCH):
        n = min(per_file, lines - part * per_file)
        rows = ["".join(rng.choice(list("ACGT"),
                                   size=int(rng.integers(30, 60))))
                for _ in range(n)]
        path = os.path.join(root, f"epoch{epoch:04d}.{part}.txt")
        with open(path + ".tmp", "w") as f:
            f.write("\n".join(rows) + "\n")
        os.rename(path + ".tmp", path)


def _sorted_table(table):
    keys, (vals,), counts = table
    order = np.argsort(np.asarray(keys))
    return (np.asarray(keys)[order], np.asarray(vals)[order],
            np.asarray(counts)[order])


def run(small: bool) -> dict:
    epochs = 12 if small else 14
    lines_per_epoch = 160 if small else 12800
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    n_shards = int(mesh.shape["data"])
    # pinned geometries: the stream packs every epoch into delta-sized
    # shapes; the one-shot packs every union into FINAL-sized shapes —
    # both therefore compile exactly once.  Each <1MB file is one split,
    # so an epoch's FILES_PER_EPOCH splits spread over that many shards
    # (or stack up when the mesh is smaller): a shard can hold up to its
    # share of files' worth of delta records.
    files_per_shard = -(-FILES_PER_EPOCH // n_shards)
    delta_cap = -(-lines_per_epoch // FILES_PER_EPOCH) * files_per_shard * 2
    full_cap = -(-lines_per_epoch * epochs * 2 // n_shards)
    oneshot_cache = PlanCache()

    root = tempfile.mkdtemp(prefix="bench_stream_")
    rng = np.random.default_rng(0)
    stream_cache = PlanCache()
    q = IncrementalQuery(
        ContinuousSource(text_source(root), mesh, capacity=delta_cap),
        _build, plan_cache=stream_cache,
        executor=Executor(mat_cache=MaterializationCache()),
        label="bench-stream")

    def full_recompute():
        one = _build(MaRe.from_source(text_source(root), mesh,
                                      capacity=full_cap,
                                      executor=Executor(
                                          mat_cache=MaterializationCache())))
        one.plan_cache = oneshot_cache
        return one.collect()

    scaling = []
    warm_misses_after_epoch0 = 0
    full_result = None
    for epoch in range(epochs):
        _write_epoch(root, epoch, lines_per_epoch, rng)
        misses_before = stream_cache.stats()["misses"]
        t0 = time.monotonic()
        update = q.update()
        update_s = time.monotonic() - t0
        assert update is not None and update.epoch == epoch
        if epoch > 0:
            warm_misses_after_epoch0 += \
                stream_cache.stats()["misses"] - misses_before
        row = {"epoch": epoch,
               "history_records": lines_per_epoch * (epoch + 1),
               "delta_records": lines_per_epoch,
               "update_ms": update_s * 1e3}
        if epoch + 1 >= SPEEDUP_AT:
            if epoch + 1 == SPEEDUP_AT:
                full_recompute()            # warm the one-shot program
            full_s = float("inf")
            for _ in range(2):              # best of 2 warm runs
                t0 = time.monotonic()
                full_result = full_recompute()
                full_s = min(full_s, time.monotonic() - t0)
            row["full_ms"] = full_s * 1e3
            row["speedup"] = full_s / update_s
        scaling.append(row)

    got = _sorted_table(q.collect())
    want = _sorted_table(full_result)
    exact = all(g.dtype == w.dtype and np.array_equal(g, w)
                for g, w in zip(got, want))
    assert exact, "incremental result diverged from one-shot recompute"

    guarded = [r for r in scaling if "speedup" in r]
    speedup = min(r["speedup"] for r in guarded)
    last = scaling[-1]
    result = {
        "bench": "stream",
        "small": small,
        "devices": n_shards,
        "epochs": epochs,
        "records_per_epoch": lines_per_epoch,
        "history_records": lines_per_epoch * epochs,
        "update_ms_final": last["update_ms"],
        "full_recompute_ms_final": last["full_ms"],
        "incremental_speedup": speedup,
        "recompiles_after_warm": warm_misses_after_epoch0,
        "fold_compiles": q.fold_engine.compiles,
        "exact_match": exact,
        "scaling": scaling,
    }
    assert warm_misses_after_epoch0 == 0, \
        f"epochs after the first recompiled {warm_misses_after_epoch0}x"
    assert q.fold_engine.compiles == 1, q.fold_engine.compiles
    if not small:
        assert speedup >= SPEEDUP_FLOOR, (
            f"incremental update only {speedup:.2f}x faster than full "
            f"recompute at history >= {SPEEDUP_AT}x epoch size "
            f"(floor {SPEEDUP_FLOOR}x)")
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI-sized run (guards relaxed to smoke level)")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args()
    result = run(small=args.small)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    print(json.dumps({k: v for k, v in result.items() if k != "scaling"},
                     indent=2, sort_keys=True))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
