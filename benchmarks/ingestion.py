"""Ingestion speedup benchmark (paper Fig. 5).

The paper ingests from HDFS (co-located), Swift (same DC) and S3 (remote);
speedup = T(1 worker) / T(N workers).  Latency profiles emulate the three
backends; parallel ingestion uses worker threads (latency-bound, so thread
scaling is honest even on one core)."""
from __future__ import annotations

import sys
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List

import numpy as np

sys.path.insert(0, "src")
from repro.data import SyntheticText  # noqa: E402

BACKENDS = {
    # (latency_s per doc, jitter_s) — co-located / same-DC / remote
    "hdfs": (0.0002, 0.0),
    "swift": (0.001, 0.0002),
    "s3": (0.004, 0.002),
}


def ingest(backend: str, workers: int, docs: int = 128) -> float:
    lat, jit = BACKENDS[backend]

    def pull(shard):
        src = SyntheticText(1000, doc_len=64, num_docs=docs // workers,
                            seed=shard, latency_s=lat, jitter_s=jit)
        return [d for d in src]

    t0 = time.monotonic()
    with ThreadPoolExecutor(max_workers=workers) as ex:
        list(ex.map(pull, range(workers)))
    return time.monotonic() - t0


def main() -> List[Dict]:
    rows = []
    for backend in BACKENDS:
        t1 = None
        for n in (1, 2, 4, 8, 16):
            t = ingest(backend, n)
            t1 = t1 or t
            rows.append({"backend": backend, "workers": n, "t": t,
                         "speedup": t1 / t})
            print(f"ingestion,{backend},workers={n},t={t:.3f},"
                  f"speedup={t1/t:.2f}")
    return rows


if __name__ == "__main__":
    main()
