"""Ingestion speedup benchmark (paper Fig. 5) through the real MaRe path.

The paper ingests a dataset from HDFS (co-located), Swift (same DC) and S3
(remote); speedup = T(1 worker) / T(N workers).  This benchmark generates
a FASTA file once, then ingests it via ``MaRe.from_source`` — split
planning, the emulated storage backend's ranged reads (latency profiles in
``repro.io.backends.BACKEND_PROFILES``), the parallel fetch pool, record
packing and device placement — varying the fetch-pool width.  Latency
sleeps happen in the fetching threads, so thread scaling is honest even on
one core.  Results land in ``BENCH_ingestion.json``.

The sweep includes a ``workers="auto"`` row per backend: the
latency-aware default (``repro.io.default_workers``) picks the serial
path for local storage — where ``read_split`` is GIL-bound record
parsing and any pool width is pure overhead (the pre-fix curve showed
~0.6x at 8 workers) — and a wide pool for latency-bound remote tiers.
Note ``workers=1`` and local ``"auto"`` run the identical serial code
path, so their rows should agree to within noise; the fix shows up as
the pooled widths (2..16) sitting at or below the serial baseline on
local while still scaling on hdfs/swift/s3.  Each configuration is
timed ``reps`` times — reps are interleaved round-robin across the
pool widths of a backend so background-load drift hits every
configuration equally — and the minimum is reported (single samples on
a shared machine swing +-30%).

  PYTHONPATH=src python benchmarks/ingestion.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, "src")
from repro.core import MaRe                         # noqa: E402
from repro.io import fasta_source, make_backend     # noqa: E402

BACKENDS = ("local", "hdfs", "swift", "s3")
WORKER_COUNTS = (1, 2, 4, 8, 16, "auto")
FILE_BYTES = 1 << 20
SPLIT_BYTES = 1 << 14          # ~64 splits -> meaningful pool parallelism


def write_fasta(path: str, nbytes: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    bases = np.array(list("ATGC"))
    with open(path, "w") as f:
        f.write(">bench synthetic genome\n")
        written = 0
        while written < nbytes:
            line = "".join(rng.choice(bases, size=70))
            f.write(line + "\n")
            written += 71


def ingest_once(path: str, backend_name: str, workers,
                split_bytes: int) -> float:
    backend = make_backend(backend_name, path)
    source = fasta_source(path, backend=backend, split_bytes=split_bytes)
    t0 = time.monotonic()
    m = MaRe.from_source(source,
                         workers=None if workers == "auto" else workers)
    m.dataset.counts.block_until_ready()
    return time.monotonic() - t0


def main() -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: smaller file, fewer pool widths")
    ap.add_argument("--out", default="BENCH_ingestion.json")
    args = ap.parse_args()

    file_bytes = FILE_BYTES >> 3 if args.small else FILE_BYTES
    split_bytes = SPLIT_BYTES >> 3 if args.small else SPLIT_BYTES
    worker_counts = (1, 8, "auto") if args.small else WORKER_COUNTS
    reps = 1 if args.small else 3

    tmp = tempfile.mkdtemp(prefix="mare_ingest_")
    path = os.path.join(tmp, "genome.fa")
    write_fasta(path, file_bytes)

    # warm-up: absorb one-time JAX/mesh/device_put initialization so the
    # first timed run (the speedup baseline) measures ingestion only
    ingest_once(path, "local", 1, split_bytes)

    rows: List[Dict] = []
    for backend in BACKENDS:
        best = {n: None for n in worker_counts}
        for _ in range(reps):
            for n in worker_counts:
                t = ingest_once(path, backend, n, split_bytes)
                best[n] = t if best[n] is None else min(best[n], t)
        t1 = None
        for n in worker_counts:
            t = best[n]
            t1 = t1 or t
            rows.append({"backend": backend, "workers": n, "t": t,
                         "speedup": t1 / t})
            print(f"ingestion,{backend},workers={n},t={t:.3f},"
                  f"speedup={t1/t:.2f}")
    out = {"bench": "ingestion", "file_bytes": file_bytes,
           "split_bytes": split_bytes, "reps": reps, "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return rows


if __name__ == "__main__":
    main()
