"""Ingestion speedup benchmark (paper Fig. 5) through the real MaRe path.

The paper ingests a dataset from HDFS (co-located), Swift (same DC) and S3
(remote); speedup = T(1 worker) / T(N workers).  This benchmark generates
a FASTA file once, then ingests it via ``MaRe.from_source`` — split
planning, the emulated storage backend's ranged reads (latency profiles in
``repro.io.backends.BACKEND_PROFILES``), the parallel fetch pool, columnar
framing, record packing and device placement — varying the fetch-pool
width.  Latency sleeps happen in the fetching threads, so thread scaling
is honest even on one core.  Results land in ``BENCH_ingestion.json``.

Two extra dimensions beyond the paper's figure:

* ``parser``: the local-backend sweep runs twice, once with the columnar
  vectorized framing path (``RecordBatch`` offsets + one bulk gather) and
  once with ``parser="legacy"`` (per-line ``List[bytes]`` parsing, kept as
  the parity oracle).  A standalone parse+pack micro-benchmark times both
  implementations on the identical payload and reports
  ``parse_pack_speedup`` — the headline number for the vectorization.
* ``workers="auto"`` per backend: the latency-aware default
  (``repro.io.default_workers``) picks a small pool for local storage
  under the vectorized parser (framing is GIL-releasing NumPy, so
  fetch+frame of neighboring shard bins overlap) and a wide pool for
  latency-bound remote tiers.  Under the legacy parser any local pool
  width is pure overhead (the pre-vectorization curve showed ~0.6x at 8
  workers), which the legacy rows still demonstrate.

Each configuration is timed ``reps`` times — reps are interleaved
round-robin across the pool widths of a backend so background-load drift
hits every configuration equally — and the minimum is reported (single
samples on a shared machine swing +-30%).

At full scale the script asserts its own acceptance invariants and exits
nonzero if ingestion regressed:

* ``parse_pack_speedup >= 3.0`` — vectorized framing+packing beats the
  legacy per-line path by at least 3x on local FASTA;
* ``local_best_pooled_speedup >= 0.95`` — pooled local ingestion no
  longer anti-scales: the best pooled width is at worst noise-level
  slower than serial (historically 0.45-0.6x before the shard-bin task
  granularity fix).

  PYTHONPATH=src python benchmarks/ingestion.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, "src")
from repro.core import MaRe                         # noqa: E402
from repro.io import fasta_source, make_backend     # noqa: E402
from repro.io.formats import (FORMATS, pack_batches,  # noqa: E402
                              pack_records)

BACKENDS = ("local", "hdfs", "swift", "s3")
WORKER_COUNTS = (1, 2, 4, 8, 16, "auto")
FILE_BYTES = 1 << 20
SPLIT_BYTES = 1 << 14          # ~64 splits -> meaningful pool parallelism
MICRO_REPS = 7

#: Acceptance floors asserted at full scale (see module docstring).
MIN_PARSE_PACK_SPEEDUP = 3.0
MIN_POOLED_LOCAL_SPEEDUP = 0.95


def write_fasta(path: str, nbytes: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    bases = np.array(list("ATGC"))
    with open(path, "w") as f:
        f.write(">bench synthetic genome\n")
        written = 0
        while written < nbytes:
            line = "".join(rng.choice(bases, size=70))
            f.write(line + "\n")
            written += 71


def ingest_once(path: str, backend_name: str, workers, split_bytes: int,
                parser: str = "vectorized") -> float:
    backend = make_backend(backend_name, path)
    source = fasta_source(path, backend=backend, split_bytes=split_bytes)
    t0 = time.monotonic()
    m = MaRe.from_source(source,
                         workers=None if workers == "auto" else workers,
                         parser=parser)
    m.dataset.counts.block_until_ready()
    return time.monotonic() - t0


def parse_pack_micro(path: str, reps: int) -> Dict:
    """Head-to-head parse+pack on one in-memory payload: legacy per-line
    parsing + row-at-a-time packing vs vectorized framing + one bulk
    gather.  Pure host compute — no storage latency, no device_put — so
    the ratio isolates exactly what the vectorization changed."""
    with open(path, "rb") as f:
        payload = f.read()
    fmt = FORMATS["fasta"]
    # shared geometry so both paths produce the identical [cap, w] array
    oracle = fmt.frame(payload)
    cap = len(oracle)
    w = oracle.max_len

    def legacy() -> np.ndarray:
        recs = fmt.parse(payload)
        return pack_records(recs, capacity=cap, width=w)["data"]

    def vectorized() -> np.ndarray:
        batch = fmt.frame(payload)
        return pack_batches([batch], capacity=cap, width=w)["data"]

    assert np.array_equal(legacy(), vectorized()), \
        "parse+pack parity violation between legacy and vectorized paths"
    t = {"legacy": [], "vectorized": []}
    for _ in range(reps):
        for name, fn in (("legacy", legacy), ("vectorized", vectorized)):
            t0 = time.perf_counter()
            fn()
            t[name].append(time.perf_counter() - t0)
    t_legacy, t_vec = min(t["legacy"]), min(t["vectorized"])
    return {"payload_bytes": len(payload), "records": cap,
            "t_legacy": t_legacy, "t_vectorized": t_vec,
            "parse_pack_speedup": t_legacy / t_vec}


def main() -> List[Dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: smaller file, fewer pool widths, "
                         "acceptance asserts skipped")
    ap.add_argument("--out", default="BENCH_ingestion.json")
    args = ap.parse_args()

    file_bytes = FILE_BYTES >> 3 if args.small else FILE_BYTES
    split_bytes = SPLIT_BYTES >> 3 if args.small else SPLIT_BYTES
    worker_counts = (1, 8, "auto") if args.small else WORKER_COUNTS
    reps = 1 if args.small else 3
    micro_reps = 2 if args.small else MICRO_REPS

    tmp = tempfile.mkdtemp(prefix="mare_ingest_")
    path = os.path.join(tmp, "genome.fa")
    write_fasta(path, file_bytes)

    # warm-up: absorb one-time JAX/mesh/device_put initialization so the
    # first timed run (the speedup baseline) measures ingestion only
    ingest_once(path, "local", 1, split_bytes)
    ingest_once(path, "local", 1, split_bytes, parser="legacy")

    micro = parse_pack_micro(path, micro_reps)
    print(f"ingestion,micro,parse_pack_speedup="
          f"{micro['parse_pack_speedup']:.2f},"
          f"t_legacy={micro['t_legacy'] * 1e3:.2f}ms,"
          f"t_vectorized={micro['t_vectorized'] * 1e3:.2f}ms")

    # local runs both parsers (legacy = the pre-columnar baseline); the
    # emulated remote tiers are latency-dominated, so one parser suffices
    sweeps = [("local", "vectorized"), ("local", "legacy")] + \
        [(b, "vectorized") for b in BACKENDS if b != "local"]

    rows: List[Dict] = []
    local_best_pooled = None
    for backend, parser in sweeps:
        best = {n: None for n in worker_counts}
        for _ in range(reps):
            for n in worker_counts:
                t = ingest_once(path, backend, n, split_bytes, parser)
                best[n] = t if best[n] is None else min(best[n], t)
        t1 = None
        for n in worker_counts:
            t = best[n]
            t1 = t1 or t
            rows.append({"backend": backend, "parser": parser,
                         "workers": n, "t": t, "speedup": t1 / t})
            print(f"ingestion,{backend},parser={parser},workers={n},"
                  f"t={t:.3f},speedup={t1/t:.2f}")
        if backend == "local" and parser == "vectorized":
            local_best_pooled = max(
                t1 / best[n] for n in worker_counts
                if isinstance(n, int) and n > 1)
            print(f"ingestion,local,best_pooled_speedup="
                  f"{local_best_pooled:.3f}")

    out = {"bench": "ingestion", "small": bool(args.small),
           "file_bytes": file_bytes,
           "split_bytes": split_bytes, "reps": reps,
           "parse_pack": micro,
           "parse_pack_speedup": micro["parse_pack_speedup"],
           "local_best_pooled_speedup": local_best_pooled,
           "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if not args.small:
        assert micro["parse_pack_speedup"] >= MIN_PARSE_PACK_SPEEDUP, (
            f"vectorized parse+pack only "
            f"{micro['parse_pack_speedup']:.2f}x over legacy "
            f"(floor {MIN_PARSE_PACK_SPEEDUP}x)")
        assert local_best_pooled >= MIN_POOLED_LOCAL_SPEEDUP, (
            f"pooled local ingestion anti-scales: best pooled width is "
            f"{local_best_pooled:.3f}x serial "
            f"(floor {MIN_POOLED_LOCAL_SPEEDUP}x)")
        print("ingestion acceptance asserts passed")
    return rows


if __name__ == "__main__":
    main()
