"""Ingestion speedup benchmark (paper Fig. 5) through the real MaRe path.

The paper ingests a dataset from HDFS (co-located), Swift (same DC) and S3
(remote); speedup = T(1 worker) / T(N workers).  This benchmark generates
a FASTA file once, then ingests it via ``MaRe.from_source`` — split
planning, the emulated storage backend's ranged reads (latency profiles in
``repro.io.backends.BACKEND_PROFILES``), the parallel fetch pool, record
packing and device placement — varying the fetch-pool width.  Latency
sleeps happen in the fetching threads, so thread scaling is honest even on
one core.  Results land in ``BENCH_ingestion.json``.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, "src")
from repro.core import MaRe                         # noqa: E402
from repro.io import fasta_source, make_backend     # noqa: E402

BACKENDS = ("local", "hdfs", "swift", "s3")
WORKER_COUNTS = (1, 2, 4, 8, 16)
FILE_BYTES = 1 << 20
SPLIT_BYTES = 1 << 14          # ~64 splits -> meaningful pool parallelism


def write_fasta(path: str, nbytes: int, seed: int = 0) -> None:
    rng = np.random.default_rng(seed)
    bases = np.array(list("ATGC"))
    with open(path, "w") as f:
        f.write(">bench synthetic genome\n")
        written = 0
        while written < nbytes:
            line = "".join(rng.choice(bases, size=70))
            f.write(line + "\n")
            written += 71


def ingest_once(path: str, backend_name: str, workers: int) -> float:
    backend = make_backend(backend_name, path)
    source = fasta_source(path, backend=backend, split_bytes=SPLIT_BYTES)
    t0 = time.monotonic()
    m = MaRe.from_source(source, workers=workers)
    m.dataset.counts.block_until_ready()
    return time.monotonic() - t0


def main() -> List[Dict]:
    tmp = tempfile.mkdtemp(prefix="mare_ingest_")
    path = os.path.join(tmp, "genome.fa")
    write_fasta(path, FILE_BYTES)

    # warm-up: absorb one-time JAX/mesh/device_put initialization so the
    # first timed run (the speedup baseline) measures ingestion only
    ingest_once(path, "local", 1)

    rows: List[Dict] = []
    for backend in BACKENDS:
        t1 = None
        for n in WORKER_COUNTS:
            t = ingest_once(path, backend, n)
            t1 = t1 or t
            rows.append({"backend": backend, "workers": n, "t": t,
                         "speedup": t1 / t})
            print(f"ingestion,{backend},workers={n},t={t:.3f},"
                  f"speedup={t1/t:.2f}")
    out = {"bench": "ingestion", "file_bytes": FILE_BYTES,
           "split_bytes": SPLIT_BYTES, "rows": rows}
    with open("BENCH_ingestion.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote BENCH_ingestion.json")
    return rows


if __name__ == "__main__":
    main()
