"""Roofline table builder: reads reports/dryrun.jsonl and emits the
per-cell three-term analysis (EXPERIMENTS.md §Roofline).

MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train cells,
2 N D (+ attention KV term) for prefill, 2 N per token for decode.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, get_shape  # noqa: E402
from repro.models.common import (active_param_count,  # noqa: E402
                                 param_count_analytic)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops_global(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = active_param_count(cfg)
    d_tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * d_tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * d_tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def load(path: str = "reports/dryrun.jsonl") -> List[Dict]:
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def build_table(rows: List[Dict], multi_pod: Optional[bool] = False,
                tag: Optional[str] = None) -> List[Dict]:
    out = []
    seen = {}
    for r in rows:
        if multi_pod is not None and r.get("multi_pod", False) != multi_pod:
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        seen[(r["arch"], r["shape"])] = r       # last write wins
    for (arch, shape), r in sorted(seen.items()):
        n = r["n_chips"]
        comp = r["flops"] / PEAK_FLOPS
        mem = r["bytes"] / HBM_BW
        coll = (r.get("collectives") or {}).get("wire_bytes", 0.0) / ICI_BW
        dom = max(("compute", comp), ("memory", mem),
                  ("collective", coll), key=lambda kv: kv[1])
        mf = model_flops_global(arch, shape)
        useful = mf / max(r["flops"] * n, 1e-30)
        step_time = max(comp, mem, coll)
        mfu = (mf / n / max(step_time, 1e-30)) / PEAK_FLOPS
        out.append({
            "arch": arch, "shape": shape, "chips": n,
            "compute_s": comp, "memory_s": mem, "collective_s": coll,
            "bottleneck": dom[0], "dominant_s": dom[1],
            "model_flops": mf, "useful_ratio": useful,
            "roofline_frac": comp / max(step_time, 1e-30),
            "mfu_bound": mfu,
            "peak_mem_gb": (r.get("memory", {}).get("peak_bytes") or 0)
            / 1e9,
        })
    return out


def main():
    rows = load()
    table = build_table(rows, multi_pod=False)
    print("arch,shape,compute_s,memory_s,collective_s,bottleneck,"
          "useful_ratio,roofline_frac,peak_mem_gb")
    for t in table:
        print(f"{t['arch']},{t['shape']},{t['compute_s']:.4g},"
              f"{t['memory_s']:.4g},{t['collective_s']:.4g},"
              f"{t['bottleneck']},{t['useful_ratio']:.3f},"
              f"{t['roofline_frac']:.3f},{t['peak_mem_gb']:.1f}")
    return table


if __name__ == "__main__":
    main()
