"""k-mer statistics benchmark: map-side combiner & segment-reduce strategies.

The k-mer counting workload (map ``kmer-stats`` -> ``reduce_by_key``) runs
over the same random reads in three fused modes on an 8-device CPU mesh:

* **combiner_tuned**    — map-side combiner, ``use_kernel=None``: the
  autotuned segment-reduce default (tiled Pallas kernel on TPU, fused
  single-scatter on CPU; see docs/kernels.md)
* **combiner_fallback** — map-side combiner, plain jnp scatter path
* **no_combiner**       — raw ``(key, 1)`` records shuffled, merge only

plus a **skewed-keys** pair (90% of records share one key,
``combiner=False``) comparing the static-capacity exchange against the
salted two-hop exchange (``salt=8``) — ``lax.all_to_all`` ships the full
statically-sized buffer regardless of fill, so the wire cost of a keyed
exchange is ``exchange_buffer_rows * ROW_BYTES``, and that is the metric
salting shrinks.

Invariants asserted in-script (CI policy, same as pipeline.py: fail on a
broken invariant, never on wall-clock):

* every fused mode compiles exactly ONE program, and re-executing the
  identical pipeline is a compile-cache hit (zero re-trace);
* the combiner reduces exchanged shuffle volume (records and bytes) vs
  combiner-off on the same input — the arXiv:1302.2966 shuffle-volume
  optimization, measured from the program's own exchange counters;
* the autotuned default is no slower warm than the scatter fallback
  (``kernel_vs_fallback_warm >= 1.0`` — the guard behind flipping the
  default; CI bench-smoke re-checks the emitted JSON);
* the salted exchange moves fewer buffer bytes than the static-capacity
  baseline on skewed keys, losslessly;
* all modes produce the exact reference k-mer table.

Results land in ``BENCH_kmer.json`` (including the autotuner's candidate
table, rendered by ``benchmarks/summary.py``).

  PYTHONPATH=src python benchmarks/kmer.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

sys.path.insert(0, "src")

import jax                                           # noqa: E402

from repro.core import MaRe, PlanCache               # noqa: E402
from repro import compat                             # noqa: E402
from repro.kernels.segment_reduce import tune_report  # noqa: E402
from repro.obs import TRACER                         # noqa: E402

READ_LEN = 64
#: key + summed value + per-key record count, all int32 (the exchanged
#: record row of a keyed reduce)
ROW_BYTES = 12

MODES = {
    "combiner_tuned": {"combiner": True, "use_kernel": None},
    "combiner_fallback": {"combiner": True, "use_kernel": False},
    "no_combiner": {"combiner": False, "use_kernel": False},
}

SKEW_SALT = 8
SKEW_HOT_FRAC = 0.9


def make_reads(n_reads: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    bases = np.frombuffer(b"ACGT", np.uint8)
    data = bases[rng.integers(0, 4, size=(n_reads, READ_LEN))]
    lens = np.full((n_reads,), READ_LEN, np.int32)
    return {"data": data, "len": lens}


def reference_table(reads: Dict[str, np.ndarray], k: int) -> Dict[int, int]:
    lut = np.full(256, -1, np.int64)
    for i, b in enumerate(b"ACGT"):
        lut[b] = i
    codes = lut[reads["data"]]
    nw = READ_LEN - k + 1
    acc = np.zeros((codes.shape[0], nw), np.int64)
    ok = np.ones((codes.shape[0], nw), bool)
    for j in range(k):
        win = codes[:, j:j + nw]
        acc = acc * 4 + np.maximum(win, 0)
        ok &= win >= 0
    keys, counts = np.unique(acc[ok], return_counts=True)
    return {int(a): int(b) for a, b in zip(keys, counts)}


def _key_of(recs):
    # module-level keyBy/valueBy: the compile cache keys keyed stages on
    # callable identity, so fresh lambdas per run would defeat it
    return recs[0]


def _ones_of(recs):
    return (recs[1],)


def build_pipeline(ds, mesh, cache: PlanCache, k: int, num_keys: int,
                   mode: Dict) -> MaRe:
    return (MaRe(ds, mesh=mesh, plan_cache=cache)
            .map(image="kmer-stats", k=k)
            .reduce_by_key(_key_of, value_by=_ones_of, op="sum",
                           num_keys=num_keys, combiner=mode["combiner"],
                           use_kernel=mode["use_kernel"]))


def run_mode(ds, mesh, k: int, num_keys: int, mode: Dict,
             expected: Dict[int, int]) -> Dict:
    cache = PlanCache()
    t0 = time.monotonic()
    m = build_pipeline(ds, mesh, cache, k, num_keys, mode)
    keys, (occ,), _ = m.collect()
    cold = time.monotonic() - t0
    got = {int(a): int(b) for a, b in zip(keys, occ)}
    assert got == expected, "k-mer table mismatch vs numpy reference"
    exchanged = m.report().diagnostics["stage1.exchanged_records"]
    rep = m.report()
    r = {
        "compiles": cache.stats()["misses"],
        "cold_s": cold,
        # where the cold action's wall went: plan.build / plan.lower /
        # plan.compile / dispatch / device_wait / counter_sync seconds
        "phases_cold": {p: round(s, 6) for p, s in rep.phases.items()},
        "exchanged_records": exchanged,
        "exchanged_bytes": exchanged * ROW_BYTES,
        "max_send_count": m.report().diagnostics["stage1.max_send_count"],
        "exchange_buffer_rows":
            m.report().diagnostics["stage1.exchange_buffer_rows"],
        "key_overflow": m.report().diagnostics["stage1.key_overflow"],
        "cache": cache,
    }
    return r


def run_warm(ds, mesh, k: int, num_keys: int, modes: Dict[str, Dict],
             results: Dict[str, Dict], reps: int) -> None:
    """Interleave warm reps across modes (scheduler-noise fairness, as in
    benchmarks/pipeline.py)."""
    times = {name: [] for name in modes}
    phase_acc: Dict[str, Dict[str, float]] = {name: {} for name in modes}
    for _ in range(reps):
        for name, mode in modes.items():
            cache = results[name]["cache"]
            t0 = time.monotonic()
            m = build_pipeline(ds, mesh, cache, k, num_keys, mode)
            m.collect()
            times[name].append(time.monotonic() - t0)
            for p, s in m.report().phases.items():
                phase_acc[name][p] = phase_acc[name].get(p, 0.0) + s
    for name, r in results.items():
        r["warm_mean_s"] = float(np.mean(times[name]))
        r["warm_min_s"] = float(np.min(times[name]))
        r["phases_warm_mean"] = {p: round(s / reps, 6)
                                 for p, s in phase_acc[name].items()}
        r["recompiles_on_rerun"] = r["cache"].stats()["misses"] \
            - r["compiles"]
        r["cache"] = r.pop("cache").stats()


# -- skewed-keys exchange: static capacity vs salted two-hop ------------------

def _skew_pipeline(ds, mesh, cache, num_keys, salt):
    return MaRe(ds, mesh=mesh, plan_cache=cache).reduce_by_key(
        _key_of, value_by=_ones_of, op="sum", num_keys=num_keys,
        combiner=False, salt=salt)


def run_skew(mesh, n_records: int, num_keys: int, reps: int) -> Dict:
    """Hot-key (90%-one-key) keyed reduce, combiner off: the worst case
    for a statically-sized exchange.  Wire cost of each variant is its
    static buffer allocation (``all_to_all`` ships capacity, not fill)."""
    rng = np.random.default_rng(7)
    keys = np.where(rng.random(n_records) < SKEW_HOT_FRAC, 3,
                    rng.integers(0, num_keys, n_records)).astype(np.int32)
    ones = np.ones(n_records, np.int32)
    ds = MaRe((keys, ones), mesh=mesh).dataset
    out: Dict[str, Dict] = {}
    expected = None
    for name, salt in (("skewed_static", 1), ("skewed_salted", SKEW_SALT)):
        cache = PlanCache()
        m = _skew_pipeline(ds, mesh, cache, num_keys, salt)
        got_keys, (got_sum,), got_cnt = m.collect()
        table = {int(a): (int(b), int(c))
                 for a, b, c in zip(got_keys, got_sum, got_cnt)}
        if expected is None:
            expected = table
        assert table == expected, f"{name}: result mismatch vs static"
        times = []
        for _ in range(reps):
            t0 = time.monotonic()
            _skew_pipeline(ds, mesh, cache, num_keys, salt).collect()
            times.append(time.monotonic() - t0)
        d = m.report().diagnostics
        rows = d["stage0.exchange_buffer_rows"]
        out[name] = {
            "salt": salt,
            "exchanged_records": d["stage0.exchanged_records"],
            "exchange_buffer_rows": rows,
            # what actually crosses the wire: full buffers, per shard
            "exchanged_bytes": rows * ROW_BYTES,
            "max_send_count": d["stage0.max_send_count"],
            "dropped": d["stage0.shuffle_dropped"],
            "warm_min_s": float(np.min(times)),
        }
    static, salted = out["skewed_static"], out["skewed_salted"]
    out["salted_buffer_reduction"] = (
        static["exchanged_bytes"] / max(1, salted["exchanged_bytes"]))
    out["n_records"] = n_records
    out["num_keys"] = num_keys
    out["hot_frac"] = SKEW_HOT_FRAC
    return out


def main() -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: tiny dataset, few reps")
    ap.add_argument("--out", default="BENCH_kmer.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Chrome-trace JSON of the whole run "
                         "(load it in https://ui.perfetto.dev)")
    args = ap.parse_args()
    if args.trace_out:
        TRACER.start()

    n_reads = 1_024 if args.small else 8_192
    k = 5 if args.small else 6
    # warm_min needs enough samples at full scale for the guard ratio
    # to be stable (the tuned-vs-fallback gap is a few percent)
    reps = 6 if args.small else 12
    num_keys = 4 ** k

    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    reads = make_reads(n_reads)
    expected = reference_table(reads, k)
    ds = MaRe(reads, mesh=mesh).dataset      # shard once, time pipelines

    results = {name: run_mode(ds, mesh, k, num_keys, mode, expected)
               for name, mode in MODES.items()}
    run_warm(ds, mesh, k, num_keys, MODES, results, reps)
    skew = run_skew(mesh, n_records=n_reads * 4, num_keys=num_keys,
                    reps=max(2, reps // 2))

    on = results["combiner_tuned"]
    off = results["no_combiner"]
    out = {
        "bench": "kmer",
        "devices": jax.device_count(),
        "n_reads": n_reads,
        "read_len": READ_LEN,
        "k": k,
        "num_keys": num_keys,
        "total_kmers": sum(expected.values()),
        "distinct_kmers": len(expected),
        "reps": reps,
        **{name: r for name, r in results.items()},
        "skewed": skew,
        "combiner_exchange_reduction":
            off["exchanged_records"] / max(1, on["exchanged_records"]),
        "kernel_vs_fallback_warm":
            results["combiner_fallback"]["warm_min_s"]
            / max(1e-9, results["combiner_tuned"]["warm_min_s"]),
        # the autotuner's audit trail: every shape tuned this process,
        # candidates tried and the winner (summary.py's tiling table)
        "autotune": tune_report(),
    }
    for name, r in results.items():
        print(f"kmer,{name},compiles={r['compiles']},"
              f"exchanged={r['exchanged_records']}"
              f"({r['exchanged_bytes']}B),cold={r['cold_s']:.3f}s,"
              f"warm_min={r['warm_min_s']*1e3:.1f}ms,"
              f"rerun_recompiles={r['recompiles_on_rerun']}")
    print(f"kmer,combiner_exchange_reduction="
          f"{out['combiner_exchange_reduction']:.2f}x")
    print(f"kmer,kernel_vs_fallback_warm="
          f"{out['kernel_vs_fallback_warm']:.3f}x")
    for name in ("skewed_static", "skewed_salted"):
        s = skew[name]
        print(f"kmer,{name},buffer_rows={s['exchange_buffer_rows']},"
              f"bytes={s['exchanged_bytes']},max_send={s['max_send_count']},"
              f"warm_min={s['warm_min_s']*1e3:.1f}ms")
    print(f"kmer,salted_buffer_reduction="
          f"{skew['salted_buffer_reduction']:.2f}x")

    for name, r in results.items():
        assert r["compiles"] == 1, \
            f"{name}: fused reduce_by_key must compile exactly 1 program," \
            f" got {r['compiles']}"
        assert r["recompiles_on_rerun"] == 0, \
            f"{name}: re-run must hit the compile cache"
        assert r["key_overflow"] == 0, f"{name}: unexpected key overflow"
    assert on["exchanged_records"] < off["exchanged_records"], \
        "map-side combiner must reduce exchanged records " \
        f"({on['exchanged_records']} vs {off['exchanged_records']})"
    assert on["exchanged_bytes"] < off["exchanged_bytes"], \
        "map-side combiner must reduce exchanged bytes"
    # The default-flip guard: autotuned dispatch must be no slower warm
    # than the scatter fallback it replaced.  Asserted at full scale only:
    # in --small the segment-reduce is <1% of a ~30ms action, so the
    # ratio is pure dispatch noise — CI instead checks the committed
    # full-scale BENCH_kmer.json (bench-smoke "default-flip guard" step).
    if not args.small:
        assert out["kernel_vs_fallback_warm"] >= 1.0, \
            "autotuned segment-reduce slower than fallback " \
            f"({out['kernel_vs_fallback_warm']:.3f}x) — default flip guard"
    assert (skew["skewed_salted"]["exchanged_bytes"]
            < skew["skewed_static"]["exchanged_bytes"]), \
        "salted exchange must shrink buffer bytes on hot-key data"
    assert skew["skewed_salted"]["dropped"] == 0, \
        "salted exchange must stay lossless on the bench distribution"

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if args.trace_out:
        TRACER.stop()
        TRACER.export(args.trace_out)
        print(f"wrote {args.trace_out} "
              f"({TRACER.events_total} events, "
              f"{TRACER.events_dropped} dropped)")
    return out


if __name__ == "__main__":
    main()
