"""Render all BENCH_*.json results as a GitHub-flavored markdown table.

CI appends the output to ``$GITHUB_STEP_SUMMARY`` after the bench-smoke
job so every run's numbers are readable from the Actions UI without
downloading artifacts:

  python benchmarks/summary.py [dir] >> "$GITHUB_STEP_SUMMARY"

Top-level scalar fields of each result file become rows; nested per-mode
dicts contribute their scalar fields as ``mode.field`` rows.  Floats are
rounded for readability; nothing here asserts — the benchmarks themselves
enforce their invariants in-script.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def rows_for(result: dict):
    for key, value in result.items():
        if isinstance(value, (int, float, str, bool)):
            yield key, _fmt(value)
        elif isinstance(value, dict):
            for sub, sv in value.items():
                if isinstance(sv, (int, float, str, bool)):
                    yield f"{key}.{sub}", _fmt(sv)


def cache_row(result: dict):
    """Runtime materialization-cache columns (BENCH_interactive.json and
    any future result reporting them): hit-rate and recompute avoided."""
    if "cache_hit_rate" not in result \
            and "recompute_avoided_stages" not in result:
        return None
    return (result.get("cache_hit_rate"),
            result.get("recompute_avoided_stages"),
            result.get("prefix_speedup"))


def print_cache_table(results) -> None:
    rows = [(name, cache_row(result)) for name, result in results]
    rows = [(name, r) for name, r in rows if r is not None]
    if not rows:
        return
    print("\n### Runtime materialization cache\n")
    print("| bench | cache hit-rate | recompute avoided (stages) "
          "| prefix speedup |")
    print("| --- | --- | --- | --- |")
    for name, (rate, avoided, speedup) in rows:
        print(f"| {name} "
              f"| {_fmt(rate) if rate is not None else '-'} "
              f"| {_fmt(avoided) if avoided is not None else '-'} "
              f"| {_fmt(speedup) + 'x' if speedup is not None else '-'} |")


def serve_rows(result: dict):
    """Per-mode latency/QPS columns for the serving benchmark
    (BENCH_serve.json): one row per load mode, plus the coalescing and
    isolation numbers the bench asserts on."""
    for mode in ("single", "cold", "shared"):
        sub = result.get(mode)
        if not isinstance(sub, dict) or "p50_s" not in sub:
            continue
        yield (mode, sub.get("tenants"), sub.get("qps"),
               sub["p50_s"] * 1e3, sub.get("p99_s", 0.0) * 1e3,
               sub.get("mean_batch_occupancy"),
               sub.get("mat_cache", {}).get("shared_hits"))


def print_serve_table(results) -> None:
    for name, result in results:
        rows = list(serve_rows(result))
        if not rows:
            continue
        print(f"\n### Multi-tenant serving ({name})\n")
        print("| mode | tenants | qps | p50 (ms) | p99 (ms) "
              "| batch occupancy | shared hits |")
        print("| --- | --- | --- | --- | --- | --- | --- |")
        for mode, tenants, qps, p50, p99, occ, shared in rows:
            print(f"| {mode} | {tenants} | {_fmt(qps)} | {_fmt(p50)} "
                  f"| {_fmt(p99)} | {_fmt(occ)} | {_fmt(shared)} |")
        ratio = result.get("p50_shared_over_cold")
        fair = result.get("worst_tenant_p99_over_single")
        if ratio is not None and fair is not None:
            print(f"\n{name}: shared-prefix p50 = **{_fmt(ratio)}x** cold "
                  f"(guard: <= 0.6), worst-tenant p99 = **{_fmt(fair)}x** "
                  f"single-tenant (guard: <= 2.0), budget violations = "
                  f"{result.get('tenant_budget_violations')}")


def stream_rows(result: dict):
    """Per-epoch scaling rows for the streaming benchmark
    (BENCH_stream.json): update cost stays flat while history — and the
    full-recompute column, where measured — grows."""
    for row in result.get("scaling", []):
        if not isinstance(row, dict) or "update_ms" not in row:
            continue
        yield (row.get("epoch"), row.get("history_records"),
               row.get("delta_records"), row["update_ms"],
               row.get("full_ms"), row.get("speedup"))


def print_stream_table(results) -> None:
    for name, result in results:
        rows = list(stream_rows(result))
        if not rows:
            continue
        print(f"\n### Streaming: incremental vs full recompute ({name})\n")
        print("| epoch | history records | delta records | update (ms) "
              "| full recompute (ms) | speedup |")
        print("| --- | --- | --- | --- | --- | --- |")
        for epoch, hist, delta, upd, full, speedup in rows:
            print(f"| {epoch} | {hist} | {delta} | {_fmt(upd)} "
                  f"| {_fmt(full) if full is not None else '-'} "
                  f"| {_fmt(speedup) + 'x' if speedup is not None else '-'}"
                  f" |")
        headline = result.get("incremental_speedup")
        if headline is not None:
            print(f"\n{name}: per-epoch update = **{_fmt(headline)}x** "
                  f"faster than full recompute at history >= 10x epoch "
                  f"size (guard: >= 5.0 at full scale), recompiles after "
                  f"warm = {result.get('recompiles_after_warm')}, fold "
                  f"compiles = {result.get('fold_compiles')}, exact "
                  f"match = {result.get('exact_match')}")


def ingestion_rows(result: dict):
    """Fetch-pool scaling rows for the ingestion benchmark
    (BENCH_ingestion.json): one row per backend x parser x pool width,
    speedup relative to that sweep's serial baseline.  ``parser`` is
    ``vectorized`` (columnar RecordBatch framing) or ``legacy`` (per-line
    oracle) — local runs both so the vectorization win is visible."""
    for row in result.get("rows", []):
        if not isinstance(row, dict) or "speedup" not in row:
            continue
        yield (row.get("backend"), row.get("parser", "-"),
               row.get("workers"), row["t"] * 1e3, row["speedup"])


def print_ingestion_table(results) -> None:
    for name, result in results:
        rows = list(ingestion_rows(result))
        if not rows:
            continue
        print(f"\n### Ingestion fetch-pool scaling ({name})\n")
        print("| backend | parser | workers | t (ms) | speedup |")
        print("| --- | --- | --- | --- | --- |")
        for backend, parser, workers, t_ms, speedup in rows:
            print(f"| {backend} | {parser} | {workers} | {_fmt(t_ms)} "
                  f"| {_fmt(speedup)}x |")
        micro = result.get("parse_pack_speedup")
        pooled = result.get("local_best_pooled_speedup")
        if micro is not None and pooled is not None:
            print(f"\n{name}: vectorized parse+pack = **{_fmt(micro)}x** "
                  f"legacy on local FASTA (guard: >= 3.0 at full scale), "
                  f"best pooled local width = **{_fmt(pooled)}x** serial "
                  f"(guard: >= 0.95 at full scale)")


def phase_rows(name: str, result: dict):
    """Per-phase wall breakdowns: any nested dict field whose name
    mentions 'phase' maps phase -> seconds (e.g. kmer's ``phases_cold``
    per mode, interactive's ``query_phase_mean_s`` per mode)."""
    for mode, sub in result.items():
        if not isinstance(sub, dict):
            continue
        for key, val in sub.items():
            if "phase" not in key or not isinstance(val, dict):
                continue
            for phase, s in sorted(val.items(), key=lambda kv: -kv[1]):
                yield name, f"{mode}.{key}", phase, s


def print_phase_table(results) -> None:
    rows = [row for name, result in results
            for row in phase_rows(name, result)]
    if not rows:
        return
    print("\n### Phase breakdown\n")
    print("| bench | mode | phase | seconds |")
    print("| --- | --- | --- | --- |")
    for bench, mode, phase, s in rows:
        print(f"| {bench} | {mode} | {phase} | {_fmt(s)} |")


def tuning_rows(name: str, result: dict):
    """Segment-reduce autotuner audit rows: one per tuned shape, from the
    ``autotune`` list kmer.py embeds (see ``tune_report()``).  Candidate
    timings are inlined as ``name=ms`` pairs so a mis-pick is visible at
    a glance; block/key_block are only meaningful for the tiled kernel."""
    for entry in result.get("autotune", []):
        cands = ", ".join(f"{c['candidate']}={c['ms']:.2f}ms"
                          for c in entry.get("candidates", []))
        blocks = (f"{entry['block']}x{entry['key_block']}"
                  if entry.get("chosen") == "tiled" else "-")
        yield (name, entry["backend"],
               f"n={entry['n']}, keys={entry['num_keys']}",
               entry["chosen"], blocks, cands or "-")


def print_tuning_table(results) -> None:
    rows = [row for name, result in results
            for row in tuning_rows(name, result)]
    if not rows:
        return
    print("\n### Segment-reduce autotuner\n")
    print("| bench | backend | shape | chosen | blocks | candidates |")
    print("| --- | --- | --- | --- | --- | --- |")
    for bench, backend, shape, chosen, blocks, cands in rows:
        print(f"| {bench} | {backend} | {shape} "
              f"| {chosen} | {blocks} | {cands} |")
    for name, result in results:
        ratio = result.get("kernel_vs_fallback_warm")
        if ratio is not None:
            print(f"\n{name}: tuned default vs scatter fallback, warm: "
                  f"**{_fmt(ratio)}x** (guard: >= 1.0 at full scale)")


def main() -> int:
    bench_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        print("No BENCH_*.json results found.")
        return 0
    print("## Benchmark results")
    results = []
    for path in paths:
        with open(path) as f:
            result = json.load(f)
        name = result.get("bench", os.path.basename(path))
        results.append((name, result))
        print(f"\n### {name} (`{os.path.basename(path)}`)\n")
        print("| metric | value |")
        print("| --- | --- |")
        for key, value in rows_for(result):
            print(f"| {key} | {value} |")
    print_cache_table(results)
    print_ingestion_table(results)
    print_serve_table(results)
    print_stream_table(results)
    print_tuning_table(results)
    print_phase_table(results)
    return 0


if __name__ == "__main__":
    sys.exit(main())
