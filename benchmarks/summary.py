"""Render all BENCH_*.json results as a GitHub-flavored markdown table.

CI appends the output to ``$GITHUB_STEP_SUMMARY`` after the bench-smoke
job so every run's numbers are readable from the Actions UI without
downloading artifacts:

  python benchmarks/summary.py [dir] >> "$GITHUB_STEP_SUMMARY"

Top-level scalar fields of each result file become rows; nested per-mode
dicts contribute their scalar fields as ``mode.field`` rows.  Floats are
rounded for readability; nothing here asserts — the benchmarks themselves
enforce their invariants in-script.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def rows_for(result: dict):
    for key, value in result.items():
        if isinstance(value, (int, float, str, bool)):
            yield key, _fmt(value)
        elif isinstance(value, dict):
            for sub, sv in value.items():
                if isinstance(sv, (int, float, str, bool)):
                    yield f"{key}.{sub}", _fmt(sv)


def main() -> int:
    bench_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
    if not paths:
        print("No BENCH_*.json results found.")
        return 0
    print("## Benchmark results")
    for path in paths:
        with open(path) as f:
            result = json.load(f)
        name = result.get("bench", os.path.basename(path))
        print(f"\n### {name} (`{os.path.basename(path)}`)\n")
        print("| metric | value |")
        print("| --- | --- |")
        for key, value in rows_for(result):
            print(f"| {key} | {value} |")
    return 0


if __name__ == "__main__":
    sys.exit(main())
