"""Inject the baseline/optimized roofline tables into EXPERIMENTS.md."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from roofline import build_table, load  # noqa: E402


def md_table(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | useful | roofline frac | peak GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_mem_gb']:.1f} |")
    return "\n".join(out)


def main():
    rows = load()
    base = build_table(rows, multi_pod=False, tag="baseline")
    opt = build_table(rows, multi_pod=False, tag="optimized")
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- BASELINE_TABLE -->", md_table(base), 1)
    text = text.replace("<!-- OPTIMIZED_TABLE -->", md_table(opt), 1)
    open(path, "w").write(text)
    print(f"injected {len(base)} baseline + {len(opt)} optimized rows")


if __name__ == "__main__":
    main()
