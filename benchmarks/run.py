"""Benchmark harness — one entry per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Emits ``name,key=value,...`` CSV lines:
  vs_wse        — paper Fig. 3 (Virtual Screening weak scaling)
  snp_wse       — paper Fig. 4 (SNP calling weak scaling)
  ingestion     — paper Fig. 5 (storage-backend ingestion speedup)
  reduce_depth  — paper §1.2.2 tree-depth K trade-off
  kernel_micro  — Pallas kernel design points
  roofline      — per (arch x shape) three-term table from the dry-run
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow multi-process WSE sweeps")
    ap.add_argument("--skip", action="append", default=[])
    args = ap.parse_args()

    failures = []

    def section(name, fn):
        if any(s in name for s in args.skip):
            return
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()

    from benchmarks import ingestion, kernel_micro, reduce_depth, roofline

    if not args.fast:
        from benchmarks import wse
        section("vs_wse (paper Fig. 3)", lambda: wse.main("vs"))
        section("snp_wse (paper Fig. 4)", lambda: wse.main("snp"))
    section("ingestion (paper Fig. 5)", ingestion.main)
    section("reduce_depth (paper §1.2.2)", reduce_depth.main)
    section("kernel_micro", kernel_micro.main)
    if os.path.exists("reports/dryrun.jsonl"):
        section("roofline (dry-run)", roofline.main)
    else:
        print("# roofline skipped: run `python -m repro.launch.dryrun` "
              "first (reports/dryrun.jsonl missing)")
    if failures:
        print("FAILURES:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
