"""Multi-tenant serving benchmark: fairness, batching, prefix sharing.

N concurrent tenant sessions (threads, barrier-synced rounds so every
tenant fires the same query at the same instant) drive one shared
:class:`repro.serve.QueryService` in three isolated modes (fresh
executor + compile cache + materialization cache each):

* **single** — ONE session issues the same TOTAL number of queries
  sequentially over the persisted shared prefix: the no-contention
  latency baseline for the fairness criterion;
* **cold**   — N sessions, no persisted prefix, batching disabled:
  every query pays its own full-plan dispatch through the fair
  scheduler (the naive multi-tenant deployment);
* **shared** — N sessions over the persisted shared prefix with
  batching on: identical queries coalesce into one suffix-only dispatch
  per round, and each tenant additionally persists private datasets
  under a small per-tenant cache budget to exercise partition eviction.

Invariants asserted in-script (everything but the two latency ratios is
wall-clock-free; the ratios are this benchmark's acceptance criteria —
they compare modes on the same machine in the same run, so machine speed
divides out):

* every mode and every tenant computes identical query results;
* measured rounds compile zero programs in every mode;
* ``tenant_budget_violations == 0`` and no tenant's cache footprint
  exceeds its partition after the private-persist churn (one tenant's
  evictions never touch another tenant's entries);
* shared mode actually batches (mean occupancy > 1) and actually shares
  (``shared_hits > 0``);
* shared-prefix p50 <= 0.6x cold p50;
* worst per-tenant p99 under fair scheduling (shared mode) <= 2x the
  single-tenant p99 at the same total load.

  PYTHONPATH=src python benchmarks/serve.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from typing import Dict, List

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

sys.path.insert(0, "src")

import jax                                           # noqa: E402

from repro import compat                             # noqa: E402
from repro.core import MaRe, PlanCache               # noqa: E402
from repro.core.dataset import from_host             # noqa: E402
from repro.obs import METRICS                        # noqa: E402
from repro.runtime import (Executor,                 # noqa: E402
                           MaterializationCache, estimate_nbytes)
from repro.serve import QueryService, ServiceConfig  # noqa: E402

READ_LEN = 64
QUERY_OPS = ("sum", "max", "min")


def make_reads(n_reads: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    bases = np.frombuffer(b"ACGT", np.uint8)
    data = bases[rng.integers(0, 4, size=(n_reads, READ_LEN))]
    return {"data": data, "len": np.full((n_reads,), READ_LEN, np.int32)}


def _key_of(recs):
    # module-level keyBy/valueBy: lineage signatures, the compile cache
    # AND the serving batch key all key on callable identity — fresh
    # lambdas would defeat cross-session coalescing entirely
    return recs[0]


def _ones_of(recs):
    return (recs[1],)


def _normalize(result):
    keys, (vals,), counts = result
    order = np.argsort(np.asarray(keys))
    return (np.asarray(keys)[order].tolist(),
            np.asarray(vals)[order].tolist(),
            np.asarray(counts)[order].tolist())


def _pct(samples: List[float], q: float) -> float:
    s = np.sort(np.asarray(samples))
    return float(s[min(len(s) - 1, int(q / 100.0 * (len(s) - 1) + 0.5))])


def run_mode(shared_ds, mesh, *, name: str, tenants: int, rounds: int,
             k: int, num_keys: int, persist_prefix: bool,
             batch_window_s: float, private_persists: int,
             tenant_budget_bytes: int) -> Dict:
    """One isolated service per mode: fresh executor, compile cache and
    materialization cache; same dataset and query mix."""
    METRICS.reset()
    executor = Executor(plan_cache=PlanCache(),
                        mat_cache=MaterializationCache())
    config = ServiceConfig(
        batch_window_s=batch_window_s,
        max_queued_per_tenant=max(8, tenants),
        tenant_device_budget_bytes=tenant_budget_bytes)
    r: Dict = {"mode": name, "tenants": tenants, "rounds": rounds}

    with QueryService(executor=executor, config=config) as svc:
        sessions = [svc.session(f"tenant{i}") for i in range(tenants)]

        if persist_prefix:
            t0 = time.monotonic()
            sessions[0].mare(shared_ds).map(image="kmer-stats",
                                            k=k).persist()
            r["persist_s"] = time.monotonic() - t0

        def query(sess, op, label=None):
            return (sess.mare(shared_ds)
                    .map(image="kmer-stats", k=k)
                    .reduce_by_key(_key_of, value_by=_ones_of, op=op,
                                   num_keys=num_keys)
                    .collect(label=label))

        # warmup pays every compile this mode will ever need
        results = {op: _normalize(query(sessions[0], op, "warmup"))
                   for op in QUERY_OPS}
        r["warmup_programs_compiled"] = \
            executor.plan_cache.stats()["misses"]

        # private-persist churn: each tenant pins its OWN small datasets
        # under the per-tenant budget — enough of them that the partition
        # must evict, proving eviction stays within the owner
        if private_persists:
            priv_rows = max(64, tenant_budget_bytes // (2 * 8))
            for i, sess in enumerate(sessions):
                for j in range(private_persists):
                    pds = from_host(
                        {"v": np.full((priv_rows,), i * 131 + j,
                                      np.int64)}, mesh)
                    sess.mare(pds).persist()

        before = executor.plan_cache.stats()
        pre = METRICS.snapshot()
        barrier = threading.Barrier(tenants)
        per_tenant: List[List[float]] = [[] for _ in sessions]
        mode_results: List[Dict] = [dict() for _ in sessions]

        def client(idx: int) -> None:
            sess = sessions[idx]
            for rnd in range(rounds):
                op = QUERY_OPS[rnd % len(QUERY_OPS)]
                barrier.wait()      # same-key queries fire together
                t0 = time.monotonic()
                out = query(sess, op, f"round {rnd}")
                per_tenant[idx].append(time.monotonic() - t0)
                mode_results[idx][op] = _normalize(out)

        threads = [threading.Thread(target=client, args=(i,), daemon=True)
                   for i in range(tenants)]
        t0 = time.monotonic()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0
        after = executor.plan_cache.stats()

        for idx, res in enumerate(mode_results):
            for op, norm in res.items():
                assert norm == results[op], \
                    f"{name}: tenant{idx} {op!r} result differs"

        flat = [s for lats in per_tenant for s in lats]
        snap = METRICS.snapshot()
        # measured window only (warmup/persist dispatches excluded)
        dispatches = int(snap.get("serve.dispatches", 0)) \
            - int(pre.get("serve.dispatches", 0))
        followers = int(snap.get("serve.batched_followers", 0)) \
            - int(pre.get("serve.batched_followers", 0))
        mat = executor.mat_cache.stats()
        r.update({
            "results": results,
            "measured_actions": len(flat),
            "measured_programs_compiled":
                after["misses"] - before["misses"],
            "wall_s": wall,
            "qps": len(flat) / wall,
            "p50_s": _pct(flat, 50),
            "p99_s": _pct(flat, 99),
            "per_tenant_p99_s": [_pct(lats, 99) for lats in per_tenant],
            "dispatches": dispatches,
            "mean_batch_occupancy": len(flat) / max(1, dispatches),
            "batched_followers": followers,
            "admission_rejected":
                int(snap.get("serve.admission_rejected", 0)),
            "mat_cache": mat,
            "owner_bytes": {
                str(owner): tiers for owner, tiers
                in executor.mat_cache.owner_bytes().items()},
        })
        assert r["measured_programs_compiled"] == 0, \
            f"{name}: measured rounds must not recompile"
        assert mat["tenant_budget_violations"] == 0, \
            f"{name}: cross-tenant cache-budget violation recorded"
        for owner, tiers in executor.mat_cache.owner_bytes().items():
            if owner is None:
                continue
            assert tiers["device"] <= tenant_budget_bytes, \
                (f"{name}: {owner} device footprint {tiers['device']} "
                 f"exceeds its {tenant_budget_bytes}-byte partition")
    return r


def main() -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: tiny dataset, few rounds")
    ap.add_argument("--sessions", type=int, default=8,
                    help="concurrent tenant sessions (acceptance: >= 8)")
    ap.add_argument("--batch-window", type=float, default=0.025)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    n_reads = 512 if args.small else 4_096
    k = 3 if args.small else 5
    rounds = 3 if args.small else 9
    num_keys = 4 ** k
    tenants = args.sessions

    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    shared_ds = MaRe(make_reads(n_reads), mesh=mesh).dataset

    # per-tenant partition: fits 2 private datasets, so the 3rd persist
    # must evict that tenant's own oldest entry
    private_persists = 3
    probe = from_host({"v": np.zeros((max(64, 4096 // (2 * 8)),),
                                     np.int64)}, mesh)
    entry_bytes = estimate_nbytes(probe)
    tenant_budget = int(entry_bytes * 2.5)

    common = dict(tenants=tenants, rounds=rounds, k=k, num_keys=num_keys,
                  private_persists=private_persists,
                  tenant_budget_bytes=tenant_budget)
    single = run_mode(shared_ds, mesh, name="single",
                      **{**common, "tenants": 1,
                         "rounds": tenants * rounds},
                      persist_prefix=True,
                      batch_window_s=args.batch_window)
    cold = run_mode(shared_ds, mesh, name="cold", **common,
                    persist_prefix=False, batch_window_s=0.0)
    shared = run_mode(shared_ds, mesh, name="shared", **common,
                      persist_prefix=True,
                      batch_window_s=args.batch_window)

    # -- cross-mode invariants ----------------------------------------------
    for op in QUERY_OPS:
        assert single["results"][op] == cold["results"][op] \
            == shared["results"][op], f"{op!r}: modes disagree"
    for mode in (single, cold, shared):
        mode.pop("results")
    assert shared["mean_batch_occupancy"] > 1.0, \
        "shared mode never batched"
    assert shared["mat_cache"]["shared_hits"] > 0, \
        "shared mode recorded no cross-tenant prefix hits"
    assert cold["mat_cache"]["hits"] == 0, \
        "cold mode must never hit the materialization cache"

    # -- acceptance criteria (latency ratios, same machine, same run) --------
    p50_ratio = shared["p50_s"] / cold["p50_s"]
    assert p50_ratio <= 0.6, \
        (f"shared-prefix p50 {shared['p50_s'] * 1e3:.1f}ms not <= 0.6x "
         f"cold p50 {cold['p50_s'] * 1e3:.1f}ms (ratio {p50_ratio:.2f})")
    worst_p99 = max(shared["per_tenant_p99_s"])
    fair_ratio = worst_p99 / single["p99_s"]
    assert fair_ratio <= 2.0, \
        (f"worst per-tenant p99 {worst_p99 * 1e3:.1f}ms not <= 2x "
         f"single-tenant p99 {single['p99_s'] * 1e3:.1f}ms "
         f"(ratio {fair_ratio:.2f})")

    out = {
        "bench": "serve",
        "devices": jax.device_count(),
        "concurrent_sessions": tenants,
        "rounds": rounds,
        "n_reads": n_reads,
        "k": k,
        "num_keys": num_keys,
        "batch_window_s": args.batch_window,
        "tenant_budget_bytes": tenant_budget,
        "private_persists_per_tenant": private_persists,
        "single": single,
        "cold": cold,
        "shared": shared,
        "p50_shared_over_cold": p50_ratio,
        "worst_tenant_p99_over_single": fair_ratio,
        "tenant_budget_violations":
            shared["mat_cache"]["tenant_budget_violations"],
    }
    for mode in (single, cold, shared):
        print(f"serve,{mode['mode']},"
              f"actions={mode['measured_actions']},"
              f"qps={mode['qps']:.2f},"
              f"p50={mode['p50_s'] * 1e3:.1f}ms,"
              f"p99={mode['p99_s'] * 1e3:.1f}ms,"
              f"occupancy={mode['mean_batch_occupancy']:.2f}")
    print(f"serve,p50_shared/cold={p50_ratio:.3f},"
          f"fairness_p99/single={fair_ratio:.3f},"
          f"budget_violations={out['tenant_budget_violations']}")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
