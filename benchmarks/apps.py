"""The paper's two evaluation applications, as MaRe pipelines on
synthetic data (used by examples/ and the WSE benchmarks).

Virtual Screening (paper Listing 2):
  map:    surrogate docking scorer over the molecule shards (FRED stand-in
          — a fixed-round arithmetic kernel over conformer features)
  reduce: keep the 30 best-scoring poses (sdsorter stand-in — the
          toolbox/topk combiner, backed by the topk_reduce Pallas kernel
          on TPU).

SNP calling (paper Listing 3):
  map:          per-read alignment score + chromosome assignment (BWA
                stand-in)
  repartitionBy: chromosome id (GATK requires all reads of a chromosome
                on one partition)
  map:          per-chromosome variant calling (HaplotypeCaller stand-in)
  reduce:       concatenate VCF records (vcf-concat stand-in).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.core import MaRe, TextFile
from repro.core.container import (DEFAULT_REGISTRY, Partition, container_op,
                                  make_partition)

FEATURES = 16
N_CHROMOSOMES = 24


def _register_once():
    if "tools/fred:latest" in DEFAULT_REGISTRY.images():
        return

    @container_op("tools/fred", registry=DEFAULT_REGISTRY)
    def fred(part: Partition, command: str = "", rounds: int = 8,
             **kw) -> Partition:
        """Surrogate docking: iterative arithmetic over features ->
        binding-affinity score per molecule."""
        feats, mol_id = part.records
        x = feats.astype(jnp.float32)
        for r in range(rounds):
            x = jnp.tanh(x @ _mix(FEATURES, r)) + 0.1 * x
        score = jnp.sum(x, axis=-1)
        return make_partition((score, mol_id), part.count)

    @container_op("tools/bwa", registry=DEFAULT_REGISTRY)
    def bwa(part: Partition, command: str = "", rounds: int = 4,
            **kw) -> Partition:
        """Surrogate aligner: read -> (chrom, align score)."""
        reads, read_id = part.records
        x = reads.astype(jnp.float32)
        for r in range(rounds):
            x = jnp.sin(x @ _mix(FEATURES, 17 + r)) + 0.2 * x
        score = jnp.sum(x, axis=-1)
        chrom = (jnp.abs(jnp.sum(reads, axis=-1).astype(jnp.int32))
                 % N_CHROMOSOMES)
        return make_partition((chrom, score, read_id), part.count)

    @container_op("tools/gatk", registry=DEFAULT_REGISTRY)
    def gatk(part: Partition, command: str = "", **kw) -> Partition:
        """Surrogate variant caller over a chromosome-grouped partition:
        emits one 'variant' per read above a score threshold."""
        chrom, score, read_id = part.records
        valid = part.mask()
        is_var = (score > 0.0) & valid
        # compact variants to front (order-stable)
        order = jnp.argsort(~is_var, stable=True)
        out = tuple(jnp.take(a, order, axis=0)
                    for a in (chrom, score, read_id))
        return make_partition(out, jnp.sum(is_var).astype(jnp.int32))


def _mix(n: int, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, n)) / np.sqrt(n), jnp.float32)


def make_library(n_molecules: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(n_molecules, FEATURES)).astype(np.float32)
    ids = np.arange(n_molecules, dtype=np.int32)
    return feats, ids


def virtual_screening(library, mesh=None, top: int = 30, rounds: int = 8,
                      depth: int = 2):
    """Paper Listing 2 — returns (scores [top], mol_ids [top])."""
    _register_once()
    pipeline = (MaRe(library, mesh=mesh)
                .map(input_mount=TextFile("/in.sdf", "\n$$$$\n"),
                     output_mount=TextFile("/out.sdf", "\n$$$$\n"),
                     image="tools/fred", rounds=rounds)
                .reduce(input_mount=TextFile("/in.sdf", "\n$$$$\n"),
                        output_mount=TextFile("/out.sdf", "\n$$$$\n"),
                        image="toolbox/topk", k=top, depth=depth))
    return pipeline.collect(shard=0)


def snp_calling(reads, mesh=None, rounds: int = 4):
    """Paper Listing 3 — returns (chrom, score, read_id) variant arrays."""
    _register_once()
    m = (MaRe(reads, mesh=mesh)
         .map(input_mount=TextFile("/in.fastq"),
              output_mount=TextFile("/out.sam"),
              image="tools/bwa", rounds=rounds)
         .repartition_by(lambda recs: recs[0])      # keyBy chromosome
         .map(image="tools/gatk")
         .reduce(image="toolbox/concat", depth=2))
    return m.collect(shard=0)


def vs_reference(library, top: int = 30, rounds: int = 8):
    """Single-core oracle (paper: 'we ran sdsorter and FRED on a single
    core ... and compared the results')."""
    feats, ids = library
    x = jnp.asarray(feats)
    for r in range(rounds):
        x = jnp.tanh(x @ _mix(FEATURES, r)) + 0.1 * x
    score = np.asarray(jnp.sum(x, axis=-1))
    order = np.argsort(-score)[:top]
    return score[order], ids[order]
