"""Interactive persisted-dataset benchmark: prefix-cached repeated queries.

The paper's interactive-processing claim (§Conclusions, Fig. 6) in
Spark terms: many aggregation queries over ONE cached dataset should pay
the shared pipeline prefix once.  This benchmark runs N repeated
``reduce_by_key`` queries (sum / max / min over k-mer keys) behind the
same expensive ``kmer-stats`` map prefix in two modes:

* **cold**    — no ``persist()``: every query recomputes the map prefix
  inside its own fused program (the pre-runtime behavior);
* **cached**  — ``persist()`` registers the map prefix's materialization
  under its lineage; every query's prefix lookup hits it and only the
  suffix (the keyed reduce) executes.

Invariants asserted in-script (CI policy: fail on a broken invariant,
never on wall-clock):

* both modes produce identical query results;
* after per-mode warmup, the measured reps compile ZERO programs in BOTH
  modes (``programs_compiled`` unchanged between cold and cached runs —
  the speedup is recompute avoidance, not compile avoidance);
* the cached mode's materialization cache records >= 1 hit per measured
  query and every cached-mode query report shows the full prefix served
  from cache; the cold mode records zero hits.

Wall-clock (cold vs prefix-cached per-query time, and the one-off
persist cost) is recorded in ``BENCH_interactive.json``, never asserted.

  PYTHONPATH=src python benchmarks/interactive.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

sys.path.insert(0, "src")

import jax                                           # noqa: E402

from repro import compat                             # noqa: E402
from repro.core import MaRe, PlanCache               # noqa: E402
from repro.runtime import (Executor,                 # noqa: E402
                           MaterializationCache)

READ_LEN = 64
QUERY_OPS = ("sum", "max", "min")


def make_reads(n_reads: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    bases = np.frombuffer(b"ACGT", np.uint8)
    data = bases[rng.integers(0, 4, size=(n_reads, READ_LEN))]
    lens = np.full((n_reads,), READ_LEN, np.int32)
    return {"data": data, "len": lens}


def _key_of(recs):
    # module-level keyBy/valueBy: compile cache AND lineage signatures key
    # keyed stages on callable identity, so fresh lambdas would defeat both
    return recs[0]


def _ones_of(recs):
    return (recs[1],)


def _normalize(result):
    keys, (vals,), counts = result
    order = np.argsort(np.asarray(keys))
    return (np.asarray(keys)[order].tolist(),
            np.asarray(vals)[order].tolist(),
            np.asarray(counts)[order].tolist())


def run_mode(ds, mesh, k: int, num_keys: int, persist_prefix: bool,
             reps: int) -> Dict:
    """One isolated engine per mode: fresh Executor + materialization
    cache + compile cache, same dataset and queries."""
    ex = Executor(mat_cache=MaterializationCache())
    cache = PlanCache()
    base = MaRe(ds, mesh=mesh, plan_cache=cache, executor=ex)

    r: Dict = {"persisted": persist_prefix}
    if persist_prefix:
        t0 = time.monotonic()
        base.map(image="kmer-stats", k=k).persist()
        r["persist_s"] = time.monotonic() - t0

    def query(op: str):
        return (base
                .map(image="kmer-stats", k=k)
                .reduce_by_key(_key_of, value_by=_ones_of, op=op,
                               num_keys=num_keys)
                .collect())

    # warmup: pays every compile this mode will ever need
    results = {op: _normalize(query(op)) for op in QUERY_OPS}
    r["warmup_programs_compiled"] = cache.stats()["misses"]

    before = cache.stats()
    times = []
    phase_tot: Dict[str, float] = {}
    for _ in range(reps):
        for op in QUERY_OPS:
            t0 = time.monotonic()
            query(op)
            times.append(time.monotonic() - t0)
            for p, s in ex.reports.latest.phases.items():
                phase_tot[p] = phase_tot.get(p, 0.0) + s

    after = cache.stats()

    r["results"] = results
    r["measured_queries"] = reps * len(QUERY_OPS)
    # per-measured-query phase means: in the cached mode the dispatch is
    # suffix-only, which is where the prefix speedup shows up
    r["query_phase_mean_s"] = {p: round(s / (reps * len(QUERY_OPS)), 6)
                               for p, s in phase_tot.items()}
    r["measured_programs_compiled"] = after["misses"] - before["misses"]
    r["query_mean_s"] = float(np.mean(times))
    r["query_min_s"] = float(np.min(times))
    mat = ex.mat_cache.stats()
    r["mat_cache"] = mat
    r["cache_hit_rate"] = mat["hits"] / max(1, mat["hits"] + mat["misses"])
    r["recompute_avoided_stages"] = sum(rep.cached_stages
                                        for rep in ex.reports)
    return r


def main() -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: tiny dataset, few reps")
    ap.add_argument("--out", default="BENCH_interactive.json")
    args = ap.parse_args()

    n_reads = 1_024 if args.small else 8_192
    k = 5 if args.small else 6
    reps = 2 if args.small else 10
    num_keys = 4 ** k

    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    reads = make_reads(n_reads)
    ds = MaRe(reads, mesh=mesh).dataset      # shard once, time queries

    cold = run_mode(ds, mesh, k, num_keys, persist_prefix=False, reps=reps)
    cached = run_mode(ds, mesh, k, num_keys, persist_prefix=True, reps=reps)

    # -- invariants ----------------------------------------------------------
    for op in QUERY_OPS:
        assert cold["results"][op] == cached["results"][op], \
            f"query {op!r}: cold and prefix-cached results differ"
    assert cold["measured_programs_compiled"] == 0, \
        "cold measured reps must not recompile"
    assert cached["measured_programs_compiled"] == 0, \
        "cached measured reps must not recompile"
    assert cold["measured_programs_compiled"] == \
        cached["measured_programs_compiled"], \
        "programs_compiled must be unchanged between cold and cached runs"
    assert cold["mat_cache"]["hits"] == 0, \
        "cold mode must never hit the materialization cache"
    assert cached["mat_cache"]["hits"] >= cached["measured_queries"], \
        "every measured cached query must hit the materialization cache"
    assert cached["recompute_avoided_stages"] >= \
        cached["measured_queries"], \
        "every measured cached query must skip the persisted prefix"

    for mode in (cold, cached):
        mode.pop("results")                 # bulky; invariants checked above

    out = {
        "bench": "interactive",
        "devices": jax.device_count(),
        "n_reads": n_reads,
        "read_len": READ_LEN,
        "k": k,
        "num_keys": num_keys,
        "queries": len(QUERY_OPS),
        "reps": reps,
        "cold": cold,
        "cached": cached,
        # min-over-reps: noise-robust steady state on shared machines
        "prefix_speedup": cold["query_min_s"] / cached["query_min_s"],
        "cache_hit_rate": cached["cache_hit_rate"],
        "recompute_avoided_stages": cached["recompute_avoided_stages"],
    }
    for name, r in (("cold", cold), ("cached", cached)):
        print(f"interactive,{name},"
              f"warmup_compiles={r['warmup_programs_compiled']},"
              f"measured_compiles={r['measured_programs_compiled']},"
              f"query_min={r['query_min_s'] * 1e3:.1f}ms,"
              f"hit_rate={r['cache_hit_rate']:.2f}")
    print(f"interactive,prefix_speedup={out['prefix_speedup']:.2f}x,"
          f"recompute_avoided_stages={out['recompute_avoided_stages']}")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
