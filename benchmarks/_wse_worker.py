"""Subprocess worker for WSE benchmarks: runs one (app, n_workers, scale)
cell on n_workers simulated devices and prints a JSON result line.

Emits BOTH:
  * measured wall time (honest caveat: this container has ONE physICAL
    core, so compute-bound scaling cannot manifest in wall time), and
  * structural roofline terms from the lowered per-device HLO with TPU
    v5e constants — the target-hardware WSE model (DESIGN.md §6).
"""
import argparse
import json
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--app", required=True)        # vs | snp
ap.add_argument("--workers", type=int, required=True)
ap.add_argument("--records-per-worker", type=int, default=4096)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.workers}")

import jax                                     # noqa: E402
import jax.numpy as jnp                        # noqa: E402
import numpy as np                             # noqa: E402
from jax.sharding import PartitionSpec as P    # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro import compat                       # noqa: E402

from benchmarks.apps import (_register_once, make_library,     # noqa: E402
                             snp_calling, virtual_screening)
from repro.launch.hlo_cost import analyze                      # noqa: E402
from repro.launch.dryrun_lib import (PEAK_FLOPS, HBM_BW,       # noqa: E402
                                     ICI_BW)

n = args.workers
total = args.records_per_worker * n
lib = make_library(total, seed=args.seed)

t0 = time.monotonic()
if args.app == "vs":
    out = virtual_screening(lib)
else:
    out = snp_calling(lib, rounds=64)   # GATK-like compute weight
jax.block_until_ready(jax.tree.leaves(out))
wall = time.monotonic() - t0

# structural terms: lower the same pipeline's fused stage and analyze
from repro.core import MaRe, from_host                          # noqa
from repro.core.plan import Plan                                # noqa

_register_once()
mesh = compat.make_mesh((n,), ("data",))
ds = from_host(lib, mesh)

if args.app == "vs":
    m = (MaRe(ds).map(image="tools/fred")
         .reduce(image="toolbox/topk", k=30, depth=2))
    text = None
    # reduce() executed eagerly; re-lower the equivalent stage for terms
    from repro.core.container import pull
    from repro.core.tree_reduce import tree_reduce_partition
    from repro.core.plan import _apply_chain
    fred = pull("tools/fred")
    topk = pull("toolbox/topk", k=30)

    def stage(records, counts):
        part = _apply_chain((fred,), records, counts[0])
        part = tree_reduce_partition(part, topk, "data", n, depth=2)
        return part.records, part.count[None]

    low = jax.jit(compat.shard_map(stage, mesh=mesh,
                                in_specs=(P("data"), P("data")),
                                out_specs=(P("data"), P("data")))
                  ).lower(ds.records, ds.counts)
else:
    from repro.core.container import pull
    from repro.core.plan import _apply_chain
    from repro.core.shuffle import shuffle_partition
    from repro.core.tree_reduce import tree_reduce_partition
    # compute-calibrated surrogate: real BWA/GATK spend hours per
    # shard; rounds=64 gives a compute:shuffle ratio in that regime
    bwa = pull("tools/bwa", rounds=64)
    gatk = pull("tools/gatk")
    concat = pull("toolbox/concat")

    def stage(records, counts):
        part = _apply_chain((bwa,), records, counts[0])
        # balanced shuffle capacity (2x headroom), as Spark sizes shuffle
        # blocks by expected not worst-case volume; overflow is counted
        cap_bal = max(1, 2 * part.capacity // n)
        res = shuffle_partition(part, part.records[0], "data", n,
                                capacity=cap_bal)
        part = _apply_chain((gatk,), res.part.records, res.part.count)
        part = tree_reduce_partition(part, concat, "data", n, depth=2)
        return part.records, part.count[None]

    low = jax.jit(compat.shard_map(stage, mesh=mesh,
                                in_specs=(P("data"), P("data")),
                                out_specs=(P("data"), P("data")))
                  ).lower(ds.records, ds.counts)

comp = low.compile()
walk = analyze(comp.as_text())
terms = {
    "compute_s": walk["flops"] / PEAK_FLOPS,
    "memory_s": walk["bytes"] / HBM_BW,
    "collective_s": walk["wire_bytes"] / ICI_BW,
}
print(json.dumps({"app": args.app, "workers": n, "records": total,
                  "wall_s": wall, **terms,
                  "model_s": max(terms["compute_s"], terms["memory_s"],
                                 terms["collective_s"])}))
