"""Whole-pipeline fusion benchmark: fused vs stage-at-a-time execution.

A 3-stage GC-content pipeline (per-read GC count -> repartitionBy
chromosome -> sum reduce) runs two ways over the same 8-device CPU mesh:

* **fused** — the lazy planner lowers the whole chain into ONE jitted
  ``shard_map`` program (overflow counters returned as program outputs,
  single host sync);
* **eager** — stage-at-a-time (``fuse=False``): each stage compiles and
  dispatches its own program with intermediate materialization, the
  pre-planner schedule.

Compiles are counted via per-mode :class:`PlanCache` instances (one cache
miss == one trace+compile); wall-clock is reported cold (first run,
includes compile) and warm (steady state).  A second, freshly built but
identical pipeline shows the compile cache absorbing interactive
re-execution (paper Fig. 6).  Results land in ``BENCH_pipeline.json``.

  PYTHONPATH=src python benchmarks/pipeline.py [--small]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, Optional

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import numpy as np

sys.path.insert(0, "src")

import jax                                           # noqa: E402
import jax.numpy as jnp                              # noqa: E402

from repro import compat                             # noqa: E402
from repro.core import (ImageManifest, MaRe, PlanCache,  # noqa: E402
                        Schema, field)
from repro.core.container import (DEFAULT_REGISTRY, Partition,  # noqa: E402
                                  container_op, make_partition)

N_CHROMOSOMES = 24
READ_LEN = 64


def _register_once():
    if "bench/gc-per-read:latest" in DEFAULT_REGISTRY.images():
        return

    manifest = ImageManifest(
        input_schema=Schema((field(np.int32, ("R",)), field(np.int32))),
        output_schema=Schema((field(np.int32), field(np.int32))))

    @container_op("bench/gc-per-read", registry=DEFAULT_REGISTRY,
                  manifest=manifest)
    def gc_per_read(part: Partition, **kw) -> Partition:
        """Per-read GC count + chromosome id (the per-record map stage)."""
        reads, read_id = part.records
        gc = jnp.sum((reads == 2) | (reads == 3), axis=-1).astype(jnp.int32)
        chrom = (read_id % N_CHROMOSOMES).astype(jnp.int32)
        return make_partition((gc, chrom), part.count)


def make_reads(n_reads: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reads = rng.integers(0, 4, size=(n_reads, READ_LEN)).astype(np.int32)
    ids = np.arange(n_reads, dtype=np.int32)
    return reads, ids


def _key_chrom(recs):
    # module-level keyBy: the compile cache keys shuffle stages on the
    # callable's identity, so a fresh lambda per run would defeat it
    return recs[1]


def build_pipeline(ds, mesh, cache: PlanCache, fuse: bool) -> MaRe:
    """map(gc-per-read) -> repartitionBy(chromosome) -> reduce(sum).

    ``ds`` is an already-sharded dataset (host->device placement is paid
    once, outside the timed loop, as in interactive re-execution).
    """
    return (MaRe(ds, mesh=mesh, plan_cache=cache, fuse=fuse)
            .map(image="bench/gc-per-read")
            .repartition_by(_key_chrom)
            .reduce(image="toolbox/sum"))


def run_cold(ds, mesh, expected_gc: int, fuse: bool) -> Dict:
    cache = PlanCache()
    t0 = time.monotonic()
    (gc_sum, _) = build_pipeline(ds, mesh, cache, fuse)\
        .collect(shard=0)
    cold = time.monotonic() - t0
    assert int(gc_sum[0]) == expected_gc, (int(gc_sum[0]), expected_gc)
    return {"compiles": cache.stats()["misses"], "cold_s": cold,
            "cache": cache}


def run_warm(ds, mesh, expected_gc: int, modes: Dict[str, Dict],
             reps: int) -> None:
    """Interleave warm reps across modes so scheduler noise and thermal
    drift hit both schedules equally (block ordering was measurably
    biased on shared machines)."""
    times = {name: [] for name in modes}
    for _ in range(reps):
        for name, r in modes.items():
            t0 = time.monotonic()
            (gc_sum, _) = build_pipeline(
                ds, mesh, r["cache"], fuse=(name == "fused"))\
                .collect(shard=0)
            times[name].append(time.monotonic() - t0)
            assert int(gc_sum[0]) == expected_gc
    for name, r in modes.items():
        r["warm_mean_s"] = float(np.mean(times[name]))
        r["warm_min_s"] = float(np.min(times[name]))
        r["recompiles_on_rerun"] = (r["cache"].stats()["misses"]
                                    - r["compiles"])
        r["cache"] = r.pop("cache").stats()


def manifest_guard(ds, mesh, small: bool,
                   baseline: Optional[Dict]) -> Dict:
    """Assert manifest/schema checking is plan-time only.

    Building a pipeline now runs full schema inference (manifests, mount
    contracts, capacity transfer).  That work must (a) never trigger a
    compile, and (b) leave compile counts — and, where comparable, warm
    wall-clock — unchanged vs. the pre-manifest baseline recorded in
    BENCH_pipeline.json.
    """
    cache = PlanCache()
    builds = 64 if small else 256
    t0 = time.monotonic()
    for _ in range(builds):
        m = build_pipeline(ds, mesh, cache, fuse=True)
    build_us = (time.monotonic() - t0) / builds * 1e6
    desc = m.describe()
    assert "(i32, i32)" in desc, \
        f"schema inference did not run at build time: {desc}"
    assert cache.stats() == {"programs": 0, "hits": 0, "misses": 0}, \
        f"plan building must not compile/execute: {cache.stats()}"
    guard = {"plan_builds": builds,
             "plan_build_us": build_us,
             "plan_build_compiles": cache.stats()["misses"]}
    if baseline is not None:
        for mode, want in (("fused", 1), ("eager", 3)):
            base = baseline.get(mode, {}).get("compiles")
            if base is not None:
                assert base == want, \
                    f"baseline {mode} compiles changed: {base} != {want}"
        guard["baseline_compiles"] = {
            m: baseline.get(m, {}).get("compiles") for m in
            ("fused", "eager")}
    return guard


def main() -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke mode: tiny dataset, few reps")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()

    n_reads = 2_048 if args.small else 65_536
    reps = 3 if args.small else 20

    baseline: Optional[Dict] = None
    if os.path.exists(args.out):
        with open(args.out) as f:
            baseline = json.load(f)

    _register_once()
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    data = make_reads(n_reads)
    expected_gc = int(np.sum((data[0] == 2) | (data[0] == 3)))

    ds = MaRe(data, mesh=mesh).dataset        # shard once, time pipelines

    # warm-up on a differently-shaped tiny pipeline: absorbs one-time JAX
    # dispatch/mesh initialization so neither timed mode pays it
    warm_data = make_reads(max(256, n_reads // 64), seed=1)
    warm_ds = MaRe(warm_data, mesh=mesh).dataset
    run_cold(warm_ds, mesh,
             int(np.sum((warm_data[0] == 2) | (warm_data[0] == 3))),
             fuse=True)

    fused = run_cold(ds, mesh, expected_gc, fuse=True)
    eager = run_cold(ds, mesh, expected_gc, fuse=False)
    run_warm(ds, mesh, expected_gc, {"fused": fused, "eager": eager},
             reps)
    guard = manifest_guard(ds, mesh, args.small, baseline)

    out = {
        "bench": "pipeline",
        "devices": jax.device_count(),
        "n_reads": n_reads,
        "read_len": READ_LEN,
        "stages": 3,
        "reps": reps,
        "fused": fused,
        "eager": eager,
        # min-over-reps is the noise-robust steady-state estimate on a
        # shared machine; mean is also recorded per mode above
        "warm_speedup": eager["warm_min_s"] / fused["warm_min_s"],
        "cold_speedup": eager["cold_s"] / fused["cold_s"],
        "manifest_guard": guard,
    }
    # warm-path regression check vs. the pre-manifest baseline: the
    # ORIGINAL pre-manifest warm time (plus the shape/device context it
    # was measured under) is pinned in the guard block and propagated
    # verbatim through EVERY regeneration — including --small runs that
    # can't use it — so the guard stays an absolute reference, not a
    # run-over-run ratchet that would re-baseline a slow drift.
    pin = None
    if baseline is not None:
        mg = baseline.get("manifest_guard", {})
        if mg.get("baseline_warm_min_s"):
            pin = {k: mg[k] for k in ("baseline_warm_min_s",
                                      "baseline_n_reads",
                                      "baseline_devices") if k in mg}
        elif (not args.small and baseline.get("n_reads") == n_reads
                and baseline.get("devices") == jax.device_count()):
            pin = {"baseline_warm_min_s": baseline["fused"]["warm_min_s"],
                   "baseline_n_reads": baseline["n_reads"],
                   "baseline_devices": baseline["devices"]}
    if pin is not None:
        guard.update(pin)
    # compare only when this run matches the pinned measurement context
    # (full mode, same shapes/devices) — generous tolerance, shared
    # machines are noisy
    if (pin is not None and not args.small
            and pin.get("baseline_n_reads") == n_reads
            and pin.get("baseline_devices") == jax.device_count()):
        base_warm = pin["baseline_warm_min_s"]
        ratio = fused["warm_min_s"] / base_warm
        guard["warm_vs_baseline"] = ratio
        assert ratio < 2.0, \
            f"warm path regressed {ratio:.2f}x vs pre-manifest baseline " \
            f"({fused['warm_min_s']:.4f}s vs {base_warm:.4f}s)"
    for mode in ("fused", "eager"):
        r = out[mode]
        print(f"pipeline,{mode},compiles={r['compiles']},"
              f"cold={r['cold_s']:.3f}s,warm_min={r['warm_min_s']*1e3:.1f}"
              f"ms,rerun_recompiles={r['recompiles_on_rerun']}")
    print(f"pipeline,warm_speedup={out['warm_speedup']:.2f}x,"
          f"cold_speedup={out['cold_speedup']:.2f}x")

    assert fused["compiles"] == 1, \
        f"fused pipeline must compile exactly 1 program, got " \
        f"{fused['compiles']}"
    assert eager["compiles"] >= 3, \
        f"stage-at-a-time must compile >= 3 programs, got " \
        f"{eager['compiles']}"
    assert fused["recompiles_on_rerun"] == 0, "re-run must hit the cache"
    print(f"pipeline,manifest_guard,plan_build="
          f"{guard['plan_build_us']:.0f}us,compiles="
          f"{guard['plan_build_compiles']}")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
