"""Reduce-tree depth K trade-off (paper §1.2.2 'the user may chose a
higher tree depth').

For the MaRe gradient tree: wire bytes per K from (a) the analytic model
(collective_bytes_tree) and (b) the lowered HLO of tree_allreduce at 8
shards, plus the fused psum reference."""
from __future__ import annotations

import json
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)

WORKER = r'''
import json
import os
import sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P
sys.path.insert(0, "src")
from repro.core.tree_reduce import tree_allreduce, fused_allreduce, collective_bytes_tree
from repro.launch.hlo_cost import analyze
mesh = compat.make_mesh((8,), ("data",))
x = jax.ShapeDtypeStruct((1<<20,), jnp.float32)   # 4 MiB gradient
rows = []
for depth in (1, 2, 3):
    low = jax.jit(compat.shard_map(lambda g: tree_allreduce(g, "data", 8, depth=depth),
                  mesh=mesh, in_specs=P(), out_specs=P(),
                  check_vma=False)).lower(x)
    w = analyze(low.compile().as_text())
    rows.append({"k": depth, "wire": w["wire_bytes"],
                 "analytic": collective_bytes_tree(x.size*4, 8, depth)})
low = jax.jit(compat.shard_map(lambda g: fused_allreduce(g, "data"),
              mesh=mesh, in_specs=P(), out_specs=P(),
                  check_vma=False)).lower(x)
w = analyze(low.compile().as_text())
rows.append({"k": "fused_psum", "wire": w["wire_bytes"], "analytic": None})
print(json.dumps(rows))
'''


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(HERE, "..", "src"))
    out = subprocess.run([sys.executable, "-c", WORKER], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.join(HERE, ".."))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    for r in rows:
        print(f"reduce_depth,K={r['k']},wire_bytes={r['wire']:.3e},"
              f"analytic={r['analytic']}")
    return rows


if __name__ == "__main__":
    main()
