"""WSE benchmarks for the paper's two applications (Figs. 3 and 4).

WSE(N) = T(base workload, 1 worker) / T(N x workload, N workers); ideal 1.
Reported on the structural (target-TPU) time model; measured wall time on
this 1-core container is printed alongside with its caveat.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

HERE = os.path.dirname(__file__)


def run_cell(app: str, workers: int, records_per_worker: int = 2048
             ) -> Dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(os.path.join(HERE, "..", "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "_wse_worker.py"),
         "--app", app, "--workers", str(workers),
         "--records-per-worker", str(records_per_worker)],
        env=env, capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"worker failed: {out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def wse_curve(app: str, worker_counts=(1, 2, 4, 8),
              records_per_worker: int = 2048) -> List[Dict]:
    rows = []
    base = None
    for n in worker_counts:
        cell = run_cell(app, n, records_per_worker)
        if base is None:
            base = cell
        cell["wse_model"] = base["model_s"] / max(cell["model_s"], 1e-12)
        cell["wse_wall"] = base["wall_s"] / max(cell["wall_s"], 1e-12)
        rows.append(cell)
    return rows


def main(app: str):
    rows = wse_curve(app)
    for r in rows:
        print(f"{app}_wse,workers={r['workers']},"
              f"model_s={r['model_s']:.4e},wse_model={r['wse_model']:.3f},"
              f"wall_s={r['wall_s']:.2f},wse_wall={r['wse_wall']:.3f}")
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "vs")
