"""Kernel micro-benchmarks: VMEM working sets + analytic FLOPs per block
(TPU design points), plus CPU wall time of the pure-jnp reference path
(interpret-mode timings are not meaningful — kernels target TPU)."""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")
from repro.kernels import (attention_ref, dispatch_ref, rmsnorm_ref,  # noqa
                           topk_ref)


def timeit(f, *args, n=5):
    f(*args)  # compile
    t0 = time.monotonic()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.monotonic() - t0) / n * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []
    # flash attention design point: bq=bk=128, d=128
    bq = bk = d = 128
    vmem = (bq * d + 2 * bk * d + bq * d) * 4 + bq * 8
    flops_blk = 2 * bq * bk * d * 2
    print(f"flash_attention,block=128x128x128,vmem_bytes={vmem},"
          f"flops/block={flops_blk},arith_intensity="
          f"{flops_blk / (2 * bk * d * 2):.0f}")
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 128)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 128)), jnp.bfloat16)
    us = timeit(jax.jit(lambda q, k, v: attention_ref(q, k, v)), q, k, k)
    print(f"attention_ref_cpu,1x8x1024x128,us_per_call={us:.0f},ref-path")
    # rmsnorm
    x = jnp.asarray(rng.normal(size=(4096, 1024)), jnp.bfloat16)
    w = jnp.ones((1024,), jnp.bfloat16)
    us = timeit(jax.jit(lambda x, w: rmsnorm_ref(x, w)), x, w)
    print(f"rmsnorm_ref_cpu,4096x1024,us_per_call={us:.0f},"
          f"bytes={x.size*2*2}")
    # topk streaming: block merge cost model
    print("topk_reduce,block=1024,k=30,merge_flops_per_block="
          f"{30 * (1024 + 30)},vmem_bytes={(1024 + 60) * 4}")
    s = jnp.asarray(rng.normal(size=(1 << 16,)), jnp.float32)
    us = timeit(jax.jit(lambda s: topk_ref(s, 30)), s)
    print(f"topk_ref_cpu,65536,us_per_call={us:.0f},ref-path")
    # fused selective scan: per-chunk VMEM working set
    chunk, d, n = 128, 1600, 16
    vm = (2 * chunk * d * n + d * n + chunk * d) * 4
    print(f"ssm_scan,chunk={chunk}x{d}x{n},vmem_bytes={vm},"
          f"hbm_bytes_per_chunk={2 * chunk * d * 4} (vs xla fallback "
          f"{2 * chunk * d * n * 4}*log2(T))")
    # dispatch
    a = jnp.asarray(rng.integers(0, 64, size=1 << 14), jnp.int32)
    us = timeit(jax.jit(lambda a: dispatch_ref(a, 64)), a)
    print(f"moe_dispatch_ref_cpu,16384x64,us_per_call={us:.0f},ref-path")
    return rows


if __name__ == "__main__":
    main()
