"""Version-tolerant JAX API surface.

The repo targets the modern spellings (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``check_vma=``) but must also run on 0.4.x installations where
``shard_map`` still lives in ``jax.experimental``, meshes take no
``axis_types``, and the replication-check kwarg is ``check_rep``.
All mesh/shard_map construction in this repo goes through here.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax

AxisType = getattr(jax.sharding, "AxisType", None)

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = set(inspect.signature(_shard_map_impl).parameters)
_MAKE_MESH_PARAMS = (set(inspect.signature(jax.make_mesh).parameters)
                     if hasattr(jax, "make_mesh") else None)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              devices: Optional[Sequence[Any]] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported; builds the
    Mesh directly on JAX versions predating ``jax.make_mesh``."""
    shape = tuple(axis_shapes)
    names = tuple(axis_names)
    if _MAKE_MESH_PARAMS is None:
        import numpy as np
        n = int(np.prod(shape))
        devs = list(devices) if devices is not None else jax.devices()[:n]
        return jax.sharding.Mesh(np.asarray(devs).reshape(shape), names)
    kwargs: dict = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AxisType is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = (AxisType.Auto,) * len(names)
    return jax.make_mesh(shape, names, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg translated to
    whatever this installation calls it (``check_vma`` vs ``check_rep``)."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict (older JAX
    returns a one-element list of per-computation dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


__all__ = ["AxisType", "make_mesh", "shard_map", "cost_analysis"]
