"""InternVL2-1B [arXiv:2404.16821; hf] — InternViT frontend + Qwen2-0.5B LM.

LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab 151655.
The InternViT vision tower is a STUB per the assignment: input_specs
provides 256 pre-computed patch embeddings which are linearly projected
and prepended to the text tokens."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151655, num_patches=256,
    rope_theta=1000000.0, dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=2, d_model=56, num_heads=2,
                         num_kv_heads=1, head_dim=28, d_ff=112,
                         vocab_size=256, num_patches=4, dtype="float32",
                         remat=False, attn_impl="ref")
