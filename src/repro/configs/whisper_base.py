"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec, audio family.

6L encoder + 6L decoder, d_model=512 8H (MHA) d_ff=2048 vocab 51865.
The conv/log-mel frontend is a STUB: input_specs provides 1500 frame
embeddings.  Deviation: sinusoidal decoder positions instead of whisper's
learned 448-entry table (required to lower the assigned 32k decode cells).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, encoder_seq=1500,
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    use_layernorm=True, use_gelu=True, tie_embeddings=True,
    dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=2, encoder_layers=2, encoder_seq=16,
                         d_model=64, num_heads=4, num_kv_heads=4,
                         head_dim=16, d_ff=128, vocab_size=256,
                         dtype="float32", remat=False, attn_impl="ref")
