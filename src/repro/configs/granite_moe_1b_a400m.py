"""IBM Granite 3.0 1b-a400m base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) MoE 32 experts top-8, per-expert
d_ff=512, vocab 49155."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=64, d_ff=0, moe_d_ff=512, num_experts=32,
    experts_per_token=8, vocab_size=49155,
    rope_theta=10000.0, dtype="bfloat16", capacity_factor=1.25)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=2, head_dim=16, moe_d_ff=32,
                         num_experts=4, experts_per_token=2,
                         vocab_size=256, dtype="float32", remat=False,
                         attn_impl="ref")
