"""xLSTM-1.3B [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

48L d_model=2048 4H, attention-free (d_ff=0), vocab 50304.
7:1 mLSTM:sLSTM pattern (slstm_every=8) as in the paper's xLSTM[7:1];
blocks carry matrix/scalar memories -> O(1) decode state, so this arch
runs the long_500k cell."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, slstm_every=8,
    dtype="bfloat16", ssm_chunk=256)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=4, d_model=64, num_heads=2,
                         num_kv_heads=2, slstm_every=2, ssm_chunk=8,
                         vocab_size=256, dtype="float32", remat=False)
