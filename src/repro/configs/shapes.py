"""The four assigned input-shape cells (LM-family shape set)."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "long_decode", 524_288, 1)

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                   LONG_500K)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in SHAPES]}")
