"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid parallel attn+SSM heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16 vocab 32001.
Sliding-window attention (1024) everywhere except 3 global layers
(first / middle / last, per the Hymba paper) — this is what makes the
long_500k decode cell feasible: only 3 layers keep a full-length KV cache.
Meta-tokens are omitted (assignment spec lists none).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_expand=1, window=1024, global_layers=(0, 15, 31),
    ssm_chunk=128,
    rope_theta=10000.0, dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=3, d_model=80, num_heads=5,
                         num_kv_heads=1, head_dim=16, d_ff=160,
                         ssm_state=8, window=8, global_layers=(1,),
                         ssm_chunk=8, vocab_size=256, dtype="float32",
                         remat=False, attn_impl="ref")
