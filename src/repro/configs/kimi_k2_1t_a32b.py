"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8, head_dim=112) MoE 384 experts top-8 with
per-expert d_ff=2048, vocab 163840.  Assignment config exactly; K2's MLA
attention and shared expert are simplified to GQA / no-shared per the
assigned spec (noted in DESIGN.md §5).  ~1.03T total / ~32B active params.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    head_dim=112, d_ff=0, moe_d_ff=2048, num_experts=384,
    experts_per_token=8, vocab_size=163840,
    rope_theta=50000.0, dtype="bfloat16", capacity_factor=1.25)


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=2, head_dim=16, moe_d_ff=32,
                         num_experts=8, experts_per_token=2,
                         vocab_size=256, dtype="float32", remat=False,
                         attn_impl="ref")
