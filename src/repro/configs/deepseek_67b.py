"""DeepSeek 67B [arXiv:2401.02954; hf] — llama-arch.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab 102400."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=22016, vocab_size=102400,
    rope_theta=10000.0, dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=3, d_model=64, num_heads=4,
                         num_kv_heads=2, head_dim=16, d_ff=160,
                         vocab_size=256, dtype="float32", remat=False,
                         attn_impl="ref")
