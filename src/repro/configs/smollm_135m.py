"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — small llama-arch.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab 49152."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    head_dim=64, d_ff=1536, vocab_size=49152,
    rope_theta=10000.0, dtype="bfloat16")


def smoke_config() -> ModelConfig:
    return CONFIG.scaled(num_layers=2, d_model=72, num_heads=3,
                         num_kv_heads=1, head_dim=24, d_ff=144,
                         vocab_size=256, dtype="float32", remat=False,
                         attn_impl="ref")
