"""Config registry: the 10 assigned architectures x 4 shape cells."""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

from repro.configs.shapes import (DECODE_32K, LONG_500K, PREFILL_32K,
                                  SHAPES, TRAIN_4K, ShapeConfig, get_shape)
from repro.models.common import ModelConfig

_ARCH_MODULES: Dict[str, str] = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "deepseek-67b": "deepseek_67b",
    "smollm-135m": "smollm_135m",
    "llama3.2-1b": "llama3_2_1b",
    "whisper-base": "whisper_base",
    "hymba-1.5b": "hymba_1_5b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig
                      ) -> Optional[str]:
    """Assignment skip rules; None = the cell runs."""
    if shape.kind == "long_decode" and not cfg.is_subquadratic:
        return ("pure full-attention stack: 524k dense-KV decode is "
                "outside the assigned regime (DESIGN.md §5)")
    return None


def cells(include_skipped: bool = False
          ) -> List[Tuple[str, str, Optional[str]]]:
    """All (arch, shape, skip_reason) cells."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            reason = shape_skip_reason(cfg, shape)
            if reason is None or include_skipped:
                out.append((arch, shape.name, reason))
    return out


__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "get_shape",
           "cells", "shape_skip_reason", "SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "ShapeConfig"]
