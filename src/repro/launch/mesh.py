"""Production meshes (assignment spec).

Importing this module never touches jax device state — meshes are built
inside functions only."""
from __future__ import annotations


import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model
    for the 2-pod = 512-chip configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this)")
    return compat.make_mesh(shape, axes, devices=devices[:n])


def make_mesh(shape, axes, devices=None) -> Mesh:
    """Generic helper for tests/benchmarks."""
    return compat.make_mesh(tuple(shape), tuple(axes), devices=devices)
