import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod        # 512 chips
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k \
      --arch kimi-k2-1t-a32b --save-hlo reports/hlo/kimi_train.txt

Results append to reports/dryrun.jsonl (one JSON per cell).
"""
import argparse
import json
import sys
import time
import traceback


from repro.configs import ARCH_IDS, get_config, get_shape, shape_skip_reason
from repro.launch.dryrun_lib import dry_run_cell
from repro.launch.mesh import make_production_mesh
from repro.train.step import StepConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-sync", default="fused")
    ap.add_argument("--moe-mode", default="weight_gather")
    ap.add_argument("--out", default="reports/dryrun.jsonl")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--no-collectives", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)} ({mesh.size} chips)", flush=True)

    archs = args.arch or list(ARCH_IDS)
    shapes = args.shape or ["train_4k", "prefill_32k", "decode_32k",
                            "long_500k"]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            shape = get_shape(shape_name)
            reason = shape_skip_reason(cfg, shape)
            if reason:
                print(f"SKIP {arch} x {shape_name}: {reason}", flush=True)
                continue
            print(f"RUN  {arch} x {shape_name} ...", flush=True)
            t0 = time.monotonic()
            try:
                res = dry_run_cell(
                    cfg, shape, mesh,
                    extract_collectives=not args.no_collectives,
                    step_cfg=StepConfig(grad_sync=args.grad_sync,
                                        moe_mode=args.moe_mode),
                    save_hlo=args.save_hlo)
                res["multi_pod"] = args.multi_pod
                res["tag"] = args.tag
                res["grad_sync"] = args.grad_sync
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
                print(f"  ok in {time.monotonic()-t0:.1f}s  "
                      f"flops/dev={res['flops']:.3e} "
                      f"bytes/dev={res['bytes']:.3e} "
                      f"coll_wire={res['collectives'].get('wire_bytes', 0):.3e}"
                      if res['collectives'] else "  ok", flush=True)
                mem = res.get("memory", {})
                if mem.get("peak_bytes"):
                    print(f"  mem/dev: args={mem['argument_bytes']:.3e} "
                          f"temp={mem['temp_bytes']:.3e} "
                          f"peak={mem['peak_bytes']:.3e}", flush=True)
            except Exception as e:
                failures.append((arch, shape_name, repr(e)))
                print(f"  FAIL {arch} x {shape_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nALL CELLS COMPILED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
