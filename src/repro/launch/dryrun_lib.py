"""Dry-run library: lower + compile every (arch x shape x mesh) cell and
extract the three roofline terms from the compiled artifact.

Terms (TPU v5e targets, per chip):
  compute    = HLO_FLOPs(per-device) / 197e12 FLOP/s (bf16)
  memory     = HLO_bytes(per-device) / 819e9 B/s (HBM)
  collective = weighted collective bytes(per-device) / 50e9 B/s (ICI link)

``cost_analysis`` supplies FLOPs/bytes of the post-SPMD per-device module;
collective bytes are parsed from ``compiled.as_text()`` with standard
per-op wire-cost factors (ring algorithms):
  all-reduce 2(n-1)/n, all-gather/reduce-scatter/all-to-all (n-1)/n,
  collective-permute 1.0 — n = largest mesh axis (conservative).
"""
from __future__ import annotations

import re
import time
from typing import Any, Dict, Optional

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeConfig
from repro.models import build_model
from repro.models.common import ModelConfig, param_count_analytic
from repro.optim import adafactor, adamw
from repro.optim.schedule import cosine_warmup
from repro.sharding import Rules, make_rules, use_rules
from repro.train.step import StepConfig, TrainState, make_train_step

# v5e hardware model
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                "reduce-scatter": 1.0, "all-to-all": 1.0,
                "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


# ---------------------------------------------------------------------------
# Rules / sharding selection per (arch x shape)
# ---------------------------------------------------------------------------

def rules_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Rules:
    # Sequence-sharding breaks seq-chunked recurrences; SSM/hybrid keep
    # seq local and instead spread BATCH over the whole mesh when it
    # divides (16x fewer tokens/device than DP-only — §Perf hymba-1).
    seq_shard = cfg.family not in ("ssm", "hybrid")
    if cfg.family == "hybrid" and cfg.ssm_cp and shape.kind == "prefill":
        seq_shard = True          # context-parallel SSM (§Perf hymba-3)
    if shape.is_decode:
        seq_shard = False
    rules = make_rules(mesh, fsdp=True, seq_shard=seq_shard)
    if cfg.family in ("ssm", "hybrid") and not shape.is_decode:
        axes_all = tuple(a for a in ("pod", "data", "model")
                         if a in mesh.shape)
        size_all = 1
        for a in axes_all:
            size_all *= int(mesh.shape[a])
        if shape.global_batch % size_all == 0:
            table = dict(rules.table)
            table["batch"] = axes_all
            if cfg.family == "ssm":
                # xLSTM: 4 heads never shard over model=16, but head_dim
                # (512) does — TP the mLSTM head_dim so grads stop being
                # replicated-over-model (§Perf xlstm-1)
                table["hd"] = "model"
            rules = Rules(table=table, mesh_shape=rules.mesh_shape)
    return rules


def choose_optimizer(cfg: ModelConfig):
    """Adafactor above 10B params (factored 2nd moments — the 1T memory
    budget), AdamW below."""
    if param_count_analytic(cfg) > 10e9:
        return adafactor(), "adafactor"
    return adamw(), "adamw"


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch stand-ins (no allocation).  For train: tokens+labels; audio
    adds stub frame embeddings; vlm adds stub patch embeddings (text len
    shrinks so total positions == shape.seq_len)."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.param_dtype
    i32 = jnp.int32
    s_text = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
    else:   # decode / long_decode: one new token (cache specs built apart)
        specs["tokens"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.family == "audio" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), dt)
    if shape.kind == "train" and cfg.family == "vlm":
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    return specs


def batch_shardings(specs: Dict[str, Any], mesh: Mesh, rules: Rules
                    ) -> Dict[str, Any]:
    out = {}
    for k, v in specs.items():
        if v.ndim == 2 and k in ("tokens", "labels"):
            spec = rules.spec_for(("batch", "seq"), dims=v.shape)
        elif v.ndim == 3:
            spec = rules.spec_for(("batch", "seq", None), dims=v.shape)
        elif v.ndim == 1:
            spec = rules.spec_for(("batch",), dims=v.shape)
        else:
            spec = P()
        out[k] = NamedSharding(mesh, spec)
    return out


def _cache_sharding(leaf, cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: Rules) -> NamedSharding:
    """Heuristic cache shardings: batch dim -> data axes, cache-seq dim ->
    model axis (context-sharded KV for long decode)."""
    dims = list(leaf.shape)
    B = shape.global_batch
    logical = [None] * len(dims)
    for i, d in enumerate(dims):
        if d == B and "batch" not in logical:
            logical[i] = "batch"
        elif d >= 1024 and d >= shape.seq_len // 2:
            logical[i] = "kv_seq"
    return NamedSharding(mesh, rules.spec_for(logical, dims=dims))


# ---------------------------------------------------------------------------
# Collective-byte extraction
# ---------------------------------------------------------------------------

def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, ring_n: int = 16) -> Dict[str, Any]:
    """Sum result bytes of every collective op in the per-device module,
    with ring wire-cost factors applied."""
    per_op: Dict[str, int] = {k: 0 for k in _COLL_FACTOR}
    counts: Dict[str, int] = {k: 0 for k in _COLL_FACTOR}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)", ls)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_txt)
        per_op[op] += b
        counts[op] += 1
    factor = {k: _COLL_FACTOR[k] * (ring_n - 1) / ring_n
              if k != "collective-permute" else 1.0 for k in _COLL_FACTOR}
    wire = {k: per_op[k] * factor[k] for k in per_op}
    return {"bytes_by_op": per_op, "counts": counts,
            "wire_bytes": sum(wire.values())}


# ---------------------------------------------------------------------------
# Cell dry-run
# ---------------------------------------------------------------------------

def dry_run_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 extract_collectives: bool = True,
                 step_cfg: Optional[StepConfig] = None,
                 donate: bool = True,
                 save_hlo: Optional[str] = None) -> Dict[str, Any]:
    scfg0 = step_cfg or StepConfig(grad_sync="fused")
    moe_mode = scfg0.moe_mode
    if cfg.is_moe and shape.is_decode and moe_mode == "weight_gather":
        # decode policy: weights >> tokens, so activation-stationary
        # dispatch wins by ~30x on the collective term (§Perf kimi-d1)
        moe_mode = "token_gather"
    if cfg.is_moe and moe_mode != cfg.moe_mode:
        cfg = cfg.scaled(moe_mode=moe_mode)
    if cfg.family == "hybrid" and shape.kind == "prefill" and \
            shape.global_batch % mesh.size != 0:
        cfg = cfg.scaled(ssm_cp=True)   # seq-shard via boundary exchange
    model = build_model(cfg)
    if scfg0.grad_sync == "mare_tree":
        # paper-faithful: replicated params, explicit K-level ppermute tree
        from repro.sharding import data_only_rules
        rules = data_only_rules(mesh)
    else:
        rules = rules_for(cfg, shape, mesh)
    specs = input_specs(cfg, shape)
    b_shard = batch_shardings(specs, mesh, rules)
    scfg = step_cfg or StepConfig(grad_sync="fused")
    t0 = time.monotonic()

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ax = model.logical_axes()
    p_shard = jax.tree.map(
        lambda leaf, axes: NamedSharding(
            mesh, rules.spec_for(tuple(axes), dims=leaf.shape)),
        params_struct, ax,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            isinstance(e, (str, type(None))) for e in t))

    if shape.kind == "train":
        opt, opt_name = choose_optimizer(cfg)
        step = make_train_step(model, opt,
                               cosine_warmup(3e-4, 100, 10000),
                               scfg, mesh=mesh, rules=rules)
        state_struct = jax.eval_shape(
            lambda p: TrainState(params=p, opt_state=opt.init(p),
                                 step=jnp.zeros((), jnp.int32),
                                 residual=()), params_struct)
        st_shard = TrainState(
            params=p_shard,
            opt_state=jax.tree.map(lambda _: None, state_struct.opt_state),
            step=NamedSharding(mesh, P()), residual=())
        jitted = jax.jit(step,
                         in_shardings=(st_shard, b_shard),
                         donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_struct, specs)
    elif shape.kind == "prefill":
        def prefill_fn(params, batch):
            with use_rules(rules, mesh):
                logits, caches = model.prefill(params, batch, shape.seq_len)
            return logits, caches

        jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
        lowered = jitted.lower(params_struct, specs)
    else:
        # decode: cache of seq_len, one new token
        with use_rules(rules, mesh):
            if cfg.family == "audio":
                pre_specs = {"tokens": jax.ShapeDtypeStruct(
                    (shape.global_batch, 8), jnp.int32),
                    "frames": specs["frames"] if "frames" in specs else
                    jax.ShapeDtypeStruct(
                        (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                        cfg.param_dtype)}
                _, cache_struct = jax.eval_shape(
                    lambda p, b: model.prefill(p, b, shape.seq_len),
                    params_struct, pre_specs)
            else:
                cache_struct = jax.eval_shape(
                    lambda: model.init_cache(shape.global_batch,
                                             shape.seq_len))
        c_shard = jax.tree.map(
            lambda leaf: _cache_sharding(leaf, cfg, shape, mesh, rules),
            cache_struct)

        def decode_fn(params, caches, tokens):
            with use_rules(rules, mesh):
                return model.decode_step(params, caches, tokens)

        jitted = jax.jit(decode_fn,
                         in_shardings=(p_shard, c_shard,
                                       b_shard["tokens"]),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(params_struct, cache_struct,
                               specs["tokens"])

    t_lower = time.monotonic() - t0
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower

    # XLA's own cost_analysis (trip-count-blind; kept as cross-check)
    cost = compat.cost_analysis(compiled)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0) +
                          (getattr(mem, "argument_size_in_bytes", 0) or 0),
        }
    except Exception as e:          # CPU backend may not implement it
        mem_info = {"error": str(e)}

    coll: Dict[str, Any] = {}
    flops = xla_flops
    byt = xla_bytes
    if extract_collectives:
        from repro.launch.hlo_cost import analyze
        text = compiled.as_text()
        walk = analyze(text)
        flops = walk["flops"]              # trip-count-aware, per device
        byt = walk["bytes"]
        coll = {"bytes_by_op": walk["coll_bytes_by_op"],
                "counts": walk["coll_counts"],
                "wire_bytes": walk["wire_bytes"],
                "unresolved_whiles": walk["unresolved_whiles"]}
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(text)

    n_chips = mesh.size
    result = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": dict(mesh.shape),
        "flops": flops, "bytes": byt,
        "xla_flops": xla_flops, "xla_bytes": xla_bytes,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byt / HBM_BW,
        "collective_s": (coll.get("wire_bytes", 0.0) / ICI_BW
                         if coll else None),
        "collectives": coll,
        "memory": mem_info,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "n_chips": n_chips,
    }
    return result
