"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
undercounts scanned-layer models by ~L x and — worse — misses the per-layer
FSDP all-gathers living inside scan bodies.  This walker parses
``compiled.as_text()`` (post-SPMD, post-fusion, per-device HLO) and
computes, bottom-up with memoization:

  flops       — dot: 2 * |out| * |contracted|; elementwise/reduce: |out|;
                fusions recursed; while bodies x trip-count.
  bytes       — per top-level op: operand + output bytes (fusions NOT
                recursed: internal traffic stays in registers/VMEM — this
                mirrors real HBM traffic post-fusion); while x trip-count.
  collectives — result bytes per op kind, x trip-count (catches the
                per-layer all-gather/reduce-scatter inside scans).

Trip counts are recovered from while-condition computations (max s32
constant compared against the induction variable — the standard lax.scan
lowering).  Unrecoverable conditions default to 1 and are reported.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|"
    r"u4|pred|c64|c128|token)\[([0-9,]*)\]")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],]+"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "compare", "select", "and", "or", "xor", "not", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "sign", "cosine", "sine",
    "atan2", "remainder", "clamp", "expm1", "log1p", "logistic",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "reduce", "reduce-window", "cbrt", "erf",
}

_ZERO_BYTE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "bitcast-convert", "reshape"}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) of all array shapes in `text`."""
    elems = 0
    byts = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    result: str                 # result shape text
    opcode: str
    rest: str                   # operands + attrs text


@dataclasses.dataclass
class Computation:
    name: str
    header: str
    instrs: List[Instr]
    shapes: Dict[str, str]      # value name -> shape text


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        # computation header: `%name (args) -> type {` or `ENTRY %name ...{`
        hm = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.+\{\s*$",
                      line)
        if hm and not line.startswith(" "):
            cur = Computation(name=hm.group(1), header=stripped, instrs=[],
                              shapes={})
            comps[cur.name] = cur
            # parameter shapes from header
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|"
                                  r"[\w\[\],]+(?:\{[^}]*\})?)",
                                  hm.group(2)):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, result, opcode, rest = im.groups()
            cur.instrs.append(Instr(name=name, result=result,
                                    opcode=opcode, rest=rest))
            cur.shapes[name] = result
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are inside the first balanced (...) of rest (already after
    # the opening paren); cut at the matching close.
    depth = 1
    out = []
    buf = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                out.append(buf)
                break
        if depth >= 1 and ch != ")":
            buf += ch
    text = out[0] if out else rest
    return re.findall(r"%([\w.\-]+)", text)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", rest)
    return m.group(1) if m else None


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    wire_bytes: float = 0.0     # ring-model bytes per device-link
    unresolved_whiles: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult
        self.wire_bytes += other.wire_bytes * mult
        self.unresolved_whiles += other.unresolved_whiles


def _group_size(rest: str) -> Optional[int]:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rest)
    if m:
        return int(m.group(2))
    return None


def _wire_bytes(op: str, result_bytes: float, n: Optional[int]) -> float:
    """Ring-model wire bytes per device for one collective.

    result_bytes is the op's RESULT size (per device, post-SPMD):
      all-gather:  result = full gathered buffer -> (n-1)/n * result
      all-reduce:  result = full buffer          -> 2(n-1)/n * result
      reduce-scatter: result = 1/n of input      -> (n-1) * result
      all-to-all:  result = local buffer         -> (n-1)/n * result
      collective-permute: one hop                -> 1.0 * result
    """
    if not n or n <= 1:
        n = 2 if op != "collective-permute" else 1
    if op == "all-gather":
        return (n - 1) / n * result_bytes
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if op == "reduce-scatter":
        return (n - 1) * result_bytes
    if op == "all-to-all":
        return (n - 1) / n * result_bytes
    return result_bytes


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
                if m:
                    entry = m.group(1)
        self.entry = entry

    def trip_count(self, cond_name: str) -> Optional[int]:
        comp = self.comps.get(cond_name)
        if comp is None:
            return None
        consts = []
        for ins in comp.instrs:
            m = re.search(r"constant\((\d+)\)", ins.name + "=" + ins.rest)
            if ins.opcode == "constant":
                m2 = re.match(r"(\d+)\)?", ins.rest)
                if m2:
                    consts.append(int(m2.group(1)))
        # also constants referenced via fusion wrapped compare: scan any
        # `constant(N)` text in the computation body
        body_text = " ".join(i.rest for i in comp.instrs)
        for m in re.finditer(r"constant\((\d+)\)", body_text):
            consts.append(int(m.group(1)))
        consts = [c for c in consts if c > 0]
        return max(consts) if consts else None

    def comp_cost(self, name: str, in_fusion: bool = False) -> Cost:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()          # cycle guard
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        for ins in comp.instrs:
            total.add(self.instr_cost(ins, comp, in_fusion))
        self._memo[key] = total
        return total

    def instr_cost(self, ins: Instr, comp: Computation,
                   in_fusion: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        out_elems, out_bytes = _shape_elems_bytes(ins.result)

        if op == "while":
            body = _attr(ins.rest, "body")
            cond = _attr(ins.rest, "condition")
            trips = self.trip_count(cond) if cond else None
            if trips is None:
                trips = 1
                c.unresolved_whiles += 1
            inner = Cost()
            if body:
                inner.add(self.comp_cost(body))
            if cond:
                inner.add(self.comp_cost(cond))
            c.add(inner, mult=trips)
            return c

        if op == "fusion":
            called = _attr(ins.rest, "calls")
            touched = None
            if called:
                sub = self.comp_cost(called, in_fusion=True)
                c.flops += sub.flops
                for k in _COLLECTIVES:
                    c.coll_bytes[k] += sub.coll_bytes[k]
                    c.coll_counts[k] += sub.coll_counts[k]
                c.wire_bytes += sub.wire_bytes
                c.unresolved_whiles += sub.unresolved_whiles
                touched = self._fusion_touched_bytes(called, ins, comp,
                                                     out_bytes)
            if touched is None:
                touched = out_bytes + self._operand_bytes(ins, comp)
            c.bytes += touched
            return c

        if op in ("call", "conditional", "sort", "custom-call",
                  "async-start"):
            called = _attr(ins.rest, "calls") or _attr(ins.rest,
                                                       "to_apply")
            if called:
                c.add(self.comp_cost(called, in_fusion=in_fusion))
            if op == "conditional":
                for m in re.finditer(r"branch_computations=\{([^}]*)\}",
                                     ins.rest):
                    names = re.findall(r"%?([\w.\-]+)", m.group(1))
                    branch_costs = [self.comp_cost(n) for n in names]
                    if branch_costs:
                        # conservative: max flops branch
                        best = max(branch_costs, key=lambda x: x.flops)
                        c.add(best)
            # a bare `call` is control flow: its body (e.g. the CPU
            # parallel-fusion wrapper around a dynamic-slice fusion)
            # already accounts its own traffic — adding the call's full
            # operands would re-count sliced buffers at full size.
            if not in_fusion and not (op == "call" and called):
                c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c

        if op in _COLLECTIVES:
            c.coll_bytes[op] += out_bytes
            c.coll_counts[op] += 1
            c.wire_bytes += _wire_bytes(op, out_bytes,
                                        _group_size(ins.rest))
            if not in_fusion:
                c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c

        if op in ("dynamic-slice", "gather", "slice"):
            # real traffic: slice read + write, not the whole operand
            ops_n = _operand_names(ins.rest)
            idx_bytes = sum(_shape_elems_bytes(comp.shapes.get(n, ""))[1]
                            for n in ops_n[1:])
            c.bytes += 2 * out_bytes + idx_bytes
            return c

        if op in ("dynamic-update-slice", "scatter"):
            # in-place region update: read update + write region
            ops_n = _operand_names(ins.rest)
            upd_idx = 1 if op == "dynamic-update-slice" else 2
            upd = (comp.shapes.get(ops_n[upd_idx], "")
                   if len(ops_n) > upd_idx else ins.result)
            ub = _shape_elems_bytes(upd)[1]
            c.bytes += 2 * ub
            return c

        if op in ("dot", "convolution"):
            lhs_contracted = 1
            ops = _operand_names(ins.rest)
            if op == "dot" and ops:
                lhs_shape = comp.shapes.get(ops[0], "")
                dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                   ins.rest)
                sm = _SHAPE_RE.search(lhs_shape)
                if dims_m and sm and sm.group(2):
                    dims = [int(d) for d in sm.group(2).split(",")]
                    for ci in dims_m.group(1).split(","):
                        if ci != "" and int(ci) < len(dims):
                            lhs_contracted *= dims[int(ci)]
                c.flops += 2.0 * out_elems * lhs_contracted
            elif op == "convolution":
                # approximate: 2 * out * (kernel elems) — kernels here are
                # tiny (whisper stub excluded); treat as elementwise
                c.flops += 2.0 * out_elems
            if not in_fusion:
                c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c

        if op in _ELEMENTWISE_FLOP_OPS:
            # reduce counts input elements; others output elements
            if op.startswith("reduce"):
                c.flops += self._operand_elems(ins, comp)
            else:
                c.flops += out_elems
        if op not in _ZERO_BYTE_OPS and not in_fusion:
            c.bytes += out_bytes + self._operand_bytes(ins, comp)
        return c

    def _fusion_touched_bytes(self, called: str, ins: Instr,
                              comp: Computation,
                              out_bytes: int) -> Optional[int]:
        """Memory traffic of a fusion: per input parameter, if every use
        inside the fused computation is a (dynamic-)slice/gather, count the
        slice outputs instead of the full operand; if the root is a
        dynamic-update-slice, the written region is the update size."""
        fused = self.comps.get(called)
        if fused is None:
            return None
        total = 0
        param_names = [i.name for i in fused.instrs
                       if i.opcode == "parameter"]
        for pname in param_names:
            full = _shape_elems_bytes(fused.shapes.get(pname, ""))[1]
            uses = [i for i in fused.instrs
                    if re.search(r"%" + re.escape(pname) + r"\b",
                                 i.rest)]

            def _sliced_use_bytes(u: Instr) -> Optional[int]:
                ops_n = _operand_names(u.rest)
                if u.opcode in ("dynamic-slice", "gather", "slice") and \
                        ops_n[:1] == [pname]:
                    return _shape_elems_bytes(u.result)[1]
                if u.opcode == "dynamic-update-slice" and \
                        ops_n[:1] == [pname]:
                    # in-place region write: traffic = update size
                    upd = fused.shapes.get(ops_n[1], "") \
                        if len(ops_n) > 1 else ""
                    return _shape_elems_bytes(upd)[1]
                return None

            per_use = [_sliced_use_bytes(u) for u in uses]
            if uses and all(b is not None for b in per_use):
                total += min(full, sum(per_use))
            else:
                total += full
        root = fused.instrs[-1] if fused.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            ops_n = _operand_names(root.rest)
            upd = fused.shapes.get(ops_n[1], "") if len(ops_n) > 1 else ""
            total += _shape_elems_bytes(upd)[1]
        else:
            total += out_bytes
        return total

    def _operand_bytes(self, ins: Instr, comp: Computation) -> int:
        total = 0
        for name in _operand_names(ins.rest):
            shp = comp.shapes.get(name)
            if shp:
                total += _shape_elems_bytes(shp)[1]
        return total

    def _operand_elems(self, ins: Instr, comp: Computation) -> int:
        total = 0
        for name in _operand_names(ins.rest):
            shp = comp.shapes.get(name)
            if shp:
                total += _shape_elems_bytes(shp)[0]
        return total

    def entry_cost(self) -> Cost:
        if not self.entry:
            return Cost()
        return self.comp_cost(self.entry)


def analyze(text: str) -> Dict[str, float]:
    cost = HloCost(text).entry_cost()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes_by_op": dict(cost.coll_bytes),
        "coll_counts": dict(cost.coll_counts),
        "wire_bytes": cost.wire_bytes,
        "unresolved_whiles": cost.unresolved_whiles,
    }
