"""Batch training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Uses the real production stack: config registry, synthetic data pipeline
with prefetch + straggler re-dispatch, MaRe-reduce or fused grad sync,
checkpoint/restart.  ``--smoke`` selects the reduced config (CPU-sized);
omit it on a real TPU slice.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.models import build_model
from repro.optim import adamw
from repro.optim.schedule import cosine_warmup
from repro.train import (StepConfig, Trainer, TrainerConfig,
                         init_train_state, make_train_step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-sync", default="fused")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    model = build_model(cfg)
    opt = adamw()
    state = init_train_state(model, opt, jax.random.PRNGKey(args.seed))


    def batch_fn(step: int):
        r = np.random.default_rng(args.seed * 100003 + step)
        b = {"tokens": r.integers(0, cfg.vocab_size,
                                  (args.batch, args.seq)).astype(np.int32)}
        b["labels"] = np.roll(b["tokens"], -1, axis=1)
        if cfg.family == "audio":
            b["frames"] = r.normal(size=(
                args.batch, cfg.encoder_seq, cfg.d_model)).astype(
                    np.float32)
        if cfg.family == "vlm" and cfg.num_patches:
            b["patch_embeds"] = r.normal(size=(
                args.batch, cfg.num_patches, cfg.d_model)).astype(
                    np.float32)
        return {k: jnp.asarray(v) for k, v in b.items()}

    step = jax.jit(make_train_step(
        model, opt, cosine_warmup(args.lr, args.warmup, args.steps),
        StepConfig(grad_sync=args.grad_sync, microbatch=args.microbatch)))
    manager = CheckpointManager(args.ckpt_dir)
    if args.resume and manager.latest_step() is not None:
        state = manager.restore(state)
        print(f"resumed from step {int(state.step)}")
    trainer = Trainer(step, state, None, manager,
                      TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=args.ckpt_every,
                                    log_every=args.log_every),
                      batch_fn=batch_fn)
    trainer.run()
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(trainer.history, f)
    first = trainer.history[0]["loss"]
    last = trainer.history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
