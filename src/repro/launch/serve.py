"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.encoder_seq, cfg.d_model)), cfg.param_dtype)
    if cfg.family == "vlm" and cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.num_patches, cfg.d_model)), cfg.param_dtype)

    t0 = time.monotonic()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pre = time.monotonic() - t0
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.monotonic()
    for i in range(args.gen - 1):
        lg, caches = decode(params, caches, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.monotonic() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill: {t_pre*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_dec/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"(batch {args.batch})")
    print("generated:", gen[:2].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
