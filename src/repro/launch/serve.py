"""Serving drivers.

Two modes behind one entry point:

* ``--service`` — the **multi-tenant query-service loop**: builds a
  shared dataset, starts a :class:`repro.serve.QueryService`, runs N
  tenant sessions issuing rounds of aggregation queries against the
  persisted shared prefix, and prints per-round latency (live p50/p99
  from the metrics registry), batch occupancy, per-tenant queue depths
  and the final cache/fairness picture.  This is the interactive
  serving demonstrator — ``benchmarks/serve.py`` is its measured twin.

      PYTHONPATH=src python -m repro.launch.serve --service \\
          --tenants 4 --rounds 5

* ``--model-smoke`` — the original batched token-decode smoke (prefill
  a prompt batch, greedy-decode N tokens):

      PYTHONPATH=src python -m repro.launch.serve --model-smoke \\
          --arch smollm-135m --smoke --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np


# -- the legacy token-decode smoke -------------------------------------------

def _model_smoke(args) -> int:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model

    if args.arch is None:
        print("--model-smoke requires --arch", file=sys.stderr)
        return 2
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.encoder_seq, cfg.d_model)), cfg.param_dtype)
    if cfg.family == "vlm" and cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(rng.normal(size=(
            args.batch, cfg.num_patches, cfg.d_model)), cfg.param_dtype)

    t0 = time.monotonic()
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pre = time.monotonic() - t0
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.monotonic()
    for i in range(args.gen - 1):
        lg, caches = decode(params, caches, tok)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.monotonic() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"prefill: {t_pre*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_dec/max(args.gen-1,1)*1e3:.2f} ms/token "
          f"(batch {args.batch})")
    print("generated:", gen[:2].tolist())
    return 0


# -- the query-service loop ---------------------------------------------------

READ_LEN = 64
QUERY_OPS = ("sum", "max", "min")


def _make_reads(n_reads: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    bases = np.frombuffer(b"ACGT", np.uint8)
    data = bases[rng.integers(0, 4, size=(n_reads, READ_LEN))]
    return {"data": data, "len": np.full((n_reads,), READ_LEN, np.int32)}


def _key_of(recs):
    # module-level keyBy/valueBy: lineage signatures and the compile
    # cache key on callable identity, so every session sharing these
    # functions shares programs AND batch keys; lambdas would defeat both
    return recs[0]


def _ones_of(recs):
    return (recs[1],)


def _service_loop(args) -> int:
    import jax

    from repro import compat
    from repro.core import MaRe
    from repro.obs import METRICS
    from repro.serve import QueryService, ServiceConfig

    k = args.k
    num_keys = 4 ** k
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    shared = MaRe(_make_reads(args.reads), mesh=mesh).dataset

    config = ServiceConfig(
        batch_window_s=args.batch_window,
        max_queued_per_tenant=args.max_queued,
        tenant_device_budget_bytes=(args.tenant_budget_mb << 20
                                    if args.tenant_budget_mb else None))
    print(f"service: {args.tenants} tenants x {args.rounds} rounds, "
          f"{jax.device_count()} devices, k={k} ({num_keys} keys), "
          f"batch_window={config.batch_window_s*1e3:.0f}ms")

    with QueryService(config=config) as svc:
        sessions = [svc.session(f"tenant{i}")
                    for i in range(args.tenants)]

        # shared prefix: one tenant persists the expensive map once;
        # every session's queries then start from the cached lineage node
        sessions[0].mare(shared).map(image="kmer-stats", k=k).persist()

        def query(sess, op):
            return (sess.mare(shared)
                    .map(image="kmer-stats", k=k)
                    .reduce_by_key(_key_of, value_by=_ones_of, op=op,
                                   num_keys=num_keys)
                    .collect(label=f"{op} query"))

        barrier = threading.Barrier(len(sessions))
        lat_lock = threading.Lock()
        latencies = []

        def client(sess):
            for rnd in range(args.rounds):
                op = QUERY_OPS[rnd % len(QUERY_OPS)]
                barrier.wait()          # all tenants fire together
                t0 = time.monotonic()
                query(sess, op)
                with lat_lock:
                    latencies.append(time.monotonic() - t0)

        threads = [threading.Thread(target=client, args=(s,), daemon=True)
                   for s in sessions]
        t0 = time.monotonic()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.monotonic() - t0

        total = len(latencies)
        lat = np.sort(np.asarray(latencies))
        occ = METRICS.histogram("serve.batch_occupancy")
        print(f"served {total} actions in {wall:.2f}s "
              f"({total / wall:.1f} QPS), "
              f"p50={lat[int(0.50 * (total - 1))] * 1e3:.1f}ms "
              f"p99={lat[int(0.99 * (total - 1))] * 1e3:.1f}ms, "
              f"mean batch occupancy={occ.mean:.2f}")
        # live histogram view (bucket resolution) vs the exact numbers
        h = METRICS.histogram("phase.queue_wait")
        if h.count:
            print(f"queue_wait (live est.): p50~{h.percentile(50)*1e3:.1f}ms "
                  f"p99~{h.percentile(99)*1e3:.1f}ms over {h.count} waits")
        for sess in sessions:
            rep = sess.report()
            print(f"  {sess.tenant}: {sess.reports.appended} actions, "
                  f"last={rep.describe() if rep else '<none>'}")
        print(METRICS.render("serve."))
        stats = svc.executor.mat_cache.stats()
        print(f"mat_cache: hits={stats['hits']} "
              f"shared_hits={stats['shared_hits']} "
              f"tenant_budget_violations={stats['tenant_budget_violations']}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--service", action="store_true",
                      help="run the multi-tenant query-service loop")
    mode.add_argument("--model-smoke", action="store_true",
                      help="legacy batched token-decode smoke")
    # service knobs
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--reads", type=int, default=2_048)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--batch-window", type=float, default=0.01)
    ap.add_argument("--max-queued", type=int, default=8)
    ap.add_argument("--tenant-budget-mb", type=int, default=None)
    # model-smoke knobs
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    if args.model_smoke:
        return _model_smoke(args)
    return _service_loop(args)


if __name__ == "__main__":
    sys.exit(main())
