"""LiveQuery: a background refresh loop over an incremental query.

The thinnest possible driver: a daemon thread that calls
:meth:`~repro.stream.incremental.IncrementalQuery.update` on an
interval.  Everything interesting already happens below it — polling
discovers new splits, the delta runs through the owning executor (for a
session-built query that means admission, fair scheduling, batching),
and every refresh appends one :class:`~repro.runtime.reports.ActionReport`
(with the ``stream.*`` counters) to the query's report log.  When that
log is a session's :class:`~repro.runtime.reports.ReportStream`,
``Session.follow()`` blocks until the next refresh lands — a live
dashboard is a ``follow()`` loop (see ``examples/kmer_stats.py
--follow`` and docs/streaming.md#live-queries).

Errors don't vanish into the thread: the first exception stops the loop
and is re-raised from :meth:`LiveQuery.stop` (and surfaced on
:attr:`error` meanwhile).
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.obs import METRICS
from repro.stream.incremental import IncrementalQuery, StreamUpdate


class LiveQuery:
    """Continuously refresh an :class:`IncrementalQuery` (or
    :class:`~repro.stream.windows.WindowedQuery`).

    .. code-block:: python

        with LiveQuery(query, interval_s=0.2) as live:
            while producing():
                drop_file(inbox)
                reports = session.follow(seen, timeout=5.0)
                seen += len(reports)
        # exiting stops the thread and re-raises any refresh error

    ``interval_s`` is the idle poll period — a refresh that found data
    immediately polls again (drain fast, sleep only when dry).
    ``max_epochs`` stops the loop after that many non-empty refreshes
    (None = run until :meth:`stop`); ``on_refresh`` is called with each
    :class:`StreamUpdate` from the refresh thread.
    """

    def __init__(self, query: IncrementalQuery, interval_s: float = 0.5,
                 max_epochs: Optional[int] = None,
                 on_refresh: Optional[Callable[[StreamUpdate], None]]
                 = None) -> None:
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.query = query
        self.interval_s = interval_s
        self.max_epochs = max_epochs
        self.on_refresh = on_refresh
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._latest: Optional[StreamUpdate] = None
        self._refreshes = 0
        self._error: Optional[BaseException] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "LiveQuery":
        if self._thread is not None:
            raise RuntimeError("LiveQuery already started")
        self._thread = threading.Thread(
            target=self._loop, name=f"live-{self.query.label}", daemon=True)
        self._thread.start()
        METRICS.counter("stream.live_queries").inc()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                update = self.query.update()
            except BaseException as e:  # surface on stop(), don't lose it
                with self._lock:
                    self._error = e
                METRICS.counter("stream.live_errors").inc()
                return
            if update is None:
                self._stop.wait(self.interval_s)
                continue
            with self._lock:
                self._latest = update
                self._refreshes += 1
                done = (self.max_epochs is not None
                        and self._refreshes >= self.max_epochs)
            if self.on_refresh is not None:
                self.on_refresh(update)
            if done:
                return

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        """Stop the refresh loop and join the thread; re-raises the first
        error the loop hit (if any)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def __enter__(self) -> "LiveQuery":
        return self.start()

    def __exit__(self, *exc) -> None:
        # an exception already in flight wins over a refresh error
        if exc[0] is not None:
            self._stop.set()
            if self._thread is not None:
                self._thread.join(10.0)
                self._thread = None
            return
        self.stop()

    # -- state ---------------------------------------------------------------

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def latest(self) -> Optional[StreamUpdate]:
        """Most recent non-empty refresh (None before the first)."""
        with self._lock:
            return self._latest

    @property
    def refreshes(self) -> int:
        """Non-empty refreshes completed so far."""
        with self._lock:
            return self._refreshes

    @property
    def error(self) -> Optional[BaseException]:
        with self._lock:
            return self._error

    def __repr__(self) -> str:
        state = "running" if self.running else "stopped"
        return (f"LiveQuery({self.query.label!r}, {state}, "
                f"refreshes={self.refreshes}, "
                f"watermark={self.query.epoch})")
