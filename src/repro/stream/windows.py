"""Tumbling and sliding windows over epoch streams.

A window is a *wave group over epochs* (the wave scheduling idea from
PR 2, lifted one level): the window state is a ring of per-epoch partial
aggregates — each epoch's delta runs the same fused plan suffix as an
:class:`~repro.stream.incremental.IncrementalQuery` epoch and is
persisted under its content lineage — and the window result is a
monoid-fold of the ring with the same cached shard-local fold program.
Eviction is cache-native: when an epoch slides out of the window, its
partial's materialization is dropped
(:meth:`repro.runtime.cache.MaterializationCache.drop`), so window state
occupies exactly ``size`` epochs of cache budget, forever.

Semantics (docs/streaming.md#windows): a window of ``size`` S covers the
S most recent epochs ``(e - S, e]``; ``slide`` L emits an aggregate
every L arrivals.  ``slide=1`` is the classic sliding window,
``slide == size`` (the :meth:`WindowedQuery.tumbling` constructor) the
tumbling window — between emissions :attr:`state` holds the previous
window's aggregate.  Windows are counted in *epochs*, not wall time:
epochs are consecutive by construction (``poll()`` consumes no epoch
number when nothing arrived), so epoch-based eviction is arrival-based
eviction.
"""
from __future__ import annotations

import collections
import time
from typing import Deque, Optional, Tuple

from repro.core.dataset import ShardedDataset
from repro.obs import METRICS, span
from repro.stream.incremental import IncrementalQuery, StreamUpdate
from repro.stream.source import EpochBatch


class WindowedQuery(IncrementalQuery):
    """A keyed aggregate over the last ``size`` epochs of a stream.

    .. code-block:: python

        win = WindowedQuery(cont, build, size=4)          # sliding
        tum = WindowedQuery.tumbling(cont, build, size=4)  # slide == size

    Same constructor seams as :class:`IncrementalQuery` (executor,
    plan_cache, reports, label) — a session-scoped windowed query gets
    admission/fairness/reports exactly like the unbounded one.
    """

    def __init__(self, source, build, *, size: int, slide: int = 1,
                 **kwargs) -> None:
        super().__init__(source, build, **kwargs)
        if size < 1:
            raise ValueError(f"window size must be >= 1 epoch, got {size}")
        if not 1 <= slide <= size:
            raise ValueError(f"slide must be in [1, size={size}], "
                             f"got {slide}")
        self.size = size
        self.slide = slide
        #: (epoch, per-epoch partial aggregate) pairs, oldest first.
        self._ring: Deque[Tuple[int, ShardedDataset]] = collections.deque()
        self._arrivals = 0
        self._evicted = 0

    @classmethod
    def tumbling(cls, source, build, *, size: int, **kwargs
                 ) -> "WindowedQuery":
        """Non-overlapping windows: one aggregate per ``size`` epochs."""
        return cls(source, build, size=size, slide=size, **kwargs)

    # -- the windowed update path --------------------------------------------

    def apply(self, batch: EpochBatch) -> StreamUpdate:
        t0 = time.monotonic()
        with span("stream.window.update", epoch=batch.epoch,
                  size=self.size, slide=self.slide, label=self.label):
            delta = self.source.ingest_epoch(batch)
            suffix = self._suffix(delta)
            table = suffix._materialize(
                label=f"{self.label} window epoch {batch.epoch}")
            # each epoch's partial lives in the cache under its content
            # lineage until it slides out of the window
            self.executor.persist(table, tier=self.persist_tier)
            self._ring.append((batch.epoch, table))
            evicted = 0
            while self._ring and self._ring[0][0] <= batch.epoch - self.size:
                _, expired = self._ring.popleft()
                if expired.lineage is not None:
                    self.executor.mat_cache.drop(expired.lineage)
                evicted += 1
            if evicted:
                self._evicted += evicted
                METRICS.counter("stream.window.evictions").inc(evicted)
            self._arrivals += 1
            keyed = self._keyed
            fold_s = 0.0
            if self._arrivals % self.slide == 0:
                f0 = time.monotonic()
                acc = self._ring[0][1]
                for _, partial in list(self._ring)[1:]:
                    acc = self.fold_engine.fold(
                        acc, partial, keyed.num_keys, keyed.op,
                        use_kernel=keyed.use_kernel)
                fold_s = time.monotonic() - f0
                self._install(acc, batch.epoch)
        METRICS.histogram("stream.update_s").observe(time.monotonic() - t0)
        METRICS.gauge("stream.watermark").set(batch.epoch)
        report = self.reports.latest
        if report is not None:
            report.counters["stream.epoch"] = batch.epoch
            report.counters["stream.watermark"] = batch.epoch
            report.counters["stream.new_splits"] = batch.num_splits
            report.counters["stream.window.epochs"] = len(self._ring)
            report.counters["stream.window.evicted"] = evicted
            report.phases["stream.fold"] = fold_s
        return StreamUpdate(epoch=batch.epoch, watermark=batch.epoch,
                            new_splits=batch.num_splits, fold_s=fold_s,
                            dataset=self._state, report=report)

    # -- introspection -------------------------------------------------------

    @property
    def window_epochs(self) -> Tuple[int, ...]:
        """Epochs currently inside the window, oldest first."""
        return tuple(e for e, _ in self._ring)

    @property
    def evicted(self) -> int:
        """Total per-epoch partials dropped from the cache so far."""
        return self._evicted

    def describe(self) -> str:
        plan = self._plan.describe() if self._plan is not None \
            else "<unbuilt>"
        kind = "tumbling" if self.slide == self.size else "sliding"
        return (f"WindowedQuery([{plan}], {kind} size={self.size} "
                f"slide={self.slide}, ring={list(self.window_epochs)}) "
                f"[incremental @ epoch {self._epoch}]")
