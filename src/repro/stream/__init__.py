"""repro.stream — incremental and windowed MapReduce over continuous
sources (docs/streaming.md).

The batch stack is reused wholesale; this package adds only the *delta*
machinery: :class:`~repro.stream.source.ContinuousSource` polls a
``DataSource`` for newly arrived splits (monotone split sets, one epoch
per poll, pinned pack geometry so epochs never recompile);
:class:`~repro.stream.incremental.IncrementalQuery` runs each epoch's
delta through the same fused plan suffix and folds the keyed result into
the persisted aggregate shard-locally under the manifest-declared monoid
— update cost scales with the delta, not the history;
:class:`~repro.stream.windows.WindowedQuery` keeps a ring of per-epoch
partials for tumbling/sliding windows with cache-native eviction; and
:class:`~repro.stream.live.LiveQuery` drives refreshes from a background
thread so a tenant ``Session`` can ``follow()`` the stream.
"""
from repro.stream.incremental import (FoldEngine, IncrementalQuery,
                                      StreamUpdate)
from repro.stream.live import LiveQuery
from repro.stream.source import ContinuousSource, EpochBatch
from repro.stream.windows import WindowedQuery

__all__ = [
    "ContinuousSource", "EpochBatch", "FoldEngine", "IncrementalQuery",
    "LiveQuery", "StreamUpdate", "WindowedQuery",
]
