"""ContinuousSource: poll a storage backend for newly arrived splits.

The streaming entry point (ROADMAP "Streaming / incremental MapReduce"):
a :class:`~repro.io.source.DataSource` names *what* to read; a
``ContinuousSource`` adds *when* — each :meth:`poll` re-plans the
source's splits and returns only the ones not seen by an earlier epoch,
as an :class:`EpochBatch`.  Split sets are **monotone**: a split, once
observed, belongs to its epoch forever (files are assumed append-only at
file granularity — the HDFS/object-store arrival model, where a producer
drops whole new objects into a prefix; mutating an already-observed file
in place is undetected, exactly as for the batch lineage cache).

Pack geometry is **pinned** across epochs: the first ingested epoch
fixes ``capacity``/``width`` (rounded up by the ingestion buckets, or
taken from the constructor), and every later epoch packs into the same
shapes.  That is what makes epochs *cheap*: the delta plan's
``program_key`` is identical every epoch, so epoch N>0 compiles nothing
(repro.stream's zero-recompile contract, asserted by
``benchmarks/stream.py``).  The flip side is a hard bound: an epoch
whose per-shard record count exceeds the pinned capacity raises at
ingest — size ``capacity`` for the largest epoch, not the first one.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional, Set, Tuple

from jax.sharding import Mesh

from repro.core.dataset import ShardedDataset
from repro.io.ingest import ingest
from repro.io.source import DataSource
from repro.io.splits import InputSplit
from repro.obs import METRICS, span


@dataclasses.dataclass(frozen=True)
class EpochBatch:
    """One poll's worth of newly discovered splits.

    ``epoch`` is the batch's position in the monotone arrival order (0,
    1, ...); ``watermark`` == ``epoch`` is the stream position an
    aggregate that folded this batch is complete *up to* — the value
    surfaced through the ``stream.watermark`` counter on reports.
    """

    epoch: int
    splits: Tuple[InputSplit, ...]

    @property
    def watermark(self) -> int:
        return self.epoch

    @property
    def num_splits(self) -> int:
        return len(self.splits)


class ContinuousSource:
    """A DataSource polled for new splits, ingested epoch by epoch.

    .. code-block:: python

        cont = ContinuousSource(text_source(inbox_dir), mesh,
                                capacity=512)
        batch = cont.poll()            # None until new files arrive
        if batch is not None:
            delta = cont.ingest_epoch(batch)   # ShardedDataset

    Thread-safe: :class:`~repro.stream.live.LiveQuery` polls from a
    background thread while the owning session inspects
    :attr:`watermark` from its own.
    """

    def __init__(self, source: DataSource, mesh: Mesh, axis: str = "data",
                 capacity: Optional[int] = None,
                 width: Optional[int] = None,
                 workers: Optional[int] = None,
                 parser: str = "vectorized") -> None:
        self.source = source
        self.mesh = mesh
        self.axis = axis
        self.workers = workers
        #: Framing implementation for every epoch's ingest ("vectorized"
        #: columnar RecordBatch by default) — epochs are latency-critical,
        #: so deltas ride the same zero-copy path as batch ingestion.
        self.parser = parser
        #: Pinned pack geometry (fixed after the first ingested epoch).
        self.capacity = capacity
        self.width = width
        self._seen: Set[InputSplit] = set()
        self._next_epoch = 0
        self._lock = threading.Lock()

    # -- discovery -----------------------------------------------------------

    def poll(self) -> Optional[EpochBatch]:
        """Newly arrived splits since the last poll as the next epoch's
        batch, or ``None`` when nothing new arrived (no epoch number is
        consumed in that case).  Arrival order within a batch follows the
        source's split plan order, so a batch's content — and therefore
        its content-keyed ingest lineage — is deterministic."""
        with self._lock:
            with span("stream.poll", epoch=self._next_epoch):
                fresh = [sp for sp in self.source.splits()
                         if sp not in self._seen]
            if not fresh:
                return None
            self._seen.update(fresh)
            batch = EpochBatch(epoch=self._next_epoch, splits=tuple(fresh))
            self._next_epoch += 1
            METRICS.counter("stream.epochs").inc()
            METRICS.counter("stream.splits_discovered").inc(len(fresh))
            return batch

    # -- ingestion -----------------------------------------------------------

    def ingest_epoch(self, batch: EpochBatch) -> ShardedDataset:
        """Ingest one epoch's splits through the parallel fetch pool into
        a dataset with the stream's pinned pack geometry."""
        with span("stream.ingest", epoch=batch.epoch,
                  splits=batch.num_splits):
            ds = ingest(self.source, self.mesh, axis=self.axis,
                        capacity=self.capacity, width=self.width,
                        workers=self.workers, splits=list(batch.splits),
                        parser=self.parser)
        with self._lock:
            # first epoch fixes the geometry every later epoch reuses —
            # identical shapes are what make the delta plan a compile-
            # cache hit from epoch 1 on
            if self.capacity is None:
                self.capacity = ds.capacity
            if self.width is None:
                leaf = ds.records["data"] if isinstance(ds.records, dict) \
                    else None
                if leaf is not None and leaf.ndim == 2:
                    self.width = int(leaf.shape[1])
        METRICS.counter("stream.splits_ingested").inc(batch.num_splits)
        return ds

    # -- introspection -------------------------------------------------------

    @property
    def watermark(self) -> int:
        """Highest epoch handed out so far (-1 before the first)."""
        with self._lock:
            return self._next_epoch - 1

    def seen_splits(self) -> List[InputSplit]:
        with self._lock:
            return sorted(self._seen,
                          key=lambda sp: (sp.path, sp.start, sp.stop))

    def __repr__(self) -> str:
        return (f"ContinuousSource(epochs={self._next_epoch}, "
                f"splits={len(self._seen)}, capacity={self.capacity}, "
                f"width={self.width})")
