"""Incremental maintenance of a persisted ``reduce_by_key`` aggregate.

The batch stack already has everything an incremental view needs:
manifests declare the reduce *monoid* (PR 4), the runtime persists
lineage-keyed keyed aggregates (PR 5), and the hash exchange routes a
key to ``hash(key) % axis_size`` **deterministically** — so the state
table and any new epoch's delta table are partitioned identically.  An
:class:`IncrementalQuery` exploits all three: each poll epoch's new
splits run through the *same fused plan suffix* as the original query
(a compile-cache hit from epoch 1 on — identical pack geometry, stable
op signatures), and the resulting delta table is folded into the
persisted state **shard-locally** with one segment-reduce
(:func:`repro.core.tree_reduce.merge_keyed_tables`) — no exchange, no
recomputation of history.  Update cost scales with the *delta*, not the
history (``benchmarks/stream.py``'s headline).

Snapshot generations: every fold produces a new state whose lineage is
:func:`repro.runtime.lineage.stream_root` (base query lineage, epoch
watermark), persisted in the materialization cache; the superseded
generation is explicitly dropped.  Two generations can never alias, and
``describe()`` shows ``[incremental @ epoch N]``.

Exactness: for integer values (and ``max``/``min`` on anything) the
incrementally maintained table is **bit-identical** to a one-shot
``reduce_by_key`` over the union of all epochs — same dtypes, same
values, same record order (tests/test_stream.py proves it over random
epoch partitions).  Float ``sum`` reassociates across epochs, as any
partitioned sum does.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import dataset as ds_lib
from repro.core.container import Registry, DEFAULT_REGISTRY, make_partition
from repro.core.dataset import ShardedDataset
from repro.core.mare import MaRe
from repro.core.plan import KeyedReduceStage, Plan
from repro.core.tree_reduce import merge_keyed_tables
from repro.obs import METRICS, span
from repro.runtime.lineage import Lineage, stream_root
from repro.runtime.reports import ActionReport, ReportLog
from repro.stream.source import ContinuousSource, EpochBatch

#: The executor seam (see repro.serve.session): anything with run /
#: persist / ensure_lineage / mat_cache works — the default engine or a
#: session's tenant proxy.
Builder = Callable[[MaRe], MaRe]


class FoldEngine:
    """Per-query cache of jitted shard-local fold programs.

    One program per (mesh, axis, num_keys, op, value shapes) — for a
    stream with pinned geometry that is exactly ONE compile over the
    query's lifetime (``compiles`` is the bench's zero-recompile
    witness).  The fold is embarrassingly shard-local: state and delta
    agree on every key's owner shard, so no collective appears in the
    program.
    """

    def __init__(self) -> None:
        self._programs: Dict[Tuple, Callable] = {}
        self.compiles = 0
        self.folds = 0

    def _key(self, state: ShardedDataset, num_keys: int, op: str,
             use_kernel: Optional[bool]) -> Tuple:
        leaves = jax.tree.leaves(state.records)
        return (state.mesh, state.axis, num_keys, op, use_kernel,
                jax.tree.structure(state.records),
                tuple((tuple(leaf.shape), str(leaf.dtype))
                      for leaf in leaves))

    def fold(self, state: ShardedDataset, delta: ShardedDataset,
             num_keys: int, op: str,
             use_kernel: Optional[bool] = None) -> ShardedDataset:
        """``state ⊕ delta`` under the query's monoid, per shard."""
        key = self._key(state, num_keys, op, use_kernel)
        prog = self._programs.get(key)
        if prog is None:
            mesh, axis = state.mesh, state.axis

            def interior(s_rec, s_cnt, d_rec, d_cnt):
                merged = merge_keyed_tables(
                    make_partition(s_rec, s_cnt[0]),
                    make_partition(d_rec, d_cnt[0]),
                    num_keys, op=op, use_kernel=use_kernel)
                return merged.records, merged.count[None]

            # the fold is purely shard-local (no collective appears in
            # the program), so the replication check buys nothing — and
            # it has no rules for the segment-reduce internals (scan
            # compaction, pallas_call when the kernel is picked)
            prog = jax.jit(compat.shard_map(
                interior, mesh=mesh,
                in_specs=(P(axis), P(axis), P(axis), P(axis)),
                out_specs=(P(axis), P(axis)), check_vma=False))
            self._programs[key] = prog
            self.compiles += 1
        with span("stream.fold", num_keys=num_keys, op=op):
            records, counts = prog(state.records, state.counts,
                                   delta.records, delta.counts)
            jax.block_until_ready(counts)
        self.folds += 1
        METRICS.counter("stream.folds").inc()
        return ShardedDataset(records=records, counts=counts,
                              mesh=state.mesh, axis=state.axis)


@dataclasses.dataclass
class StreamUpdate:
    """What one :meth:`IncrementalQuery.update` did."""

    epoch: int
    watermark: int
    new_splits: int
    fold_s: float
    dataset: ShardedDataset
    report: Optional[ActionReport] = None


class IncrementalQuery:
    """A continuously maintained keyed aggregate over a polled source.

    .. code-block:: python

        cont = ContinuousSource(fasta_source(inbox), mesh, capacity=512)
        query = IncrementalQuery(
            cont, lambda m: (m.map(image="kmer-stats", k=6)
                              .reduce_by_key(key_of, value_by=ones_of,
                                             op="sum")))
        while producing:
            query.update()                 # no-op when nothing arrived
        keys, (vals,), counts = query.collect()

    ``build`` applies the plan *suffix* to a fresh MaRe handle over each
    epoch's delta — it must build the same plan every epoch (module-level
    ``key_by``/``value_by`` callables, same images/params; enforced by
    signature check) and end in a ``reduce_by_key``.  ``executor`` is
    the runtime seam: pass a session's tenant executor (or use
    :meth:`repro.serve.session.Session.stream`) to get admission,
    fairness, batching, and per-refresh reports on the session's stream.
    """

    def __init__(self, source: ContinuousSource, build: Builder, *,
                 executor: Any = None,
                 plan_cache: Any = None,
                 reports: Optional[ReportLog] = None,
                 registry: Registry = DEFAULT_REGISTRY,
                 label: str = "stream",
                 persist_tier: str = "device") -> None:
        from repro.runtime.executor import DEFAULT_EXECUTOR
        self.source = source
        self.build = build
        self.executor = executor if executor is not None else DEFAULT_EXECUTOR
        self.plan_cache = plan_cache
        self.reports = reports if reports is not None else ReportLog()
        self.registry = registry
        self.label = label
        self.persist_tier = persist_tier
        self.fold_engine = FoldEngine()
        self._state: Optional[ShardedDataset] = None
        self._epoch = -1                 # watermark folded into state
        self._plan: Optional[Plan] = None
        self._plan_sig: Optional[Tuple] = None
        self._keyed: Optional[KeyedReduceStage] = None
        self._base: Optional[Lineage] = None
        self._generation: Optional[Lineage] = None

    # -- plan suffix ---------------------------------------------------------

    def _suffix(self, delta: ShardedDataset) -> MaRe:
        m = self.build(MaRe(delta, registry=self.registry,
                            plan_cache=self.plan_cache,
                            executor=self.executor,
                            _reports=self.reports))
        if not isinstance(m, MaRe):
            raise TypeError(f"build must return a MaRe chain, got "
                            f"{type(m).__name__}")
        plan = m.plan
        if plan.empty or not isinstance(plan.stages[-1], KeyedReduceStage):
            raise ValueError(
                "an IncrementalQuery plan must end in reduce_by_key — "
                "only a monoid-folded keyed table is incrementally "
                f"maintainable (got plan [{plan.describe()}])")
        if self._plan_sig is None:
            self._plan = plan
            self._plan_sig = plan.signature()
            self._keyed = plan.stages[-1]
            # base lineage of the maintained query: its canonical stage
            # signatures.  Generations extend it with the epoch watermark.
            self._base = Lineage(source=("stream-query", self.label),
                                 stages=self._plan_sig)
        elif plan.signature() != self._plan_sig:
            raise ValueError(
                "build produced a different plan than the previous epoch "
                "— an incremental query must apply the SAME suffix every "
                "epoch (use module-level key_by/value_by callables; "
                f"was [{self._plan.describe()}], now [{plan.describe()}])")
        return m

    # -- the update path -----------------------------------------------------

    def update(self) -> Optional[StreamUpdate]:
        """Poll once; when new splits arrived, ingest them, run the plan
        suffix over the delta, and fold the result into the maintained
        state.  Returns ``None`` when nothing arrived (nothing runs)."""
        batch = self.source.poll()
        if batch is None:
            return None
        return self.apply(batch)

    def apply(self, batch: EpochBatch) -> StreamUpdate:
        """Fold one epoch batch into the state (the non-polling half of
        :meth:`update`, for callers that already hold a batch)."""
        t0 = time.monotonic()
        with span("stream.update", epoch=batch.epoch,
                  splits=batch.num_splits, label=self.label):
            delta = self.source.ingest_epoch(batch)
            suffix = self._suffix(delta)
            table = suffix._materialize(
                label=f"{self.label} epoch {batch.epoch}")
            keyed = self._keyed
            f0 = time.monotonic()
            if self._state is None:
                folded = table
            else:
                folded = self.fold_engine.fold(
                    self._state, table, keyed.num_keys, keyed.op,
                    use_kernel=keyed.use_kernel)
            fold_s = time.monotonic() - f0
            self._install(folded, batch.epoch)
        update_s = time.monotonic() - t0
        METRICS.histogram("stream.update_s").observe(update_s)
        METRICS.histogram("stream.fold_s").observe(fold_s)
        METRICS.gauge("stream.watermark").set(batch.epoch)
        report = self.reports.latest
        if report is not None:
            # the epoch's counters ride the delta action's report through
            # the typed counter channel (shared dict: session-side clones
            # see them too)
            report.counters["stream.epoch"] = batch.epoch
            report.counters["stream.watermark"] = batch.epoch
            report.counters["stream.new_splits"] = batch.num_splits
            report.phases["stream.fold"] = fold_s
        return StreamUpdate(epoch=batch.epoch, watermark=batch.epoch,
                            new_splits=batch.num_splits, fold_s=fold_s,
                            dataset=self._state, report=report)

    def _install(self, folded: ShardedDataset, epoch: int) -> None:
        """Persist the new snapshot generation, drop the superseded one."""
        generation = stream_root(self._base, epoch)
        state = ShardedDataset(records=folded.records, counts=folded.counts,
                               mesh=folded.mesh, axis=folded.axis,
                               lineage=generation)
        self.executor.persist(state, tier=self.persist_tier)
        if self._generation is not None:
            self.executor.mat_cache.drop(self._generation)
        self._state = state
        self._generation = generation
        self._epoch = epoch

    # -- results -------------------------------------------------------------

    @property
    def state(self) -> Optional[ShardedDataset]:
        """The maintained keyed table (None before the first epoch)."""
        return self._state

    @property
    def epoch(self) -> int:
        """Watermark: highest epoch folded into the state (-1 = none)."""
        return self._epoch

    watermark = epoch

    def collect(self) -> Any:
        """Host copy of the maintained aggregate — the same
        ``(keys, values, counts)`` layout ``reduce_by_key().collect()``
        returns.  Raises before the first epoch."""
        if self._state is None:
            raise RuntimeError("IncrementalQuery has no state yet: no "
                               "epoch has arrived (call update() after "
                               "data lands)")
        return ds_lib.collect(self._state)

    def describe(self) -> str:
        plan = self._plan.describe() if self._plan is not None \
            else "<unbuilt>"
        gen = (f" @{self._generation.digest()}"
               if self._generation is not None else "")
        return (f"IncrementalQuery([{plan}]{gen}) "
                f"[incremental @ epoch {self._epoch}]")

    def __repr__(self) -> str:
        return self.describe()
