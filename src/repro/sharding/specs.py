"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP per (arch, shape).

Tensors are annotated with *logical* axis names ("embed", "heads", "ff",
"experts", "batch", "seq", ...).  A :class:`Rules` object maps logical axes
to mesh axes, refusing any mapping that does not divide the dimension
(e.g. 25 hymba heads never shard over a 16-way model axis — the rule
silently degrades to replication, and the roofline table shows the cost).

Activated via a context manager so model code stays annotation-only:

    with use_rules(rules, mesh):
        logits = model(params, tokens)   # constraints applied inside
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis name -> mesh axis (or tuple of mesh axes)."""

    table: Dict[str, MeshAxes]
    mesh_shape: Dict[str, int]

    def mesh_size(self, mesh_axes: MeshAxes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            return self.mesh_shape.get(mesh_axes, 1)
        out = 1
        for a in mesh_axes:
            out *= self.mesh_shape.get(a, 1)
        return out

    def spec_for(self, logical: Sequence[Optional[str]],
                 dims: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor with the given logical axes.

        If ``dims`` is given, any mapping that does not evenly divide the
        dimension is dropped (replication) — divisibility-safe TP.
        Duplicate mesh axes across dims are dropped (a mesh axis may be
        used once per spec)."""
        used: set = set()
        out = []
        for i, name in enumerate(logical):
            axes = self.table.get(name) if name else None
            if axes is None:
                out.append(None)
                continue
            ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
            ax_tuple = tuple(a for a in ax_tuple if a not in used)
            if not ax_tuple:
                out.append(None)
                continue
            size = self.mesh_size(ax_tuple)
            if dims is not None and dims[i] % size != 0:
                out.append(None)
                continue
            used.update(ax_tuple)
            out.append(ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple)
        return P(*out)


_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    tok = _ACTIVE.set((rules, mesh))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active() -> Tuple[Optional[Rules], Optional[Mesh]]:
    cur = _ACTIVE.get()
    return cur if cur is not None else (None, None)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint for the active rules (no-op outside)."""
    rules, mesh = active()
    if rules is None or mesh is None:
        return x
    spec = rules.spec_for(logical, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------

def make_rules(mesh: Mesh, *, fsdp: bool = True,
               seq_shard: bool = True,
               batch_axes: MeshAxes = "data") -> Rules:
    """Default DP(+FSDP) x TP(+EP) rules for a ("data", "model") or
    ("pod", "data", "model") mesh.

    - batch       -> data (+pod if present)
    - seq         -> model (sequence/context parallelism for activations)
    - embed       -> data for weights (FSDP / ZeRO-3; gathered on use)
    - heads/ff    -> model (tensor parallelism)
    - experts     -> model (expert parallelism; MaRe repartition_by)
    - vocab       -> model (sharded logits + distributed softmax)
    """
    shape = dict(mesh.shape)
    has_pod = "pod" in shape
    batch = (("pod", "data") if has_pod else "data") if batch_axes == "data" \
        else batch_axes
    table: Dict[str, MeshAxes] = {
        "batch": batch,
        "seq": "model" if seq_shard else None,
        "embed": "data" if fsdp else None,
        "embed_pod": ("pod", "data") if (fsdp and has_pod) else (
            "data" if fsdp else None),
        "heads": "model",
        "kv": "model",
        "hd": None,
        "ff": "model",
        "experts": "model",
        "expert_ff": "data" if fsdp else None,
        "vocab": "model",
        "kv_seq": "model",
        "layers": None,
        "conv": None,
        "state": None,
    }
    return Rules(table=table, mesh_shape=shape)


def data_only_rules(mesh: Mesh) -> Rules:
    """Pure-DP rules (small models / paper-faithful MaRe tree grad sync)."""
    shape = dict(mesh.shape)
    axes = tuple(a for a in ("pod", "data", "model") if a in shape)
    table: Dict[str, MeshAxes] = {k: None for k in (
        "seq", "embed", "embed_pod", "heads", "kv", "hd", "ff", "experts",
        "expert_ff", "vocab", "kv_seq", "layers", "conv", "state")}
    table["batch"] = axes if len(axes) > 1 else (axes[0] if axes else None)
    return Rules(table=table, mesh_shape=shape)
