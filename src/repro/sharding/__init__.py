from repro.sharding.specs import (Rules, active, constrain, data_only_rules,
                                  make_rules, use_rules)

__all__ = ["Rules", "active", "constrain", "data_only_rules", "make_rules",
           "use_rules"]
