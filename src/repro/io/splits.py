"""InputSplit planning: carve files into per-shard byte ranges.

The Hadoop InputFormat analogue: each file is cut into ``split_bytes``
ranges; :func:`assign_splits` then bin-packs splits onto shards by byte
length (longest-processing-time greedy) so each shard fetches only its own
byte ranges — locality by construction, balanced by size.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.io.backends import StorageBackend

DEFAULT_SPLIT_BYTES = 1 << 20


@dataclasses.dataclass(frozen=True)
class InputSplit:
    """A byte range ``[start, stop)`` of one stored object."""

    path: str
    start: int
    stop: int
    file_size: int

    @property
    def length(self) -> int:
        return self.stop - self.start


def plan_splits(backend: StorageBackend,
                paths: Optional[Sequence[str]] = None,
                split_bytes: int = DEFAULT_SPLIT_BYTES,
                num_splits: Optional[int] = None) -> List[InputSplit]:
    """Carve ``paths`` (default: everything the backend lists) into splits.

    ``num_splits`` overrides ``split_bytes`` with ``ceil(total/num_splits)``
    (at least one split per file either way).
    """
    paths = list(paths) if paths is not None else backend.list()
    sizes = {p: backend.size(p) for p in paths}
    if num_splits is not None:
        total = sum(sizes.values())
        split_bytes = max(1, math.ceil(total / max(1, num_splits)))
    out: List[InputSplit] = []
    for p in paths:
        size = sizes[p]
        if size == 0:
            continue
        nchunks = max(1, math.ceil(size / split_bytes))
        chunk = math.ceil(size / nchunks)
        for start in range(0, size, chunk):
            out.append(InputSplit(path=p, start=start,
                                  stop=min(start + chunk, size),
                                  file_size=size))
    return out


def assign_splits(splits: Sequence[InputSplit], num_shards: int
                  ) -> List[List[InputSplit]]:
    """Greedy LPT bin packing of splits onto shards (balance by bytes).

    Within each shard, splits keep global plan order so record order is
    deterministic.
    """
    bins: List[List[int]] = [[] for _ in range(num_shards)]
    load = [0] * num_shards
    order = sorted(range(len(splits)), key=lambda i: -splits[i].length)
    for i in order:
        s = min(range(num_shards), key=lambda b: load[b])
        bins[s].append(i)
        load[s] += splits[i].length
    return [[splits[i] for i in sorted(b)] for b in bins]
