"""Out-of-core wave execution: datasets larger than device capacity.

A :class:`WaveRunner` streams a :class:`~repro.io.source.DataSource`
through a MaRe map(+reduce) pipeline in *waves*: each wave ingests a
byte-budgeted group of splits into one on-device ``ShardedDataset``, runs
the pipeline, and releases the wave.  Per-wave reduce outputs are folded
with the same (required-associative+commutative) combiner in a final MaRe
reduce, so ``collect`` over a source that never fits on device at once is
exact.  Wave *w+1* ingestion overlaps wave *w* compute via the
:class:`~repro.data.pipeline.Prefetcher` (one-wave lookahead buffer).

Each wave executes the pipeline as ONE fused ``shard_map`` program via
:mod:`repro.core.planner`; because ingestion buckets wave geometry
(capacity/width rounding in :mod:`repro.io.ingest`) and the plan compile
cache keys on (stage structure, shapes, mesh), the pipeline compiles once
and every same-shaped wave is a cache hit — ``stats["programs_compiled"]``
records how many distinct programs a run actually built.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro import compat
from repro.core import planner as planner_lib
from repro.core.container import Registry, DEFAULT_REGISTRY
from repro.core.mare import MaRe
from repro.data.pipeline import Prefetcher
from repro.io.ingest import ingest
from repro.io.source import DataSource
from repro.io.splits import InputSplit


def plan_waves(splits: Sequence[InputSplit], wave_bytes: Optional[int]
               ) -> List[List[InputSplit]]:
    """Group splits (plan order) into waves of at most ``wave_bytes`` each
    (always at least one split per wave); ``None`` -> a single wave."""
    if wave_bytes is None:
        return [list(splits)] if splits else []
    waves: List[List[InputSplit]] = []
    cur: List[InputSplit] = []
    cur_bytes = 0
    for sp in splits:
        if cur and cur_bytes + sp.length > wave_bytes:
            waves.append(cur)
            cur, cur_bytes = [], 0
        cur.append(sp)
        cur_bytes += sp.length
    if cur:
        waves.append(cur)
    return waves


class WaveRunner:
    """MaRe-shaped pipeline builder executed wave-by-wave.

    .. code-block:: python

        total = (WaveRunner(fasta_source("genome.fa"), wave_bytes=1 << 20)
                 .map(image="ubuntu", command="grep-chars GC")
                 .reduce(image="ubuntu", command="awk-sum")
                 .collect())
    """

    def __init__(self, source: DataSource, mesh=None, axis: str = "data",
                 wave_bytes: Optional[int] = None,
                 workers: Optional[int] = None,
                 capacity: Optional[int] = None,
                 width: Optional[int] = None,
                 registry: Registry = DEFAULT_REGISTRY,
                 prefetch: bool = True,
                 plan_cache: Optional["planner_lib.PlanCache"] = None):
        if mesh is None:
            mesh = compat.make_mesh((jax.device_count(),), (axis,))
        self.source = source
        self.mesh = mesh
        self.axis = axis
        self.wave_bytes = wave_bytes
        self.workers = workers
        self.capacity = capacity
        self.width = width
        self.registry = registry
        self.prefetch = prefetch
        self.plan_cache = plan_cache
        self._maps: List[Dict[str, Any]] = []
        self._reduce: Optional[Dict[str, Any]] = None
        self.stats: Dict[str, Any] = {}

    # -- pipeline spec (MaRe-API mirror) ------------------------------------

    def map(self, **kwargs: Any) -> "WaveRunner":
        if self._reduce is not None:
            raise ValueError("map after reduce is not supported in waves")
        self._maps.append(kwargs)
        return self

    def reduce(self, **kwargs: Any) -> "WaveRunner":
        if self._reduce is not None:
            raise ValueError("only one reduce stage per wave pipeline")
        self._reduce = kwargs
        return self

    # -- execution -----------------------------------------------------------

    def waves(self) -> List[List[InputSplit]]:
        return plan_waves(self.source.splits(), self.wave_bytes)

    def _pipeline(self, ds) -> MaRe:
        m = MaRe(ds, registry=self.registry, plan_cache=self.plan_cache)
        for kw in self._maps:
            m = m.map(**kw)
        if self._reduce is not None:
            m = m.reduce(**self._reduce)
        return m

    def _run_wave(self, ds) -> Any:
        m = self._pipeline(ds)
        if self._reduce is not None:
            return m.collect_first_shard()
        return m.collect()

    def _ingest_wave(self, wave: Sequence[InputSplit]):
        return ingest(self.source, self.mesh, axis=self.axis,
                      capacity=self.capacity, width=self.width,
                      workers=self.workers, splits=wave)

    def collect(self) -> Any:
        """Run all waves and return the folded (reduced) or concatenated
        (map-only) result as host arrays."""
        waves = self.waves()
        self.stats = {"num_waves": len(waves),
                      "num_splits": sum(len(w) for w in waves)}
        if not waves:
            raise ValueError("source produced no input splits")
        cache = (self.plan_cache if self.plan_cache is not None
                 else planner_lib.DEFAULT_CACHE)
        cache_before = cache.stats()

        outputs: List[Any] = []
        if self.prefetch and len(waves) > 1:
            # one-wave lookahead: wave w+1 fetch/pack/transfer overlaps
            # wave w compute (at most two waves resident at once)
            pf = Prefetcher(
                lambda: (self._ingest_wave(w) for w in waves), capacity=1)
            try:
                for _ in waves:
                    outputs.append(self._run_wave(next(pf)))
            finally:
                pf.close()
        else:
            for w in waves:
                outputs.append(self._run_wave(self._ingest_wave(w)))

        def snap_cache_stats():
            # taken at every return so the cross-wave fold program (when
            # it runs) is counted too
            cache_after = cache.stats()
            self.stats["programs_compiled"] = (cache_after["misses"]
                                               - cache_before["misses"])
            self.stats["program_cache_hits"] = (cache_after["hits"]
                                                - cache_before["hits"])

        if len(outputs) == 1:
            snap_cache_stats()
            return outputs[0]

        def cat(*ls):
            ls = [np.asarray(l) for l in ls]
            # waves may pack different record widths; pad trailing dims to
            # the common max before concatenating along records
            tail = tuple(max(l.shape[d] for l in ls)
                         for d in range(1, ls[0].ndim))
            ls = [np.pad(l, [(0, 0)] + [(0, t - s) for t, s in
                                        zip(tail, l.shape[1:])])
                  for l in ls]
            return np.concatenate(ls, axis=0)

        stacked = jax.tree.map(cat, *outputs)
        if self._reduce is None:
            snap_cache_stats()
            return stacked
        # fold per-wave partials with the same associative combiner
        fold = MaRe(stacked, mesh=self.mesh, axis=self.axis,
                    registry=self.registry,
                    plan_cache=self.plan_cache).reduce(**self._reduce)
        out = fold.collect_first_shard()
        snap_cache_stats()
        return out
