"""Out-of-core wave execution: datasets larger than device capacity.

A :class:`WaveRunner` streams a :class:`~repro.io.source.DataSource`
through a MaRe map(+reduce) pipeline in *waves*: each wave ingests a
byte-budgeted group of splits into one on-device ``ShardedDataset``, runs
the pipeline, and releases the wave.  Per-wave reduce outputs are folded
with the same (required-associative+commutative) combiner in a final MaRe
reduce, so ``collect`` over a source that never fits on device at once is
exact.

The wave loop runs on the SAME engine as every other MaRe action
(:class:`repro.runtime.Executor`): each wave's pipeline is submitted as
an async action on the executor's bounded dispatch queue, so wave *w*'s
compile + device execution (executor thread) overlaps wave *w+1*'s
fetch/pack/transfer (main thread behind the
:class:`~repro.data.pipeline.Prefetcher`), and every wave appends its
:class:`~repro.runtime.reports.ActionReport` to one shared diagnostics
channel (``runner.reports``).

Because ingestion buckets wave geometry (capacity/width rounding in
:mod:`repro.io.ingest`) and the plan compile cache keys on (stage
structure, shapes, mesh), the pipeline compiles once and every
same-shaped wave is a cache hit — ``stats["programs_compiled"]`` records
how many distinct programs a run actually built.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro import compat
from repro.core import planner as planner_lib
from repro.core.container import Registry, DEFAULT_REGISTRY
from repro.core.mare import MaRe
from repro.data.pipeline import Prefetcher
from repro.io.ingest import ingest
from repro.io.source import DataSource
from repro.io.splits import InputSplit
from repro.obs import METRICS, span
from repro.runtime.executor import DEFAULT_EXECUTOR, Executor
from repro.runtime.reports import ReportLog


def plan_waves(splits: Sequence[InputSplit], wave_bytes: Optional[int]
               ) -> List[List[InputSplit]]:
    """Group splits (plan order) into waves of at most ``wave_bytes`` each
    (always at least one split per wave); ``None`` -> a single wave."""
    if wave_bytes is None:
        return [list(splits)] if splits else []
    waves: List[List[InputSplit]] = []
    cur: List[InputSplit] = []
    cur_bytes = 0
    for sp in splits:
        if cur and cur_bytes + sp.length > wave_bytes:
            waves.append(cur)
            cur, cur_bytes = [], 0
        cur.append(sp)
        cur_bytes += sp.length
    if cur:
        waves.append(cur)
    return waves


class WaveRunner:
    """MaRe-shaped pipeline builder executed wave-by-wave.

    .. code-block:: python

        total = (WaveRunner(fasta_source("genome.fa"), wave_bytes=1 << 20)
                 .map(image="ubuntu", command="grep-chars GC")
                 .reduce(image="ubuntu", command="awk-sum")
                 .collect())
    """

    def __init__(self, source: DataSource, mesh=None, axis: str = "data",
                 wave_bytes: Optional[int] = None,
                 workers: Optional[int] = None,
                 capacity: Optional[int] = None,
                 width: Optional[int] = None,
                 registry: Registry = DEFAULT_REGISTRY,
                 prefetch: bool = True,
                 plan_cache: Optional["planner_lib.PlanCache"] = None,
                 executor: Optional[Executor] = None,
                 parser: str = "vectorized"):
        if mesh is None:
            mesh = compat.make_mesh((jax.device_count(),), (axis,))
        self.source = source
        self.mesh = mesh
        self.axis = axis
        self.wave_bytes = wave_bytes
        self.workers = workers
        self.capacity = capacity
        self.width = width
        #: Framing implementation forwarded to every wave's ingest —
        #: "vectorized" columnar RecordBatch (default) or the "legacy"
        #: per-line oracle; waves inherit the columnar win wholesale.
        self.parser = parser
        self.registry = registry
        self.prefetch = prefetch
        self.plan_cache = plan_cache
        self.executor = executor if executor is not None else DEFAULT_EXECUTOR
        #: One diagnostics channel for the whole run: every wave action
        #: (and the cross-wave fold) appends its ActionReport here.
        self.reports = ReportLog()
        self._maps: List[Dict[str, Any]] = []
        self._reduce: Optional[Dict[str, Any]] = None
        self.stats: Dict[str, Any] = {}

    # -- pipeline spec (MaRe-API mirror) ------------------------------------

    def map(self, **kwargs: Any) -> "WaveRunner":
        if self._reduce is not None:
            raise ValueError("map after reduce is not supported in waves")
        self._maps.append(kwargs)
        return self

    def reduce(self, **kwargs: Any) -> "WaveRunner":
        if self._reduce is not None:
            raise ValueError("only one reduce stage per wave pipeline")
        self._reduce = kwargs
        return self

    # -- execution -----------------------------------------------------------

    def waves(self) -> List[List[InputSplit]]:
        return plan_waves(self.source.splits(), self.wave_bytes)

    def _pipeline(self, ds) -> MaRe:
        m = MaRe(ds, registry=self.registry, plan_cache=self.plan_cache,
                 executor=self.executor, _reports=self.reports)
        for kw in self._maps:
            m = m.map(**kw)
        if self._reduce is not None:
            m = m.reduce(**self._reduce)
        return m

    def _submit_wave(self, ds, idx: int):
        """Queue one wave's pipeline on the executor's dispatch thread
        (bounded queue: backpressure once ``max_pending`` waves are in
        flight) and return its ActionHandle."""
        m = self._pipeline(ds)
        label = f"wave {idx}"
        shard = 0 if self._reduce is not None else None
        return m.collect(shard=shard, asynchronous=True, label=label)

    def _ingest_wave(self, wave: Sequence[InputSplit],
                     idx: Optional[int] = None):
        with span("wave.ingest", index=idx, splits=len(wave)):
            return ingest(self.source, self.mesh, axis=self.axis,
                          capacity=self.capacity, width=self.width,
                          workers=self.workers, splits=wave,
                          parser=self.parser)

    def _await_wave(self, handle, idx: int):
        """Block for one wave's async action; the wave span links the
        wave index to the ActionReport the executor recorded for it."""
        with span("wave", index=idx) as sp:
            out = handle.result()
            rep = handle.report
            if rep is not None:
                sp.set(action_id=rep.action_id,
                       action_wall_s=rep.wall_s,
                       queue_wait_s=rep.queue_wait_s)
        METRICS.counter("waves.completed").inc()
        return out

    def collect(self) -> Any:
        """Run all waves and return the folded (reduced) or concatenated
        (map-only) result as host arrays."""
        waves = self.waves()
        self.stats = {"num_waves": len(waves),
                      "num_splits": sum(len(w) for w in waves)}
        if not waves:
            raise ValueError("source produced no input splits")
        cache = (self.plan_cache if self.plan_cache is not None
                 else planner_lib.DEFAULT_CACHE)
        cache_before = cache.stats()

        reports_before = self.reports.appended
        outputs: List[Any] = []
        if self.prefetch and len(waves) > 1:
            # one-wave ingest lookahead (Prefetcher) + async dispatch:
            # wave w's compile+compute (executor thread) overlaps wave
            # w+1's fetch/pack/transfer (prefetcher thread).  Wave w's
            # result is awaited BEFORE pulling wave w+1 off the
            # prefetcher, preserving the pre-runtime out-of-core memory
            # bound: at most the computing wave plus the one the
            # prefetcher is ingesting are device-resident.
            pf = Prefetcher(
                lambda: (self._ingest_wave(w, i)
                         for i, w in enumerate(waves)), capacity=1)
            try:
                pending = None
                for i in range(len(waves)):
                    if pending is not None:
                        outputs.append(self._await_wave(pending, i - 1))
                    pending = self._submit_wave(next(pf), i)
                outputs.append(self._await_wave(pending, len(waves) - 1))
            finally:
                pf.close()
        else:
            for i, w in enumerate(waves):
                outputs.append(self._await_wave(
                    self._submit_wave(self._ingest_wave(w, i), i), i))

        def snap_stats():
            # taken at every return so the cross-wave fold program (when
            # it runs) is counted too
            cache_after = cache.stats()
            self.stats["programs_compiled"] = (cache_after["misses"]
                                               - cache_before["misses"])
            self.stats["program_cache_hits"] = (cache_after["hits"]
                                                - cache_before["hits"])
            # lifetime append counter, not len(): the ReportLog deque is
            # bounded, so len() would undercount runs with many waves
            self.stats["actions"] = (self.reports.appended
                                     - reports_before)

        if len(outputs) == 1:
            snap_stats()
            return outputs[0]

        def cat(*ls):
            ls = [np.asarray(x) for x in ls]
            # waves may pack different record widths; pad trailing dims to
            # the common max before concatenating along records
            tail = tuple(max(x.shape[d] for x in ls)
                         for d in range(1, ls[0].ndim))
            ls = [np.pad(x, [(0, 0)] + [(0, t - s) for t, s in
                                        zip(tail, x.shape[1:])])
                  for x in ls]
            return np.concatenate(ls, axis=0)

        stacked = jax.tree.map(cat, *outputs)
        if self._reduce is None:
            snap_stats()
            return stacked
        # fold per-wave partials with the same associative combiner — a
        # plain MaRe action on the same executor/report channel
        fold = MaRe(stacked, mesh=self.mesh, axis=self.axis,
                    registry=self.registry, plan_cache=self.plan_cache,
                    executor=self.executor,
                    _reports=self.reports).reduce(**self._reduce)
        out = fold.collect(shard=0)
        snap_stats()
        return out
