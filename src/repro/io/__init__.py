"""repro.io — heterogeneous-storage ingestion and out-of-core execution.

The paper's ingestion story (Fig. 5: HDFS co-located, Swift same-DC, S3
remote) realized as a real subsystem:

* :mod:`repro.io.backends` — ``StorageBackend`` protocol (``list`` /
  ``size`` / ``read_range``) with a real ``LocalFS`` plus emulated
  ``HDFS`` / ``Swift`` / ``S3`` backends carrying the paper's latency
  profiles.
* :mod:`repro.io.formats` — line-delimited text, FASTA and SMILES record
  readers framing splits into columnar ``RecordBatch`` offsets
  (vectorized, zero-copy) and packing them into the fixed-shape
  ``{"data": [cap, width] uint8, "len": [cap] int32}`` contract that
  static-SPMD :class:`~repro.core.dataset.ShardedDataset` assumes.
* :mod:`repro.io.splits` — InputSplit planning: files are carved into
  byte-range splits so each shard fetches only its own data (locality by
  construction, Hadoop InputFormat analogue).
* :mod:`repro.io.source` — ``DataSource``: backend + format + split plan.
* :mod:`repro.io.ingest` — parallel fetch pool + per-shard
  ``jax.device_put`` producing a ``ShardedDataset``
  (``MaRe.from_source`` entry point).
* :mod:`repro.io.waves` — out-of-core wave executor: streams a source
  bigger than one ``ShardedDataset`` through a map+reduce pipeline in
  waves, folding per-wave reduce outputs with the associative combiner.
"""
from repro.io.backends import (BACKEND_PROFILES, EmulatedObjectStore, HDFS,
                               LocalFS, S3, StorageBackend, Swift,
                               make_backend)
from repro.io.formats import (FastaFormat, LineFormat, RecordBatch,
                              RecordFormat, SmilesFormat, pack_batches,
                              pack_records, unpack_records)
from repro.io.ingest import default_workers, ingest
from repro.io.source import (DataSource, fasta_source, smiles_source,
                             text_source)
from repro.io.splits import InputSplit, assign_splits, plan_splits
from repro.io.waves import WaveRunner, plan_waves

__all__ = [
    "StorageBackend", "LocalFS", "EmulatedObjectStore", "HDFS", "Swift",
    "S3", "BACKEND_PROFILES", "make_backend",
    "RecordFormat", "RecordBatch", "LineFormat", "FastaFormat",
    "SmilesFormat", "pack_batches", "pack_records", "unpack_records",
    "InputSplit", "plan_splits", "assign_splits",
    "DataSource", "text_source", "fasta_source", "smiles_source",
    "ingest", "default_workers", "WaveRunner", "plan_waves",
]
