"""DataSource: a storage backend + record format + split plan.

The handle passed to ``MaRe.from_source`` / :class:`~repro.io.waves.
WaveRunner` — everything ingestion needs to enumerate and fetch a dataset,
with no data touched until ingest time.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.io.backends import StorageBackend, make_backend
from repro.io.formats import (FastaFormat, LineFormat, RecordFormat,
                              SmilesFormat)
from repro.io.splits import (DEFAULT_SPLIT_BYTES, InputSplit, plan_splits)


@dataclasses.dataclass
class DataSource:
    backend: StorageBackend
    fmt: RecordFormat
    paths: Optional[Sequence[str]] = None
    split_bytes: int = DEFAULT_SPLIT_BYTES

    def splits(self) -> List[InputSplit]:
        return plan_splits(self.backend, self.paths, self.split_bytes)

    def total_bytes(self) -> int:
        return sum(s.length for s in self.splits())

    def with_splits(self, split_bytes: int) -> "DataSource":
        return dataclasses.replace(self, split_bytes=split_bytes)


def _resolve_backend(backend: Union[str, StorageBackend], root: str
                     ) -> StorageBackend:
    if isinstance(backend, StorageBackend):
        return backend
    return make_backend(backend, root)


def text_source(root: str, backend: Union[str, StorageBackend] = "local",
                split_bytes: int = DEFAULT_SPLIT_BYTES) -> DataSource:
    """Line-delimited text under ``root`` (file or directory)."""
    return DataSource(_resolve_backend(backend, root), LineFormat(),
                      split_bytes=split_bytes)


def fasta_source(root: str, backend: Union[str, StorageBackend] = "local",
                 split_bytes: int = DEFAULT_SPLIT_BYTES) -> DataSource:
    """FASTA sequence data under ``root``."""
    return DataSource(_resolve_backend(backend, root), FastaFormat(),
                      split_bytes=split_bytes)


def smiles_source(root: str, backend: Union[str, StorageBackend] = "local",
                  split_bytes: int = DEFAULT_SPLIT_BYTES) -> DataSource:
    """SMILES molecule files under ``root``."""
    return DataSource(_resolve_backend(backend, root), SmilesFormat(),
                      split_bytes=split_bytes)
