"""Record formats: framing + packing for byte-oriented datasets.

A :class:`RecordFormat` turns a byte-range split into complete records
(Hadoop RecordReader analogue) and :func:`pack_batches` packs the
variable-length records into the fixed-shape static-SPMD contract the rest
of the stack assumes:

    {"data": uint8 [capacity, width], "len": int32 [capacity]}

The hot path is **columnar**: :meth:`RecordFormat.read_split_batch`
returns a :class:`RecordBatch` — one contiguous ``uint8`` payload buffer
plus ``starts``/``lens`` int32 offset arrays — produced by vectorized
framing (``np.frombuffer`` the payload once, newline offsets via
``np.flatnonzero(buf == 0x0A)``, then a per-format offset-array
transform).  No per-record ``bytes`` objects are materialized between
storage and the packed device buffer; :func:`pack_batches` turns a list
of batches into the ``[cap, width]`` array with one masked
advanced-indexing gather per batch.  The legacy per-line path
(:meth:`RecordFormat.read_split` / :func:`pack_records`) is kept as the
parity oracle — the property tests in ``tests/test_io.py`` pin the two
paths byte-identical.

Split-boundary rule (classic InputFormat semantics): a record is owned by
the split containing its **first byte**.  A reader starting mid-file
discards the partial leading record (it belongs to the previous split) and
reads past its end offset to finish its last record, so every record is
read exactly once regardless of how files are carved.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schema import Schema, bytes_record_schema
from repro.io.backends import StorageBackend
from repro.io.splits import InputSplit

_READAHEAD = 1 << 16

#: byte -> "is ASCII whitespace" lookup (the set ``bytes.strip()`` uses),
#: for whole-payload masks; per-row edge tests use :func:`_is_ws` on
#: gathered bytes instead (O(rows), not O(payload))
_WS_TABLE = np.zeros(256, np.bool_)
_WS_TABLE[[0x09, 0x0A, 0x0B, 0x0C, 0x0D, 0x20]] = True

_EMPTY_U8 = np.empty(0, np.uint8)
_EMPTY_I32 = np.empty(0, np.int32)


def _is_ws(vals: np.ndarray) -> np.ndarray:
    """ASCII-whitespace test on gathered row-edge bytes (newlines never
    appear inside a framed row, but including 0x0A keeps this total)."""
    return ((vals == 0x20) | (vals == 0x09) | (vals == 0x0A)
            | (vals == 0x0D) | (vals == 0x0B) | (vals == 0x0C))


@dataclasses.dataclass(frozen=True)
class RecordBatch:
    """Columnar framed records: views into one contiguous payload buffer.

    ``buf`` is the split's raw payload (``np.frombuffer`` — zero-copy);
    record *i* is ``buf[starts[i] : starts[i] + lens[i]]``.  Framing and
    format selection are offset-array transforms, so a batch never owns
    per-record ``bytes`` objects.
    """

    buf: np.ndarray      # uint8 [payload_bytes]
    starts: np.ndarray   # int32 [n]
    lens: np.ndarray     # int32 [n]

    def __len__(self) -> int:
        return int(self.starts.shape[0])

    @property
    def max_len(self) -> int:
        """Longest record in the batch (0 for an empty batch)."""
        return int(self.lens.max()) if self.lens.size else 0

    @property
    def payload_bytes(self) -> int:
        return int(self.buf.size)

    def to_list(self) -> List[bytes]:
        """Materialize per-record ``bytes`` (tests/debugging only)."""
        return [bytes(self.buf[s:s + ln]) for s, ln in
                zip(self.starts.tolist(), self.lens.tolist())]

    @classmethod
    def empty(cls) -> "RecordBatch":
        return cls(_EMPTY_U8, _EMPTY_I32, _EMPTY_I32)

    @classmethod
    def from_records(cls, records: Sequence[bytes]) -> "RecordBatch":
        """Columnarize a record list (legacy-path bridge and tests)."""
        if not records:
            return cls.empty()
        lens = np.asarray([len(r) for r in records], np.int32)
        starts = np.zeros(len(records), np.int32)
        np.cumsum(lens[:-1], out=starts[1:])
        buf = np.frombuffer(b"".join(records), np.uint8)
        return cls(buf, starts, lens)


class RecordFormat:
    """Line-framed record reader; subclasses refine record extraction."""

    name = "base"

    @property
    def schema(self) -> Schema:
        """The record schema :func:`pack_batches` output satisfies — the
        same ``{"data": u8[W], "len": i32}`` contract byte-oriented image
        manifests declare as their input, so an ingested dataset
        type-checks against e.g. ``grep-chars``/``kmer-stats`` at plan
        time (``W`` binds to the packed width)."""
        return bytes_record_schema()

    # -- legacy per-line path (parity oracle) --------------------------------

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        """Map complete, newline-stripped lines to records."""
        raise NotImplementedError  # pragma: no cover - abstract

    def parse(self, payload: bytes) -> List[bytes]:
        """Records in a payload that starts and ends on record boundaries."""
        lines = [ln for ln in payload.split(b"\n")]
        if lines and lines[-1] == b"":
            lines.pop()
        return self.records_from_lines(lines)

    def read_split(self, backend: StorageBackend, split: InputSplit,
                   readahead: int = _READAHEAD) -> List[bytes]:
        """All records whose first byte lies in ``[split.start,
        split.stop)``, as a ``bytes`` list (legacy per-line path)."""
        payload = self.read_payload(backend, split, readahead)
        return self.parse(payload) if payload else []

    # -- columnar path -------------------------------------------------------

    def _select(self, buf: np.ndarray, starts: np.ndarray,
                ends: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Format-specific offset transform: framed line extents
        ``[starts, ends)`` -> record ``(starts, lens)``."""
        raise NotImplementedError  # pragma: no cover - abstract

    def frame(self, payload: bytes) -> RecordBatch:
        """Vectorized :meth:`parse`: one ``frombuffer``, newline offsets
        via ``flatnonzero``, then the per-format offset transform."""
        buf = np.frombuffer(payload, np.uint8)
        if buf.size == 0:
            return RecordBatch.empty()
        nl = np.flatnonzero(buf == 0x0A)
        # line i spans [starts[i], ends[i]); a trailing newline would
        # open a phantom zero-length line past the buffer — parse() pops
        # it, here it simply never gets an extent
        if buf[-1] == 0x0A:
            ends = nl
        else:
            ends = np.concatenate([nl, [buf.size]])
        starts = np.concatenate([[0], nl + 1])[:ends.size]
        rec_starts, rec_lens = self._select(buf, starts, ends)
        return RecordBatch(buf, rec_starts.astype(np.int32),
                           rec_lens.astype(np.int32))

    def read_split_batch(self, backend: StorageBackend, split: InputSplit,
                         readahead: int = _READAHEAD) -> RecordBatch:
        """Columnar :meth:`read_split`: the split's records as a
        :class:`RecordBatch` (the ingest hot path)."""
        return self.frame(self.read_payload(backend, split, readahead))

    # -- shared payload reader ----------------------------------------------

    def read_payload(self, backend: StorageBackend, split: InputSplit,
                     readahead: int = _READAHEAD) -> bytes:
        """The split's record-aligned payload: head-trimmed past the
        previous split's partial record, tail-extended through the final
        record's newline.  Shared by both parse paths."""
        size = split.file_size
        if split.start > 0:
            # peek one byte back: if byte start-1 is a newline, a record
            # begins exactly at `start` and is ours; otherwise we are
            # mid-record and the partial head belongs to the previous
            # split — skip through the first newline.
            data = backend.read_range(split.path, split.start - 1,
                                      split.stop)
            if data[:1] == b"\n":
                data = data[1:]
            else:
                nl = data.find(b"\n")
                if nl < 0:
                    # the record containing split.start extends past
                    # split.stop; it is owned by an earlier split.
                    return b""
                data = data[nl + 1:]
        else:
            data = backend.read_range(split.path, 0, split.stop)
        # empty after head-trim: the split's last byte was the terminating
        # newline of a record owned by an earlier split, and the next
        # record starts at `stop` — owned by the next split.
        if not data:
            return b""
        # extend past stop to finish the final record; chunks accumulate
        # in a list and join once (appending to `data` would recopy the
        # whole payload per readahead iteration — quadratic on records
        # spanning many readahead windows)
        chunks = [data]
        pos = split.stop
        while pos < size and not chunks[-1].endswith(b"\n"):
            extra = backend.read_range(split.path, pos,
                                       min(pos + readahead, size))
            if not extra:
                break
            nl = extra.find(b"\n")
            if nl >= 0:
                chunks.append(extra[:nl + 1])
                break
            chunks.append(extra)
            pos += len(extra)
        return b"".join(chunks) if len(chunks) > 1 else data


def _strip_extents(buf: np.ndarray, starts: np.ndarray, ends: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Whitespace-strip line extents (``bytes.strip`` semantics): trim
    whitespace off both row edges by iterated O(rows) edge-byte gathers
    (each pass advances every row that still has a whitespace edge, so
    iterations = longest edge run — 0 or 1 on clean data).  Returns
    ``(keep, starts, ends)``: the stripped-nonempty row mask plus the
    trimmed extents (unfiltered — index with ``keep`` as needed)."""
    s = starts.astype(np.int64, copy=True)
    e = ends.astype(np.int64, copy=True)
    top = max(buf.size - 1, 0)
    while True:
        m = (s < e) & _is_ws(buf[np.minimum(s, top)])
        if not m.any():
            break
        s[m] += 1
    while True:
        m = (e > s) & _is_ws(buf[np.maximum(e - 1, 0)])
        if not m.any():
            break
        e[m] -= 1
    return e > s, s, e


class LineFormat(RecordFormat):
    """Line-delimited text: every non-empty line is one record."""

    name = "text"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        return [ln for ln in lines if ln.strip()]

    def _select(self, buf, starts, ends):
        # records keep the line UNSTRIPPED (parity with the oracle above:
        # strip() is only the blank-line test)
        keep, _, _ = _strip_extents(buf, starts, ends)
        return starts[keep], (ends - starts)[keep]


class FastaFormat(RecordFormat):
    """FASTA: header lines (``>``) are dropped; each sequence line is one
    record (a fixed-width-friendly chunking of the sequence — exact for
    any per-base statistic such as GC count)."""

    name = "fasta"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        out = []
        for ln in lines:
            ln = ln.strip()
            if ln and not ln.startswith(b">") and not ln.startswith(b";"):
                out.append(ln)
        return out

    def _select(self, buf, starts, ends):
        keep, s, e = _strip_extents(buf, starts, ends)
        s, e = s[keep], e[keep]
        # header mask: one gather of each stripped row's first byte
        first = buf[s]
        body = (first != 0x3E) & (first != 0x3B)      # not '>' nor ';'
        return s[body], (e - s)[body]


class SmilesFormat(RecordFormat):
    """SMILES: the first whitespace-separated token of each line (the
    molecule string; trailing columns are ids/metadata)."""

    name = "smiles"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        out = []
        for ln in lines:
            parts = ln.split()
            if parts:
                out.append(parts[0])
        return out

    def _select(self, buf, starts, ends):
        keep, s, e = _strip_extents(buf, starts, ends)
        s, e = s[keep], e[keep]
        # clamp each row's length at the first whitespace after the token
        # start: searchsorted into the whole-payload ws index list finds
        # it without touching row bytes (the row-terminating newline is
        # itself ws, so in-bounds hits are guaranteed except for a final
        # unterminated row — clamped by the row end)
        wz = np.flatnonzero(_WS_TABLE[buf])
        if wz.size == 0:                    # no whitespace anywhere
            return s, e - s
        cut = np.searchsorted(wz, s)
        tok_end = np.where(cut < wz.size,
                           wz[np.minimum(cut, wz.size - 1)],
                           buf.size)
        tok_end = np.minimum(tok_end, e)
        return s, tok_end - s


FORMATS = {f.name: f for f in (LineFormat(), FastaFormat(), SmilesFormat())}


def pack_batches(batches: Sequence[RecordBatch],
                 capacity: Optional[int] = None,
                 width: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Pack record batches into ``{"data": [cap, width] u8, "len": [cap]
    i32}`` with one masked advanced-indexing gather per batch.

    The batches' records are laid out consecutively (batch order, record
    order within a batch).  ``capacity``/``width`` default to the total
    record count / longest record.  Records longer than ``width`` raise
    (truncation would corrupt data).  No intermediate per-record ``bytes``
    objects are created — bytes move straight from each batch's payload
    buffer into the packed array.
    """
    n = sum(len(b) for b in batches)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError(f"{n} records exceed capacity {cap}")
    maxlen = max((b.max_len for b in batches), default=0)
    w = width if width is not None else max(maxlen, 1)
    if maxlen > w:
        raise ValueError(f"record length {maxlen} exceeds width {w}")
    data = np.zeros((cap, w), np.uint8)
    lens = np.zeros((cap,), np.int32)
    col = np.arange(w, dtype=np.int64)
    row = 0
    for b in batches:
        m = len(b)
        if m == 0:
            continue
        lens[row:row + m] = b.lens
        length0 = int(b.lens[0])
        # uniform-geometry fast path: fixed-width records at a constant
        # offset stride (wrapped FASTA, fixed-width text) are a strided
        # VIEW of the payload — one memcpy into the packed array, no
        # index arrays at all
        uniform = bool((b.lens == length0).all()) and (
            m == 1 or bool((np.diff(b.starts)
                            == int(b.starts[1] - b.starts[0])).all()))
        if b.buf.size == 0 or b.max_len == 0:
            pass                            # zero-length rows: lens only
        elif uniform:
            if m == 1:
                start0 = int(b.starts[0])
                data[row, :length0] = b.buf[start0:start0 + length0]
            else:
                stride = int(b.starts[1] - b.starts[0])
                view = np.lib.stride_tricks.as_strided(
                    b.buf[int(b.starts[0]):], shape=(m, length0),
                    strides=(stride, 1))
                data[row:row + m, :length0] = view
        else:
            # general path — one [m, w] masked gather: row i reads
            # buf[starts[i] : starts[i]+w], clamped in-bounds; the mask
            # zeroes the cols past lens[i]
            idx = b.starts[:, None].astype(np.int64) + col[None, :]
            np.minimum(idx, b.buf.size - 1, out=idx)
            mask = col[None, :] < b.lens[:, None]
            data[row:row + m] = np.where(mask, b.buf[idx], 0)
        row += m
    return {"data": data, "len": lens}


def pack_records(records: List[bytes], capacity: Optional[int] = None,
                 width: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Pack byte records into ``{"data": [cap, width] u8, "len": [cap] i32}``.

    Legacy row-at-a-time packer, kept as :func:`pack_batches`' parity
    oracle.  ``capacity``/``width`` default to the record count / longest
    record; when ``width`` is passed explicitly (ingest already knows the
    max) the separate O(n) max-length pre-scan is skipped and overlong
    records are caught row-by-row.  Records longer than ``width`` raise
    (truncation would corrupt data).
    """
    n = len(records)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError(f"{n} records exceed capacity {cap}")
    if width is None:
        w = max(max((len(r) for r in records), default=1), 1)
    else:
        w = width
    data = np.zeros((cap, w), np.uint8)
    lens = np.zeros((cap,), np.int32)
    for i, r in enumerate(records):
        if len(r) > w:
            raise ValueError(f"record length {len(r)} exceeds width {w}")
        buf = np.frombuffer(r, np.uint8)
        data[i, :buf.shape[0]] = buf
        lens[i] = buf.shape[0]
    return {"data": data, "len": lens}


def unpack_records(packed: Dict[str, Any], count: Optional[int] = None
                   ) -> List[bytes]:
    """Inverse of :func:`pack_batches` (host-side, for tests/debugging):
    one bulk copy out of the array, then per-record slices of that single
    ``bytes`` object (no per-row numpy indexing)."""
    data = np.ascontiguousarray(np.asarray(packed["data"]), dtype=np.uint8)
    lens = np.asarray(packed["len"])
    n = int(count if count is not None else data.shape[0])
    w = int(data.shape[1])
    raw = data[:n].tobytes()
    return [raw[i * w: i * w + ln]
            for i, ln in enumerate(lens[:n].astype(np.int64).tolist())]
