"""Record formats: framing + packing for byte-oriented datasets.

A :class:`RecordFormat` turns a byte-range split into complete records
(Hadoop RecordReader analogue) and :func:`pack_records` packs the
variable-length records into the fixed-shape static-SPMD contract the rest
of the stack assumes:

    {"data": uint8 [capacity, width], "len": int32 [capacity]}

Split-boundary rule (classic InputFormat semantics): a record is owned by
the split containing its **first byte**.  A reader starting mid-file
discards the partial leading record (it belongs to the previous split) and
reads past its end offset to finish its last record, so every record is
read exactly once regardless of how files are carved.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.schema import Schema, bytes_record_schema
from repro.io.backends import StorageBackend
from repro.io.splits import InputSplit

_READAHEAD = 1 << 16


class RecordFormat:
    """Line-framed record reader; subclasses refine record extraction."""

    name = "base"

    @property
    def schema(self) -> Schema:
        """The record schema :func:`pack_records` output satisfies — the
        same ``{"data": u8[W], "len": i32}`` contract byte-oriented image
        manifests declare as their input, so an ingested dataset
        type-checks against e.g. ``grep-chars``/``kmer-stats`` at plan
        time (``W`` binds to the packed width)."""
        return bytes_record_schema()

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        """Map complete, newline-stripped lines to records."""
        raise NotImplementedError  # pragma: no cover - abstract

    def parse(self, payload: bytes) -> List[bytes]:
        """Records in a payload that starts and ends on record boundaries."""
        lines = [ln for ln in payload.split(b"\n")]
        if lines and lines[-1] == b"":
            lines.pop()
        return self.records_from_lines(lines)

    def read_split(self, backend: StorageBackend, split: InputSplit,
                   readahead: int = _READAHEAD) -> List[bytes]:
        """All records whose first byte lies in ``[split.start, split.stop)``."""
        size = split.file_size
        if split.start > 0:
            # peek one byte back: if byte start-1 is a newline, a record
            # begins exactly at `start` and is ours; otherwise we are
            # mid-record and the partial head belongs to the previous
            # split — skip through the first newline.
            data = backend.read_range(split.path, split.start - 1,
                                      split.stop)
            if data[:1] == b"\n":
                data = data[1:]
            else:
                nl = data.find(b"\n")
                if nl < 0:
                    # the record containing split.start extends past
                    # split.stop; it is owned by an earlier split.
                    return []
                data = data[nl + 1:]
        else:
            data = backend.read_range(split.path, 0, split.stop)
        # empty after head-trim: the split's last byte was the terminating
        # newline of a record owned by an earlier split, and the next
        # record starts at `stop` — owned by the next split.
        if not data:
            return []
        # extend past stop to finish the final record
        pos = split.stop
        while pos < size and not data.endswith(b"\n"):
            extra = backend.read_range(split.path, pos,
                                       min(pos + readahead, size))
            if not extra:
                break
            nl = extra.find(b"\n")
            if nl >= 0:
                data += extra[:nl + 1]
                break
            data += extra
            pos += len(extra)
        return self.parse(data)


class LineFormat(RecordFormat):
    """Line-delimited text: every non-empty line is one record."""

    name = "text"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        return [ln for ln in lines if ln.strip()]


class FastaFormat(RecordFormat):
    """FASTA: header lines (``>``) are dropped; each sequence line is one
    record (a fixed-width-friendly chunking of the sequence — exact for
    any per-base statistic such as GC count)."""

    name = "fasta"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        out = []
        for ln in lines:
            ln = ln.strip()
            if ln and not ln.startswith(b">") and not ln.startswith(b";"):
                out.append(ln)
        return out


class SmilesFormat(RecordFormat):
    """SMILES: the first whitespace-separated token of each line (the
    molecule string; trailing columns are ids/metadata)."""

    name = "smiles"

    def records_from_lines(self, lines: List[bytes]) -> List[bytes]:
        out = []
        for ln in lines:
            parts = ln.split()
            if parts:
                out.append(parts[0])
        return out


FORMATS = {f.name: f for f in (LineFormat(), FastaFormat(), SmilesFormat())}


def pack_records(records: List[bytes], capacity: Optional[int] = None,
                 width: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Pack byte records into ``{"data": [cap, width] u8, "len": [cap] i32}``.

    ``capacity``/``width`` default to the record count / longest record.
    Records longer than ``width`` raise (truncation would corrupt data).
    """
    n = len(records)
    cap = capacity if capacity is not None else max(n, 1)
    if n > cap:
        raise ValueError(f"{n} records exceed capacity {cap}")
    maxlen = max((len(r) for r in records), default=1)
    w = width if width is not None else max(maxlen, 1)
    if maxlen > w:
        raise ValueError(f"record length {maxlen} exceeds width {w}")
    data = np.zeros((cap, w), np.uint8)
    lens = np.zeros((cap,), np.int32)
    for i, r in enumerate(records):
        buf = np.frombuffer(r, np.uint8)
        data[i, :buf.shape[0]] = buf
        lens[i] = buf.shape[0]
    return {"data": data, "len": lens}


def unpack_records(packed: Dict[str, Any], count: Optional[int] = None
                   ) -> List[bytes]:
    """Inverse of :func:`pack_records` (host-side, for tests/debugging)."""
    data = np.asarray(packed["data"])
    lens = np.asarray(packed["len"])
    n = count if count is not None else data.shape[0]
    return [bytes(data[i, :int(lens[i])].tobytes()) for i in range(int(n))]
