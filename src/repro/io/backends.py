"""Storage backends: the heterogeneous-storage abstraction (paper Fig. 5).

A :class:`StorageBackend` exposes exactly what split planning and parallel
ingestion need — ``list`` / ``size`` / ``read_range`` — mirroring the
narrow waist shared by HDFS, Swift and S3 clients.  ``LocalFS`` is a real
filesystem implementation; :class:`EmulatedObjectStore` wraps any backend
with a request-latency / jitter / bandwidth profile so the paper's three
storage tiers (HDFS co-located, Swift same-DC, S3 remote) are reproducible
on one machine.  The profile table lived hardcoded in
``benchmarks/ingestion.py``; it now lives here as :data:`BACKEND_PROFILES`
and the benchmark consumes the real ingestion path.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional

import numpy as np


class StorageBackend:
    """Minimal storage contract: enumerate objects, stat, ranged read."""

    name = "base"

    def list(self) -> List[str]:  # pragma: no cover - abstract
        """All object paths under this backend's root (sorted)."""
        raise NotImplementedError

    def size(self, path: str) -> int:  # pragma: no cover - abstract
        raise NotImplementedError

    def read_range(self, path: str, start: int, stop: int) -> bytes:
        """Bytes ``[start, stop)`` of ``path`` (may return fewer at EOF)."""
        raise NotImplementedError  # pragma: no cover - abstract


class LocalFS(StorageBackend):
    """Real local filesystem rooted at a file or directory."""

    name = "local"

    def __init__(self, root: str):
        self.root = os.path.abspath(root)

    def _resolve(self, path: str) -> str:
        if os.path.isabs(path):
            return path
        return os.path.join(self.root, path)

    def list(self) -> List[str]:
        if os.path.isfile(self.root):
            return [self.root]
        out: List[str] = []
        for dirpath, _, names in os.walk(self.root):
            for n in names:
                out.append(os.path.join(dirpath, n))
        return sorted(out)

    def size(self, path: str) -> int:
        return os.path.getsize(self._resolve(path))

    def read_range(self, path: str, start: int, stop: int) -> bytes:
        with open(self._resolve(path), "rb") as f:
            f.seek(start)
            return f.read(max(0, stop - start))


#: (request latency s, exponential jitter s) — co-located / same-DC / remote
#: storage tiers, matching the paper's HDFS / Swift / S3 deployment.
BACKEND_PROFILES: Dict[str, tuple] = {
    "hdfs": (0.0002, 0.0),
    "swift": (0.001, 0.0002),
    "s3": (0.004, 0.002),
}


class EmulatedObjectStore(StorageBackend):
    """Wrap a backend with a deterministic latency/jitter/bandwidth profile.

    Each ``read_range`` request pays ``latency_s`` plus an exponential
    jitter term (seeded per backend, so runs are reproducible) plus a
    bandwidth term proportional to bytes transferred.  Metadata calls
    (``list`` / ``size``) pay the base latency only.  Sleeps happen in the
    calling thread, so a fetch pool's thread scaling is honest even on one
    core (latency-bound, like the paper's remote-storage runs).
    """

    def __init__(self, inner: StorageBackend, name: str = "emulated",
                 latency_s: float = 0.0, jitter_s: float = 0.0,
                 bandwidth_bps: Optional[float] = None, seed: int = 0):
        self.inner = inner
        self.name = name
        self.latency_s = latency_s
        self.jitter_s = jitter_s
        self.bandwidth_bps = bandwidth_bps
        self.stats = {"requests": 0, "bytes": 0}
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()

    def _delay(self, nbytes: int = 0) -> None:
        d = self.latency_s
        if self.jitter_s:
            with self._lock:
                d += float(self._rng.exponential(self.jitter_s))
        if self.bandwidth_bps and nbytes:
            d += nbytes / self.bandwidth_bps
        if d > 0:
            time.sleep(d)
        with self._lock:
            self.stats["requests"] += 1
            self.stats["bytes"] += nbytes

    def list(self) -> List[str]:
        self._delay()
        return self.inner.list()

    def size(self, path: str) -> int:
        self._delay()
        return self.inner.size(path)

    def read_range(self, path: str, start: int, stop: int) -> bytes:
        data = self.inner.read_range(path, start, stop)
        self._delay(len(data))
        return data


def HDFS(root: str, **kw) -> EmulatedObjectStore:
    """Co-located HDFS emulation (lowest request latency)."""
    lat, jit = BACKEND_PROFILES["hdfs"]
    return EmulatedObjectStore(LocalFS(root), name="hdfs", latency_s=lat,
                               jitter_s=jit, **kw)


def Swift(root: str, **kw) -> EmulatedObjectStore:
    """Same-datacenter OpenStack Swift emulation."""
    lat, jit = BACKEND_PROFILES["swift"]
    return EmulatedObjectStore(LocalFS(root), name="swift", latency_s=lat,
                               jitter_s=jit, **kw)


def S3(root: str, **kw) -> EmulatedObjectStore:
    """Remote S3 emulation (highest latency + jitter)."""
    lat, jit = BACKEND_PROFILES["s3"]
    return EmulatedObjectStore(LocalFS(root), name="s3", latency_s=lat,
                               jitter_s=jit, **kw)


_FACTORIES = {"local": LocalFS, "hdfs": HDFS, "swift": Swift, "s3": S3}


def make_backend(kind: str, root: str, **kw) -> StorageBackend:
    """Build a backend by name: ``local`` | ``hdfs`` | ``swift`` | ``s3``."""
    if kind not in _FACTORIES:
        raise KeyError(f"unknown backend {kind!r}; available: "
                       f"{sorted(_FACTORIES)}")
    return _FACTORIES[kind](root, **kw)
