"""Parallel ingestion: splits -> fetch pool -> packed ShardedDataset.

The real MaRe ingestion path (paper Fig. 5): splits are fetched
concurrently by a thread pool (latency-bound against remote storage, so
pool width is the paper's "number of workers"), framed per split into a
columnar :class:`~repro.io.formats.RecordBatch` (vectorized NumPy
newline/offset transforms — no per-record ``bytes``), gathered per shard
into the fixed-shape byte-record contract, and placed shard-by-shard with
double-buffered ``jax.device_put`` (transfer of shard *s* overlaps packing
of shard *s+1* via :func:`repro.core.dataset.from_shard_arrays`).

Pool-width default: with the vectorized parser, a pool pays off on EVERY
backend — remote fetches wait on request latency, and local fetches
overlap the OS read (GIL released in ``f.read``) with framing's bulk
NumPy ops (GIL released in the C loops), so ``workers=None`` picks a
small pool for latency-free backends and ``min(32, num_splits)`` for
backends that declare a request latency.  The legacy per-line parser
(``parser="legacy"``) is GIL-serialized Python record parsing, where any
local pool width is pure overhead (profiled at ~0.6x of serial at 8
workers — BENCH_ingestion.json pre-vectorization), so it keeps the
serial local default.  ``workers == 1`` bypasses the executor entirely.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

from jax.sharding import Mesh

from repro.core.dataset import ShardedDataset, from_shard_arrays
from repro.io.formats import RecordBatch, pack_batches, pack_records
from repro.io.source import DataSource
from repro.io.splits import InputSplit, assign_splits
from repro.kernels.common import round_up
from repro.obs import METRICS, span
from repro.runtime.lineage import source_root

#: Pack geometry is rounded up to these multiples so consecutive waves of
#: similar size reuse one compiled executable instead of recompiling.
_CAP_BUCKET = 64
_WIDTH_BUCKET = 16

#: Local (latency-free) pool cap for the vectorized parser: enough
#: threads to overlap OS reads with framing, few enough that pool
#: bookkeeping stays negligible against small splits.
_LOCAL_POOL_CAP = 4


def _round_up(x: int, m: int) -> int:
    return round_up(max(x, 1), m)


#: Process-lifetime fetch pools keyed by width: spinning up a
#: ThreadPoolExecutor costs ~0.5ms, which is real money against a
#: ~10ms vectorized local ingest — repeated ingests (benchmark sweeps,
#: waves, stream epochs) reuse the pool of their width instead.
_POOLS: Dict[int, ThreadPoolExecutor] = {}
_POOLS_LOCK = threading.Lock()


def _pool(width: int) -> ThreadPoolExecutor:
    with _POOLS_LOCK:
        pool = _POOLS.get(width)
        if pool is None:
            pool = ThreadPoolExecutor(
                max_workers=width, thread_name_prefix=f"ingest-{width}")
            _POOLS[width] = pool
        return pool


def default_workers(backend, num_splits: int,
                    parser: str = "vectorized") -> int:
    """Latency-aware fetch-pool width.  Latency-bound (emulated/remote)
    backends get up to 32 threads.  Latency-free backends get a small
    pool under the vectorized parser (framing is GIL-releasing NumPy, so
    fetch+frame of neighboring splits overlap) and the serial path under
    ``parser="legacy"`` (GIL-bound per-line Python, where pooling
    anti-scales)."""
    latency = float(getattr(backend, "latency_s", 0.0) or 0.0)
    if latency <= 0.0:
        if parser == "legacy":
            return 1
        return max(1, min(_LOCAL_POOL_CAP, os.cpu_count() or 1,
                          num_splits))
    return min(32, max(1, num_splits))


def ingest(source: DataSource, mesh: Mesh, axis: str = "data",
           capacity: Optional[int] = None, width: Optional[int] = None,
           workers: Optional[int] = None,
           splits: Optional[Sequence[InputSplit]] = None,
           parser: str = "vectorized") -> ShardedDataset:
    """Fetch ``source`` (or an explicit subset of its splits) into a
    :class:`ShardedDataset` of ``{"data", "len"}`` byte records.

    ``parser`` selects the framing/packing implementation:
    ``"vectorized"`` (default) flows columnar ``RecordBatch`` offsets
    from storage to the device buffer; ``"legacy"`` is the per-line
    ``List[bytes]`` oracle the property tests pin it against.
    """
    if parser not in ("vectorized", "legacy"):
        raise ValueError(f"unknown parser {parser!r}; "
                         "expected 'vectorized' or 'legacy'")
    if splits is None:
        splits = source.splits()
    n = int(mesh.shape[axis])
    bins = assign_splits(splits, n)
    if workers is None:
        workers = default_workers(source.backend, len(splits), parser)

    backend, fmt = source.backend, source.fmt

    def read_one(sp: InputSplit) -> RecordBatch:
        # fetch + frame of one split (possibly on a pool thread — spans
        # record their thread, so the trace shows pool parallelism)
        with span("ingest.fetch", path=sp.path, start=sp.start,
                  length=sp.length):
            payload = fmt.read_payload(backend, sp)
        with span("ingest.frame", path=sp.path, bytes=len(payload)):
            if parser == "legacy":
                batch = RecordBatch.from_records(
                    fmt.parse(payload) if payload else [])
            else:
                batch = fmt.frame(payload)
        METRICS.counter("ingest.splits").inc()
        METRICS.counter("ingest.records").inc(len(batch))
        return batch

    def read_bin(b: Sequence[InputSplit]) -> List[RecordBatch]:
        return [read_one(sp) for sp in b]

    latency = float(getattr(backend, "latency_s", 0.0) or 0.0)

    def geometry(shard_batches: List[List[RecordBatch]]):
        counts = [sum(len(b) for b in bs) for bs in shard_batches]
        max_count = max(counts, default=0)
        max_width = max((b.max_len for bs in shard_batches for b in bs),
                        default=0)
        cap = capacity if capacity is not None else _round_up(max_count,
                                                              _CAP_BUCKET)
        w = width if width is not None else _round_up(max_width,
                                                      _WIDTH_BUCKET)
        if max_count > cap:
            raise ValueError(
                f"shard record count {max_count} exceeds capacity {cap}; "
                "raise `capacity` or stream via repro.io.waves")
        if max_width > w:
            raise ValueError(f"record length {max_width} exceeds width {w}")
        return counts, cap, w

    def make_pack_one(cap: int, w: int):
        def pack_one(batches: List[RecordBatch], count: int, shard: int):
            # one gather per batch straight out of the framed payload
            # buffers — the columnar fast path; the legacy parser goes
            # through the row-at-a-time oracle packer
            with span("ingest.gather", shard=shard, records=count):
                if parser == "legacy":
                    recs = [r for b in batches for r in b.to_list()]
                    return pack_records(recs, capacity=cap, width=w)
                return pack_batches(batches, capacity=cap, width=w)
        return pack_one

    with span("ingest", splits=len(splits), shards=n, workers=workers,
              parser=parser):
        if workers <= 1:
            # serial fast path: no executor, no future bookkeeping
            shard_batches: List[List[RecordBatch]] = [
                read_bin(b) for b in bins]
        elif latency <= 0.0:
            # latency-free pooled: per-split futures would drown the
            # (fast, vectorized) per-split work in pool bookkeeping —
            # one task per shard bin, so whole shards fetch+frame
            # concurrently
            pool = _pool(workers)
            shard_batches = [
                f.result() for f in
                [pool.submit(read_bin, b) for b in bins]]
        else:
            # latency-bound: one future per split (grouped per shard in
            # plan order) so every request's wait overlaps
            pool = _pool(workers)
            futs = [[pool.submit(read_one, sp) for sp in b]
                    for b in bins]
            shard_batches = [[f.result() for f in shard]
                             for shard in futs]
        counts, cap, w = geometry(shard_batches)
        pack_one = make_pack_one(cap, w)
        # geometry is a barrier (capacity/width need every shard's
        # extents), so packing can't race the fetches anyway — a lazy
        # generator double-buffers instead: shard s packs while shard
        # s-1's device transfer drains, with zero future bookkeeping
        packed = (pack_one(bs, counts[i], i)
                  for i, bs in enumerate(shard_batches))
        with span("ingest.device_put", shards=n, capacity=cap,
                  width=w):
            ds = from_shard_arrays(packed, counts, mesh, axis)
    # content-keyed lineage root: re-ingesting the same byte ranges with
    # the same pack geometry reaches materializations persisted earlier
    # (sources assumed immutable while cached — the HDFS/object-store
    # model; see repro.runtime.lineage)
    ds.lineage = source_root(type(backend).__name__, type(fmt).__name__,
                             splits, cap, w)
    return ds
