"""Parallel ingestion: splits -> fetch pool -> packed ShardedDataset.

The real MaRe ingestion path (paper Fig. 5): splits are fetched
concurrently by a thread pool (latency-bound against remote storage, so
pool width is the paper's "number of workers"), packed per shard into the
fixed-shape byte-record contract, and placed shard-by-shard with
double-buffered ``jax.device_put`` (transfer of shard *s* overlaps packing
of shard *s+1* via :func:`repro.core.dataset.from_shard_arrays`).

Pool-width default: threads only pay off when fetches *wait* (remote
request latency).  Against zero-latency local storage, ``read_split`` is
GIL-serialized Python record parsing, so any pool width > 1 is pure
overhead (profiled at ~0.6x of serial at 8 workers — BENCH_ingestion.json
pre-fix); ``workers=None`` therefore picks 1 for latency-free backends
and ``min(32, num_splits)`` for backends that declare a request latency,
and ``workers == 1`` bypasses the executor entirely.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Sequence

from jax.sharding import Mesh

from repro.core.dataset import ShardedDataset, from_shard_arrays
from repro.io.formats import pack_records
from repro.io.source import DataSource
from repro.io.splits import InputSplit, assign_splits
from repro.kernels.common import round_up
from repro.obs import METRICS, span
from repro.runtime.lineage import source_root

#: Pack geometry is rounded up to these multiples so consecutive waves of
#: similar size reuse one compiled executable instead of recompiling.
_CAP_BUCKET = 64
_WIDTH_BUCKET = 16


def _round_up(x: int, m: int) -> int:
    return round_up(max(x, 1), m)


def default_workers(backend, num_splits: int) -> int:
    """Latency-aware fetch-pool width: 1 (serial) for latency-free
    backends, up to 32 when each request waits on emulated/remote I/O."""
    latency = float(getattr(backend, "latency_s", 0.0) or 0.0)
    if latency <= 0.0:
        return 1
    return min(32, max(1, num_splits))


def ingest(source: DataSource, mesh: Mesh, axis: str = "data",
           capacity: Optional[int] = None, width: Optional[int] = None,
           workers: Optional[int] = None,
           splits: Optional[Sequence[InputSplit]] = None) -> ShardedDataset:
    """Fetch ``source`` (or an explicit subset of its splits) into a
    :class:`ShardedDataset` of ``{"data", "len"}`` byte records."""
    if splits is None:
        splits = source.splits()
    n = int(mesh.shape[axis])
    bins = assign_splits(splits, n)
    if workers is None:
        workers = default_workers(source.backend, len(splits))

    backend, fmt = source.backend, source.fmt

    def read_one(sp: InputSplit) -> List[bytes]:
        # fetch + decode of one split (possibly on a pool thread — spans
        # record their thread, so the trace shows pool parallelism)
        with span("ingest.fetch", path=sp.path, start=sp.start,
                  length=sp.length):
            recs = fmt.read_split(backend, sp)
        METRICS.counter("ingest.splits").inc()
        METRICS.counter("ingest.records").inc(len(recs))
        return recs

    with span("ingest", splits=len(splits), shards=n, workers=workers):
        if workers <= 1:
            # serial fast path: no executor, no future bookkeeping
            shard_recs: List[List[bytes]] = [
                [r for sp in b for r in read_one(sp)] for b in bins]
        else:
            with ThreadPoolExecutor(max_workers=workers) as pool:
                # one future per split, grouped per shard in plan order
                futs = [[pool.submit(read_one, sp) for sp in b]
                        for b in bins]
                shard_recs = [
                    [r for f in shard for r in f.result()]
                    for shard in futs]

        max_count = max((len(r) for r in shard_recs), default=0)
        max_width = max((len(rec) for recs in shard_recs for rec in recs),
                        default=0)
        cap = capacity if capacity is not None else _round_up(max_count,
                                                              _CAP_BUCKET)
        w = width if width is not None else _round_up(max_width,
                                                      _WIDTH_BUCKET)
        if max_count > cap:
            raise ValueError(
                f"shard record count {max_count} exceeds capacity {cap}; "
                "raise `capacity` or stream via repro.io.waves")
        if max_width > w:
            raise ValueError(f"record length {max_width} exceeds width {w}")

        counts = [len(r) for r in shard_recs]

        def pack_one(recs: List[bytes], shard: int):
            with span("ingest.pack", shard=shard, records=len(recs)):
                return pack_records(recs, capacity=cap, width=w)

        # lazy generator: each shard packs during the previous shard's
        # device transfer (double buffering preserved)
        packed = (pack_one(recs, i) for i, recs in enumerate(shard_recs))
        with span("ingest.device_put", shards=n, capacity=cap, width=w):
            ds = from_shard_arrays(packed, counts, mesh, axis)
    # content-keyed lineage root: re-ingesting the same byte ranges with
    # the same pack geometry reaches materializations persisted earlier
    # (sources assumed immutable while cached — the HDFS/object-store
    # model; see repro.runtime.lineage)
    ds.lineage = source_root(type(backend).__name__, type(fmt).__name__,
                             splits, cap, w)
    return ds
