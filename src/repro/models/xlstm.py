"""xLSTM blocks: mLSTM (matrix memory, parallelizable) + sLSTM (scalar
memory, sequential) — arXiv:2405.04517, adapted to TPU.

mLSTM recurrence per head (state C [hd, hd], n [hd], stabilizer m):
    f_t = sigmoid(f̃_t)   i_t = exp(ĩ_t)        (exponential input gate)
    m_t = max(log f_t + m_{t-1}, log i_t)
    C_t = f'_t C_{t-1} + i'_t v_t k_t^T         (gates rescaled by m_t)
    n_t = f'_t n_{t-1} + i'_t k_t
    y_t = (C_t q_t) / max(|n_t . q_t|, 1)
Train/prefill evaluates it *chunkwise*: stabilized parallel form within a
chunk, tiny (C, n, m) carry across chunks via lax.scan — same pattern as
ssm.py, O(chunk) memory, O(1) decode.

sLSTM heads keep true sequential recurrence (R_* recurrent weights) — they
are the non-parallelizable part of the paper; the 7:1 m:s layer pattern is
expressed as scanned units of (slstm_every - 1) mLSTM blocks + 1 sLSTM
block so the whole 48-layer stack is still two nested scans.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, trunc_normal

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    return {"wq": trunc_normal(ks[0], (d, H, hd), dt),
            "wk": trunc_normal(ks[1], (d, H, hd), dt),
            "wv": trunc_normal(ks[2], (d, H, hd), dt),
            "wi": trunc_normal(ks[3], (d, H), jnp.float32),
            "wf": trunc_normal(ks[4], (d, H), jnp.float32),
            "f_bias": jnp.full((H,), 3.0, jnp.float32),
            "wo": trunc_normal(ks[5], (H, hd, d), dt)}


def mlstm_logical_axes(cfg: ModelConfig) -> Params:
    return {"wq": ("embed", "heads", "hd"), "wk": ("embed", "heads", "hd"),
            "wv": ("embed", "heads", "hd"), "wi": ("embed", "heads"),
            "wf": ("embed", "heads"), "f_bias": ("heads",),
            "wo": ("heads", "hd", "embed")}


class MLSTMState(NamedTuple):
    c: jnp.ndarray    # [B, H, hd, hd] f32
    n: jnp.ndarray    # [B, H, hd] f32
    m: jnp.ndarray    # [B, H] f32 log-stabilizer


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    H = cfg.num_heads
    hd = cfg.d_model // H
    return MLSTMState(c=jnp.zeros((batch, H, hd, hd), jnp.float32),
                      n=jnp.zeros((batch, H, hd), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def _mlstm_chunk(q, k, v, logf, logi, state: MLSTMState):
    """Stabilized chunk-parallel mLSTM.

    q,k,v: [B, H, T, hd] f32; logf, logi: [B, H, T]; returns (y, state')."""
    b, h, t, hd = q.shape
    F = jnp.cumsum(logf, axis=-1)                       # log prod f_(1..t)
    # decay from chunk start to step t (inclusive of f_t)
    logas = F                                            # state-in decay
    # pairwise decay D[t, s] = log prod f_(s+1..t) + log i_s,  s <= t
    D = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    tri = jnp.tril(jnp.ones((t, t), bool))
    D = jnp.where(tri, D, -jnp.inf)
    m_in = state.m[..., None] + logas                    # [B,H,T] carried
    m_local = jnp.max(D, axis=-1)                        # [B,H,T]
    m_t = jnp.maximum(m_in, m_local)
    # intra-chunk contribution
    w = jnp.exp(D - m_t[..., None])                      # [B,H,T,T]
    s_qk = jnp.einsum("bhtd,bhsd->bhts", q, k) / (hd ** 0.5)
    y_intra = jnp.einsum("bhts,bhsd->bhtd", w * s_qk, v)
    n_intra = jnp.einsum("bhts,bhsd->bhtd", w, k)
    # inter-chunk (carried state, stored stabilized at state.m) rescale
    scale_in = jnp.exp(state.m[..., None] + logas - m_t)  # [B,H,T]
    y_inter = jnp.einsum("bhde,bhte->bhtd", state.c,
                         q) / (hd ** 0.5)
    y_inter = y_inter * scale_in[..., None]
    n_inter = state.n[:, :, None, :] * scale_in[..., None]
    y_num = y_intra + y_inter
    n_tot = n_intra + n_inter
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_tot,
                                           q) / (hd ** 0.5)),
                        jnp.exp(-m_t))
    y = y_num / denom[..., None]
    # state update to end of chunk
    loga_T = F[..., -1:]                                 # total chunk decay
    m_new = jnp.maximum(state.m + loga_T[..., 0],
                        jnp.max(D[..., -1, :], axis=-1))
    up_w = jnp.exp(F[..., -1:] - F + logi - m_new[..., None])  # [B,H,T]
    c_new = (state.c * jnp.exp(state.m + loga_T[..., 0] - m_new
                               )[..., None, None] +
             jnp.einsum("bht,bhtd,bhte->bhde", up_w, v, k))
    n_new = (state.n * jnp.exp(state.m + loga_T[..., 0] - m_new)[..., None]
             + jnp.einsum("bht,bhtd->bhd", up_w, k))
    return y, MLSTMState(c=c_new, n=n_new, m=m_new)


def mlstm_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[MLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[MLSTMState]]:
    """x: [B, S, d] -> (y [B, S, d], state')."""
    b, s, d = x.shape
    H = cfg.num_heads
    hd = d // H
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"]).astype(jnp.float32)
    logi = (x.astype(jnp.float32) @ p["wi"]).transpose(0, 2, 1)  # [B,H,S]
    logf = jax.nn.log_sigmoid(
        (x.astype(jnp.float32) @ p["wf"]).transpose(0, 2, 1) + p["f_bias"][:, None])
    st = state if state is not None else init_mlstm_state(cfg, b)
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, 0), (0, pad)),
                       constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    nc = q.shape[2] // chunk

    def split(a):
        return a.reshape(a.shape[0], a.shape[1], nc, chunk,
                         *a.shape[3:]).transpose(2, 0, 1, 3,
                                                 *range(4, a.ndim + 1))

    def step(carry, inp):
        qc, kc, vc, fc, ic = inp
        y, new = _mlstm_chunk(qc, kc, vc, fc, ic, carry)
        return new, y

    final, ys = jax.lax.scan(
        step, st, (split(q), split(k), split(v), split(logf), split(logi)))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, H, nc * chunk, hd)[:, :, :s]
    y = y.transpose(0, 2, 1, 3).astype(x.dtype)          # [B,S,H,hd]
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return out, (final if state is not None else None)


# ---------------------------------------------------------------------------
# sLSTM (sequential scalar memory)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    dt = cfg.param_dtype
    ks = jax.random.split(key, 10)
    p = {"wz": trunc_normal(ks[0], (d, H, hd), dt),
         "wi": trunc_normal(ks[1], (d, H, hd), dt),
         "wf": trunc_normal(ks[2], (d, H, hd), dt),
         "wo_g": trunc_normal(ks[3], (d, H, hd), dt),
         "rz": trunc_normal(ks[4], (H, hd, hd), dt),
         "ri": trunc_normal(ks[5], (H, hd, hd), dt),
         "rf": trunc_normal(ks[6], (H, hd, hd), dt),
         "ro": trunc_normal(ks[7], (H, hd, hd), dt),
         "f_bias": jnp.full((H, hd), 3.0, jnp.float32),
         "wout": trunc_normal(ks[8], (H, hd, d), dt)}
    return p


def slstm_logical_axes(cfg: ModelConfig) -> Params:
    ax3 = ("embed", "heads", "hd")
    axr = ("heads", "hd", None)
    return {"wz": ax3, "wi": ax3, "wf": ax3, "wo_g": ax3,
            "rz": axr, "ri": axr, "rf": axr, "ro": axr,
            "f_bias": ("heads", "hd"), "wout": ("heads", "hd", "embed")}


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, H, hd]
    n: jnp.ndarray   # [B, H, hd]
    h: jnp.ndarray   # [B, H, hd]
    m: jnp.ndarray   # [B, H, hd]


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def slstm_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                state: Optional[SLSTMState] = None
                ) -> Tuple[jnp.ndarray, Optional[SLSTMState]]:
    """Sequential sLSTM: x [B, S, d] -> y [B, S, d] (lax.scan over S)."""
    b, s, d = x.shape
    zx = jnp.einsum("bsd,dhk->sbhk", x, p["wz"]).astype(jnp.float32)
    ix = jnp.einsum("bsd,dhk->sbhk", x, p["wi"]).astype(jnp.float32)
    fx = jnp.einsum("bsd,dhk->sbhk", x, p["wf"]).astype(jnp.float32)
    ox = jnp.einsum("bsd,dhk->sbhk", x, p["wo_g"]).astype(jnp.float32)
    st = state if state is not None else init_slstm_state(cfg, b)

    def recur(h_prev, w):
        return jnp.einsum("bhk,hkl->bhl", h_prev,
                          w.astype(jnp.float32))

    def step(carry, inp):
        zt, it, ft, ot = inp
        c, n, h, m = carry
        z = jnp.tanh(zt + recur(h, p["rz"]))
        logi = it + recur(h, p["ri"])
        logf = jax.nn.log_sigmoid(ft + recur(h, p["rf"]) + p["f_bias"])
        o = jax.nn.sigmoid(ot + recur(h, p["ro"]))
        m_new = jnp.maximum(logf + m, logi)
        i_p = jnp.exp(logi - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new), h_new

    final, hs = jax.lax.scan(step, st, (zx, ix, fx, ox))
    y = hs.transpose(1, 0, 2, 3).astype(x.dtype)          # [B,S,H,hd]
    out = jnp.einsum("bshk,hkd->bsd", y, p["wout"])
    return out, (final if state is not None else None)
