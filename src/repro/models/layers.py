"""Shared layers: norms, RoPE, SwiGLU MLP, embeddings (sharding-annotated)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, trunc_normal
from repro.sharding import constrain
from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref

Params = Dict[str, Any]


# -- norms -------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def apply_rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6,
                  use_kernel: bool = False) -> jnp.ndarray:
    if use_kernel:
        return rmsnorm_kernel(x, p["scale"], eps=eps)
    return rmsnorm_ref(x, p["scale"], eps=eps)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5
                    ) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    return (init_layernorm(d, cfg.param_dtype) if cfg.use_layernorm
            else init_rmsnorm(d, cfg.param_dtype))


def apply_norm(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.use_layernorm:
        return apply_layernorm(p, x, eps=cfg.norm_eps)
    return apply_rmsnorm(p, x, eps=cfg.norm_eps)


# -- rotary position embeddings ----------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (absolute)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -- MLP ----------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.use_gelu:
        return {"w1": trunc_normal(k1, (d, f), dt),
                "b1": jnp.zeros((f,), dt),
                "w2": trunc_normal(k2, (f, d), dt),
                "b2": jnp.zeros((d,), dt)}
    return {"w1": trunc_normal(k1, (d, f), dt),    # gate
            "w3": trunc_normal(k3, (d, f), dt),    # up
            "w2": trunc_normal(k2, (f, d), dt)}    # down


def mlp_logical_axes(cfg: ModelConfig) -> Params:
    if cfg.use_gelu:
        return {"w1": ("embed", "ff"), "b1": ("ff",),
                "w2": ("ff", "embed"), "b2": ("embed",)}
    return {"w1": ("embed", "ff"), "w3": ("embed", "ff"),
            "w2": ("ff", "embed")}


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.use_gelu:
        h = jax.nn.gelu(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["w2"]


# -- embeddings ---------------------------------------------------------------

def init_embedding(key, cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    p = {"table": trunc_normal(key, (cfg.vocab_size, cfg.d_model), dt,
                               scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = trunc_normal(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), dt)
    return p


def embedding_logical_axes(cfg: ModelConfig) -> Params:
    p = {"table": ("vocab", "embed_pod")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed_pod", "vocab")
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    x = jnp.take(p["table"], tokens, axis=0)
    return constrain(x, "batch", "seq", None)


def unembed(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Returns logits [..., vocab] (sharded over "vocab")."""
    if cfg.tie_embeddings:
        logits = x @ p["table"].T.astype(x.dtype)
    else:
        logits = x @ p["unembed"]
    return constrain(logits, "batch", "seq", "vocab")


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token NLL in f32; logits may be vocab-sharded (XLA reduces)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)
