"""Whisper-style encoder-decoder (audio family).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
[B, encoder_seq, d].  Both stacks use pre-LN + GELU MLP (whisper style);
positions are sinusoidal on both sides so any decoder length lowers
(whisper's learned 448-position table would not reach the 32k cells —
deviation recorded in configs/whisper_base.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.attention import KVCache
from repro.models.common import ModelConfig, trunc_normal
from repro.models.layers import (apply_layernorm, apply_mlp, cross_entropy,
                                 init_layernorm, init_mlp, mlp_logical_axes)
from repro.sharding import constrain

Params = Dict[str, Any]


def sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return attn_lib.init_attention(key, cfg)


def init_encdec(key, cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_layernorm(cfg.d_model, dt),
                "attn": attn_lib.init_attention(k1, cfg),
                "ln2": init_layernorm(cfg.d_model, dt),
                "mlp": init_mlp(k2, cfg)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_layernorm(cfg.d_model, dt),
                "self_attn": attn_lib.init_attention(k1, cfg),
                "ln_x": init_layernorm(cfg.d_model, dt),
                "cross_attn": attn_lib.init_attention(k2, cfg),
                "ln2": init_layernorm(cfg.d_model, dt),
                "mlp": init_mlp(k3, cfg)}

    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": {"table": trunc_normal(
            ks[2], (cfg.vocab_size, cfg.d_model), dt)},
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_ln": init_layernorm(cfg.d_model, dt),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "dec_ln": init_layernorm(cfg.d_model, dt),
    }


def encdec_logical_axes(cfg: ModelConfig) -> Params:
    ln = {"scale": ("embed",), "bias": ("embed",)}
    attn_ax = attn_lib.attention_logical_axes(cfg)
    enc = {"ln1": dict(ln), "attn": attn_ax, "ln2": dict(ln),
           "mlp": mlp_logical_axes(cfg)}
    dec = {"ln1": dict(ln), "self_attn": attn_ax, "ln_x": dict(ln),
           "cross_attn": attn_ax, "ln2": dict(ln),
           "mlp": mlp_logical_axes(cfg)}
    lift = lambda tree: jax.tree.map(     # noqa: E731
        lambda ax: ("layers",) + tuple(ax), tree,
        is_leaf=lambda t: isinstance(t, tuple))
    return {"embed": {"table": ("vocab", "embed_pod")},
            "enc_layers": lift(enc), "enc_ln": dict(ln),
            "dec_layers": lift(dec), "dec_ln": dict(ln)}


def _mha(p: Params, xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig,
         causal: bool) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", xkv, p["wv"])
    impl = attn_lib.resolve_impl(cfg, xq.shape[1])
    o = attn_lib.full_attention(q, k, v, causal=causal, window=None,
                                impl=impl, chunk=cfg.attn_chunk)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"])


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig
           ) -> jnp.ndarray:
    """frames: [B, S_enc, d] (stub frontend output) -> memory."""
    b, s, d = frames.shape
    pos = sinusoid(jnp.arange(s), d)[None]
    x = frames + pos.astype(frames.dtype)
    x = constrain(x, "batch", "seq", None)

    def body(x, lp):
        h = apply_layernorm(lp["ln1"], x)
        x = x + _mha(lp["attn"], h, h, cfg, causal=False)
        h = apply_layernorm(lp["ln2"], x)
        return x + apply_mlp(lp["mlp"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_layernorm(params["enc_ln"], x)


def decode_train(params: Params, tokens: jnp.ndarray, memory: jnp.ndarray,
                 cfg: ModelConfig) -> jnp.ndarray:
    b, s = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    x = constrain(x, "batch", "seq", None)

    def body(x, lp):
        h = apply_layernorm(lp["ln1"], x)
        x = x + _mha(lp["self_attn"], h, h, cfg, causal=True)
        h = apply_layernorm(lp["ln_x"], x)
        x = x + _mha(lp["cross_attn"], h, memory, cfg, causal=False)
        h = apply_layernorm(lp["ln2"], x)
        return x + apply_mlp(lp["mlp"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_layernorm(params["dec_ln"], x)
    return x @ params["embed"]["table"].T.astype(x.dtype)


def forward(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig) -> jnp.ndarray:
    memory = encode(params, batch["frames"], cfg)
    return decode_train(params, batch["tokens"], memory, cfg)


def encdec_loss(params: Params, batch: Dict[str, jnp.ndarray],
                cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict]:
    logits = forward(params, batch, cfg)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


# -- serving ------------------------------------------------------------------

def prefill(params: Params, frames: jnp.ndarray, tokens: jnp.ndarray,
            cfg: ModelConfig, max_len: int):
    """Encode + run prompt tokens; returns (logits, caches).

    caches = list per decoder layer: {"self": KVCache, "cross_k/v"}."""
    memory = encode(params, frames, cfg)
    b, s = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = x + sinusoid(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
    caches: List[Any] = []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda l: l[i], params["dec_layers"])
        h = apply_layernorm(lp["ln1"], x)
        q = jnp.einsum("bsd,dhk->bhsk", h, lp["self_attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bhsk", h, lp["self_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bhsk", h, lp["self_attn"]["wv"])
        o = attn_lib.full_attention(q, k, v, causal=True, window=None,
                                    impl="chunked", chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bhsk,hkd->bsd", o, lp["self_attn"]["wo"])
        kc = jnp.zeros((b, cfg.num_kv_heads, max_len, cfg.hd), k.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
        h = apply_layernorm(lp["ln_x"], x)
        ck = jnp.einsum("bsd,dhk->bhsk", memory, lp["cross_attn"]["wk"])
        cv = jnp.einsum("bsd,dhk->bhsk", memory, lp["cross_attn"]["wv"])
        qx = jnp.einsum("bsd,dhk->bhsk", h, lp["cross_attn"]["wq"])
        ox = attn_lib.full_attention(qx, ck, cv, causal=False, window=None,
                                     impl="chunked", chunk=cfg.attn_chunk)
        x = x + jnp.einsum("bhsk,hkd->bsd", ox, lp["cross_attn"]["wo"])
        h = apply_layernorm(lp["ln2"], x)
        x = x + apply_mlp(lp["mlp"], h, cfg)
        caches.append({"self": KVCache(kc, vc, jnp.asarray(s, jnp.int32)),
                       "cross_k": ck, "cross_v": cv})
    x = apply_layernorm(params["dec_ln"], x)
    logits = x @ params["embed"]["table"].T.astype(x.dtype)
    return logits, caches


def decode_step(params: Params, caches: List[Any], tokens: jnp.ndarray,
                cfg: ModelConfig):
    """tokens: [B] one step with self-KV cache + static cross K/V."""
    new_caches: List[Any] = []
    x = jnp.take(params["embed"]["table"], tokens[:, None], axis=0)
    pos = caches[0]["self"].pos
    x = x + sinusoid(pos[None, None], cfg.d_model).astype(x.dtype)
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda l: l[i], params["dec_layers"])
        c = caches[i]
        h = apply_layernorm(lp["ln1"], x)
        a, kv = attn_lib.decode_attention(lp["self_attn"], h, c["self"],
                                          cfg, rope=False)
        x = x + a
        h = apply_layernorm(lp["ln_x"], x)
        q = jnp.einsum("bsd,dhk->bhsk", h, lp["cross_attn"]["wq"])
        o = attn_lib.full_attention(q, c["cross_k"], c["cross_v"],
                                    causal=False, window=None, impl="ref")
        x = x + jnp.einsum("bhsk,hkd->bsd", o, lp["cross_attn"]["wo"])
        h = apply_layernorm(lp["ln2"], x)
        x = x + apply_mlp(lp["mlp"], h, cfg)
        new_caches.append({"self": kv, "cross_k": c["cross_k"],
                           "cross_v": c["cross_v"]})
    x = apply_layernorm(params["dec_ln"], x)
    logits = x @ params["embed"]["table"].T.astype(x.dtype)
    return logits[:, 0], new_caches
