"""Mixture-of-Experts FFN with expert-parallel dispatch = MaRe repartitionBy.

The token->expert shuffle IS the paper's repartitionBy primitive
(keyBy = router argmax, HashPartitioner = expert-owner map): tokens are
packed into a [num_shards, capacity] send buffer with the same
``_pack_by_dest`` used by ``MaRe.repartition_by`` and exchanged with one
``lax.all_to_all`` over the ``model`` mesh axis (DESIGN.md §3.2).

Two expert-compute layouts (a §Perf hillclimb axis):
  * ``weight_gather`` — expert weights are FSDP-sharded over ``data`` and
    all-gathered per layer (ZeRO-3; weight-stationary).
  * ``token_gather``  — tokens are all-gathered over ``data`` and each data
    shard computes its f-slice for the whole row, reduce-scattering the
    output (activation-stationary TP).
Dense reference path (no shard_map, exact) validates both.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.shuffle import _pack_by_dest, unpack_gather
from repro.models.common import ModelConfig, trunc_normal
from repro.sharding import active

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"router": trunc_normal(k1, (d, E), jnp.float32),
            "w1": trunc_normal(k2, (E, d, f), dt),
            "w3": trunc_normal(k3, (E, d, f), dt),
            "w2": trunc_normal(k4, (E, f, d), dt)}


def moe_logical_axes(cfg: ModelConfig) -> Params:
    return {"router": ("embed", None),
            "w1": ("experts", None, "expert_ff"),
            "w3": ("experts", None, "expert_ff"),
            "w2": ("experts", "expert_ff", None)}


class MoEStats(NamedTuple):
    aux_loss: jnp.ndarray        # load-balancing loss (f32 scalar)
    dropped: jnp.ndarray         # tokens dropped to capacity (f32 scalar)


def _route(p: Params, x2d: jnp.ndarray, cfg: ModelConfig):
    """x2d: [T, d] -> (topk idx [T,k], gates [T,k], aux_loss)."""
    logits = (x2d.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    E = cfg.num_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    f_e = jnp.mean(onehot, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e)
    return idx, gates.astype(x2d.dtype), aux


def _expert_mlp(w1, w3, w2, xs: jnp.ndarray, group_sizes: jnp.ndarray
                ) -> jnp.ndarray:
    """Grouped SwiGLU over tokens sorted by expert (ragged_dot)."""
    h = jax.lax.ragged_dot(xs, w1, group_sizes)
    u = jax.lax.ragged_dot(xs, w3, group_sizes)
    h = jax.nn.silu(h) * u
    return jax.lax.ragged_dot(h, w2, group_sizes)


# ---------------------------------------------------------------------------
# Dense reference path (exact; smoke tests + oracles)
# ---------------------------------------------------------------------------

def moe_ffn_dense(p: Params, x: jnp.ndarray, cfg: ModelConfig
                  ) -> Tuple[jnp.ndarray, MoEStats]:
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    t = x2.shape[0]
    idx, gates, aux = _route(p, x2, cfg)
    k = cfg.experts_per_token
    flat_e = idx.reshape(-1)                       # [T*k]
    flat_x = jnp.repeat(x2, k, axis=0)             # [T*k, d]
    order = jnp.argsort(flat_e, stable=True)
    xs = jnp.take(flat_x, order, axis=0, mode="clip")
    es = jnp.take(flat_e, order, mode="clip")
    group_sizes = jnp.bincount(es, length=cfg.num_experts)
    ys = _expert_mlp(p["w1"], p["w3"], p["w2"], xs, group_sizes)
    y_flat = jnp.zeros_like(flat_x).at[order].set(ys)
    y = jnp.sum(y_flat.reshape(t, k, d) * gates[..., None], axis=1)
    return y.reshape(b, s, d), MoEStats(aux_loss=aux,
                                        dropped=jnp.zeros((), jnp.float32))


# ---------------------------------------------------------------------------
# Expert-parallel path: repartitionBy over the `model` axis (shard_map)
# ---------------------------------------------------------------------------

def moe_ffn_sharded(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                    mode: Optional[str] = None) -> Tuple[jnp.ndarray,
                                                        MoEStats]:
    """x: [B, S, d] sharded (batch->data(+pod), seq->model)."""
    mode = mode or cfg.moe_mode
    rules, mesh = active()
    if mesh is None or "model" not in mesh.shape or \
            mesh.shape["model"] == 1 or \
            cfg.num_experts % mesh.shape["model"] != 0:
        return moe_ffn_dense(p, x, cfg)
    m = int(mesh.shape["model"])
    e_loc = cfg.num_experts // m
    k = cfg.experts_per_token
    # FSDP axes for expert weights (everything except 'model')
    fsdp_axes = tuple(a for a in mesh.axis_names if a != "model")
    fsdp = 1
    for a in fsdp_axes:
        fsdp *= int(mesh.shape[a])
    f = cfg.moe_d_ff
    f_shard = (fsdp if (f % fsdp == 0 and fsdp > 1) else 1)
    f_axes = fsdp_axes if f_shard > 1 else ()

    batch_axes = rules.table.get("batch") if rules else "data"
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    seq_ax = rules.table.get("seq") if rules else "model"
    b_dim, s_dim = x.shape[0], x.shape[1]
    b_size = 1
    for a in (batch_axes or ()):
        b_size *= int(mesh.shape[a])
    if b_dim % max(b_size, 1) != 0:
        batch_axes = None
    if seq_ax is not None and (s_dim % int(mesh.shape.get(seq_ax, 1)) != 0
                               or s_dim == 1):
        seq_ax = None  # decode / non-divisible: replicate seq over model

    x_spec = P(batch_axes, seq_ax, None)
    w_spec = P("model", None, f_axes if f_axes else None)
    w2_spec = P("model", f_axes if f_axes else None, None)

    def inner(xl, router, w1, w3, w2):
        bl, sl, d = xl.shape
        dt = cfg.param_dtype
        x2 = xl.reshape(-1, d).astype(dt)
        tl = x2.shape[0]
        idx, gates, aux = _route({"router": router}, x2, cfg)
        gates = gates.astype(dt)
        flat_e = idx.reshape(-1)                   # [tl*k] expert ids
        owner = flat_e // e_loc                    # destination model shard
        flat_x = jnp.repeat(x2, k, axis=0).astype(dt)
        cap = max(1, int(tl * k / m * cfg.capacity_factor))
        part_records = (flat_x, flat_e.astype(jnp.int32))
        valid = jnp.ones((tl * k,), bool)
        pack1 = _pack_by_dest(part_records, owner, valid, m, cap)
        bx, be = pack1.buffer
        rx = jax.lax.all_to_all(bx, "model", 0, 0)      # [m, cap, d]
        re = jax.lax.all_to_all(be, "model", 0, 0)
        rc = jax.lax.all_to_all(
            pack1.counts.reshape(m, 1), "model", 0, 0).reshape(m)
        dropped = pack1.dropped
        slot_ok = (jnp.arange(cap)[None, :] < rc[:, None]).reshape(-1)
        rx = rx.reshape(-1, d)
        re_l = re.reshape(-1) - jax.lax.axis_index("model") * e_loc
        re_l = jnp.where(slot_ok, re_l, e_loc)         # invalid -> sentinel
        # pack by LOCAL expert into [e_loc, cap_e, d] blocks so the expert
        # compute is one MXU-shaped batched einsum (ragged_dot decomposes
        # to e_loc dense per-group matmuls over ALL rows on some backends —
        # a measured ~14x flop waste; see EXPERIMENTS.md §Perf kimi-1).
        cap_e = max(1, int(m * cap / e_loc * cfg.capacity_factor))
        pack2 = _pack_by_dest((rx.astype(dt),), re_l, slot_ok, e_loc,
                              cap_e)
        (bx2,) = pack2.buffer
        if mode == "token_gather" and f_shard > 1:
            # activation-stationary: replicate packed tokens over the fsdp
            # axes, compute the local f-slice, reduce-scatter partial sums
            # back (the down-proj contracts f so partials sum exactly).
            xg = jax.lax.all_gather(bx2, f_axes, axis=1, tiled=True)
            h = jnp.einsum("ecd,edf->ecf", xg, w1)
            u = jnp.einsum("ecd,edf->ecf", xg, w3)
            yg = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w2)
            ys_blk = jax.lax.psum_scatter(
                yg, f_axes[0] if len(f_axes) == 1 else f_axes,
                scatter_dimension=1, tiled=True)
        else:
            if f_shard > 1:
                w1 = jax.lax.all_gather(w1, f_axes, axis=2, tiled=True)
                w3 = jax.lax.all_gather(w3, f_axes, axis=2, tiled=True)
                w2 = jax.lax.all_gather(w2, f_axes, axis=1, tiled=True)
            h = jnp.einsum("ecd,edf->ecf", bx2, w1)
            u = jnp.einsum("ecd,edf->ecf", bx2, w3)
            ys_blk = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w2)
        # gather expert outputs back to recv-slot layout (pure gather —
        # the pack's inverse; dropped slots read the sentinel zero row)
        y_unsort = unpack_gather(ys_blk.reshape(-1, d), pack2, cap_e)
        dropped = dropped + pack2.dropped
        y_buf = y_unsort.reshape(m, cap, d)
        y_back = jax.lax.all_to_all(y_buf, "model", 0, 0)  # [m, cap, d]
        y_per_choice = unpack_gather(y_back.reshape(-1, d), pack1, cap)
        y2 = jnp.sum(y_per_choice.reshape(tl, k, d) *
                     gates[..., None], axis=1)
        all_axes = tuple(mesh.axis_names)
        n_drop = jax.lax.psum(dropped.astype(jnp.float32), all_axes)
        aux = jax.lax.pmean(aux, all_axes)
        return (y2.reshape(bl, sl, d), aux[None],
                n_drop.astype(jnp.float32)[None])

    y, aux, dropped = compat.shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w2_spec),
        out_specs=(x_spec, P(), P()),
        check_vma=False,
    )(x, p["router"], p["w1"], p["w3"], p["w2"])
    return y, MoEStats(aux_loss=aux[0], dropped=dropped[0])


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig,
            mode: Optional[str] = None) -> Tuple[jnp.ndarray, MoEStats]:
    _, mesh = active()
    if mesh is not None and mesh.shape.get("model", 1) > 1 and \
            cfg.num_experts % mesh.shape["model"] == 0:
        return moe_ffn_sharded(p, x, cfg, mode=mode or cfg.moe_mode)
    return moe_ffn_dense(p, x, cfg)
