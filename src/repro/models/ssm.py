"""Selective SSM (Mamba-style) block with chunked scan + O(1) decode state.

Train/prefill uses a *chunked* selective scan: within a chunk the linear
recurrence h_t = a_t * h_{t-1} + b_t is evaluated with an associative scan
(parallel, VPU-friendly); chunks are chained with a tiny carried state via
``lax.scan`` — memory O(chunk * d_inner * n) instead of O(seq * ...), which
is what lets hymba's 32k prefill fit (DESIGN.md §5).

Decode keeps (conv window, h state) — constant per step, which is why the
SSM/hybrid archs are the ones assigned the 524k-token cell.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, trunc_normal

Params = Dict[str, Any]


def init_ssm(key, cfg: ModelConfig, d_in: Optional[int] = None) -> Params:
    d = d_in or cfg.d_model
    di = d * max(cfg.ssm_expand, 1)
    n = cfg.ssm_state
    dt = cfg.param_dtype
    ks = jax.random.split(key, 6)
    return {
        "in_proj": trunc_normal(ks[0], (d, 2 * di), dt),
        "conv_w": trunc_normal(ks[1], (cfg.ssm_conv, di), dt),
        "x_proj": trunc_normal(ks[2], (di, 2 * n + 1), dt),  # B, C, dt
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),                  # [di, n]
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": trunc_normal(ks[3], (di, d), dt),
    }


def ssm_logical_axes(cfg: ModelConfig) -> Params:
    return {"in_proj": ("embed", "ff"), "conv_w": ("conv", "ff"),
            "x_proj": ("ff", None), "dt_bias": ("ff",),
            "a_log": ("ff", "state"), "d_skip": ("ff",),
            "out_proj": ("ff", "embed")}


class SSMState(NamedTuple):
    conv: jnp.ndarray    # [B, conv_width-1, di] trailing inputs
    h: jnp.ndarray       # [B, di, n] recurrent state (f32)


def init_ssm_state(cfg: ModelConfig, batch: int,
                   d_in: Optional[int] = None) -> SSMState:
    d = d_in or cfg.d_model
    di = d * max(cfg.ssm_expand, 1)
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di), cfg.param_dtype),
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32))


def _ssm_coeffs(p: Params, xc: jnp.ndarray):
    """xc: [..., di] post-conv activations -> (a, bx, c) scan coefficients.

    a = exp(dt * A)  [.., di, n];  bx = dt * B * x  [.., di, n];  c [.., n].
    """
    proj = xc @ p["x_proj"].astype(xc.dtype)             # [.., 2n+1]
    n = p["a_log"].shape[1]
    bb, cc, dtr = (proj[..., :n], proj[..., n:2 * n], proj[..., 2 * n])
    dt_ = jax.nn.softplus(dtr.astype(jnp.float32)[..., None]
                          + p["dt_bias"])                # [.., di]
    a = jnp.exp(-jnp.exp(p["a_log"]) * dt_[..., None])   # [.., di, n]
    bx = (dt_ * xc.astype(jnp.float32))[..., None] * \
        bb.astype(jnp.float32)[..., None, :]             # [.., di, n]
    return a, bx, cc.astype(jnp.float32)


def _chunk_scan(a, bx, h0):
    """Associative scan of h_t = a_t h_{t-1} + bx_t within a chunk.

    a, bx: [T, B, di, n]; h0: [B, di, n] -> (h_all [T, B, di, n], h_T)."""
    def combine(x, y):
        ax, bxx = x
        ay, byy = y
        return ax * ay, ay * bxx + byy

    a_c, b_c = jax.lax.associative_scan(combine, (a, bx), axis=0)
    h_all = a_c * h0[None] + b_c
    return h_all, h_all[-1]


def ssm_scan(p: Params, xc: jnp.ndarray, cfg: ModelConfig,
             h0: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xc: [B, S, di] -> (y [B, S, di], h_final [B, di, n]).

    Chunked: lax.scan over chunks of cfg.ssm_chunk."""
    b, s, di = xc.shape
    n = cfg.ssm_state
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    xp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    nc = xp.shape[1] // chunk
    xp = xp.reshape(b, nc, chunk, di).transpose(1, 2, 0, 3)  # [nc,T,B,di]
    # padded steps must be identity on the carried state (a=1, bx=0)
    valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        xch, vch = inp
        a, bx, c = _ssm_coeffs(p, xch)                   # [T,B,di,n],[T,B,n]
        v = vch[:, None, None, None]
        a = jnp.where(v, a, 1.0)
        bx = jnp.where(v, bx, 0.0)
        h_all, h_last = _chunk_scan(a, bx, h)
        y = jnp.einsum("tbdn,tbn->tbd", h_all, c)
        return h_last, y

    # recompute chunk internals in backward: the [T,B,di,n] coefficient
    # tensors are the dominant SSM memory cost (§Perf hymba-2)
    step = jax.checkpoint(step)
    h_final, ys = jax.lax.scan(step, h0, (xp, valid))
    y = ys.transpose(2, 0, 1, 3).reshape(b, nc * chunk, di)[:, :s]
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    return y.astype(xc.dtype), h_final


def ssm_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              state: Optional[SSMState] = None
              ) -> Tuple[jnp.ndarray, Optional[SSMState]]:
    """Full Mamba-ish block: in_proj -> conv -> SiLU -> SSM -> gate -> out.

    x: [B, S, d].  With ``state`` given, runs statefully (S may be 1 for
    decode) and returns the updated state.
    """
    b, s, d = x.shape
    xz = x @ p["in_proj"]                                 # [B, S, 2*di]
    xi, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv along seq
    cw = cfg.ssm_conv
    if state is not None:
        xin = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)
    else:
        xin = jnp.pad(xi, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(xin[:, i:i + s] * p["conv_w"][i] for i in range(cw))
    xc = jax.nn.silu(conv)
    h0 = state.h if state is not None else None
    y, h_final = ssm_scan(p, xc, cfg, h0=h0)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = None
    if state is not None:
        tail = xin[:, -(cw - 1):] if cw > 1 else xin[:, :0]
        new_state = SSMState(conv=tail.astype(cfg.param_dtype), h=h_final)
    return out, new_state
