"""Attention: GQA + RoPE with three interchangeable implementations.

impl = "ref"     — full score materialization (tiny smoke tests / oracles)
impl = "chunked" — lax.scan over KV blocks with online softmax: O(chunk)
                   memory, pure jnp, shard-agnostic.  This is the
                   memory-efficient path the 512-device dry-run compiles
                   (Pallas does not lower on the CPU host platform).
impl = "pallas"  — the flash-attention kernel (TPU runtime path).

Decode helpers maintain a KV cache [B, KV, S_max, hd] with a write cursor;
``sliding window`` caches keep only the last `window` positions (ring
buffer), which is what makes hymba's long_500k cell O(window) per step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.common import ModelConfig, trunc_normal
from repro.sharding import constrain

Params = Dict[str, Any]
NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None
                   ) -> Params:
    d = d_in or cfg.d_model
    hd, H, KV = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"wq": trunc_normal(k1, (d, H, hd), dt),
            "wk": trunc_normal(k2, (d, KV, hd), dt),
            "wv": trunc_normal(k3, (d, KV, hd), dt),
            "wo": trunc_normal(k4, (H, hd, d), dt)}


def attention_logical_axes(cfg: ModelConfig) -> Params:
    return {"wq": ("embed", "heads", "hd"),
            "wk": ("embed", "kv", "hd"),
            "wv": ("embed", "kv", "hd"),
            "wo": ("heads", "hd", "embed")}


def _project_qkv(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if rope:
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool, window: Optional[int],
                      chunk: int = 512,
                      q_offset: int | jnp.ndarray = 0,
                      kv_len: Optional[jnp.ndarray] = None,
                      remat_chunks: bool = True) -> jnp.ndarray:
    """Online-softmax attention scanning KV in blocks (pure jnp).

    q: [B, Hq, Sq, hd]; k/v: [B, KV, Sk, hd].  ``q_offset``: absolute
    position of q[0] minus kv[0] (right-aligned when Sq != Sk).
    ``kv_len``: dynamic valid KV length (decode with a partially-filled
    cache).  f32 accumulators; memory O(Sq * chunk).

    Layout note: all per-chunk tensors stay in FULL-head space
    [B, Hq, ...] (GQA KV is broadcast per chunk) with an explicit "heads"
    sharding constraint — the grouped [B, KV, group, ...] layout defeats
    head-TP propagation (a measured 4-16x per-device score blow-up;
    EXPERIMENTS.md §Perf kimi-3).  ``remat_chunks`` recomputes chunk
    internals in the backward pass instead of saving [nchunks, ...]
    stacks.
    """
    b, hq, sq, hd = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    nchunks = (sk + chunk - 1) // chunk
    pad = nchunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kp = kp.reshape(b, hkv, nchunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(b, hkv, nchunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    # flash-style dtype discipline: big HBM tensors (q, k, v, p) stay in
    # the input dtype; only softmax stats and the accumulator are f32
    # (mirrors the Pallas kernel's VMEM behaviour on the XLA fallback —
    # EXPERIMENTS.md §Perf kimi-5).  GQA stays in grouped-einsum form:
    # materializing repeated KV amplified the per-chunk KV gather by
    # `group`x on seq-sharded layouts (§Perf kimi-4/deepseek regression).
    qg = q.reshape(b, hkv, group, sq, hd)
    qpos = jnp.arange(sq) + q_offset          # absolute q positions

    def step(carry, inp):
        m, l, acc = carry
        ci, kc, vc = inp
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        kpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal or window is not None:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        mask &= (kpos < sk)[None, :]
        if kv_len is not None:
            mask &= (kpos < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(q.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    if remat_chunks:
        step = jax.checkpoint(step)
    m0 = jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(nchunks), kp, vp))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(b, hq, sq, hd).astype(q.dtype)


def full_attention(q, k, v, causal, window, impl: str = "ref",
                   chunk: int = 512, q_offset=0):
    """Dispatch over implementations; q/k/v: [B, H(/KV), S, hd]."""
    if impl == "pallas":
        # kernel expects [B, H, S, D] layout
        return flash_attention(q, k, v, causal=causal, window=window)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal, window, chunk=chunk,
                                 q_offset=q_offset)
    return attention_ref(q, k, v, causal=causal, window=window)


def resolve_impl(cfg: ModelConfig, seq: int) -> str:
    if cfg.attn_impl != "auto":
        return cfg.attn_impl
    if jax.default_backend() == "tpu":
        return "pallas"
    return "chunked" if seq > 2048 else "ref"


def self_attention(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   cfg: ModelConfig, window: Optional[int] = None,
                   impl: Optional[str] = None, return_kv: bool = False):
    """Causal self-attention over x: [B, S, d]."""
    b, s, d = x.shape
    impl = impl or resolve_impl(cfg, s)
    q, k, v = _project_qkv(p, x, positions, cfg)
    q = constrain(q, "batch", "seq", "heads", None)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = full_attention(qt, kt, vt, causal=True, window=window, impl=impl,
                         chunk=cfg.attn_chunk)
    out = out.transpose(0, 2, 1, 3)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, "batch", "seq", None)
    if return_kv:
        return y, (kt, vt)
    return y


# -- KV cache & decode ---------------------------------------------------------

class KVCache:
    """KV cache; ``window`` is static pytree metadata (0 = full cache,
    >0 = ring buffer of the last `window` positions)."""

    def __init__(self, k: jnp.ndarray, v: jnp.ndarray, pos: jnp.ndarray,
                 window: int = 0):
        self.k, self.v, self.pos, self.window = k, v, pos, int(window)

    @property
    def cache_len(self) -> int:
        return self.k.shape[2]


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.k, c.v, c.pos), c.window),
    lambda window, ch: KVCache(ch[0], ch[1], ch[2], window=window))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int] = None,
                  dtype=None) -> KVCache:
    size = min(window, max_len) if window else max_len
    dt = dtype or cfg.param_dtype
    shape = (batch, cfg.num_kv_heads, size, cfg.hd)
    return KVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                   pos=jnp.zeros((), jnp.int32), window=window or 0)


def decode_attn_raw(p: Params, x: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, pos: jnp.ndarray,
                    cfg: ModelConfig, window: int = 0, rope: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against raw cache arrays.

    x: [B, 1, d]; k/v_cache: [B, KV, S_cache, hd]; pos: absolute position
    of the new token.  Returns (y [B, 1, d], k', v')."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(
        p, x, jnp.full((b, 1), pos, jnp.int32), cfg, rope=rope)
    size = k_cache.shape[2]
    slot = (pos % size) if window else pos
    k = jax.lax.dynamic_update_slice(
        k_cache, k_new.transpose(0, 2, 1, 3).astype(k_cache.dtype),
        (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(
        v_cache, v_new.transpose(0, 2, 1, 3).astype(v_cache.dtype),
        (0, 0, slot, 0))
    qt = q.transpose(0, 2, 1, 3)                       # [B, H, 1, hd]
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    group = hq // hkv
    scale = 1.0 / (cfg.hd ** 0.5)
    # bf16 operands + f32 accumulate: upcasting k here makes XLA widen the
    # whole carried cache to f32 (2x cache traffic; §Perf kimi-d3)
    qg = qt.reshape(b, hkv, group, cfg.hd)
    s = jnp.einsum("bkgd,bkcd->bkgc", qg, k,
                   preferred_element_type=jnp.float32) * scale
    cpos = jnp.arange(size)
    if window:
        # ring buffer holds positions (pos - size, pos]; all slots valid
        # once pos + 1 >= size, else only slots 0..pos
        valid = jnp.where(pos + 1 >= size, jnp.ones_like(cpos, bool),
                          cpos <= pos)
    else:
        valid = cpos <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bkcd->bkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq, cfg.hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, k, v


def decode_attention(p: Params, x: jnp.ndarray, cache: KVCache,
                     cfg: ModelConfig, impl: str = "einsum",
                     rope: bool = True) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode: x [B, 1, d] against the cache."""
    y, k, v = decode_attn_raw(p, x, cache.k, cache.v, cache.pos, cfg,
                              window=cache.window, rope=rope)
    return y, KVCache(k=k, v=v, pos=cache.pos + 1, window=cache.window)
