"""Decoder LM assembly for all families: dense / moe / hybrid / ssm / vlm.

Layer stacks are scanned (``lax.scan`` over stacked params) with optional
remat — keeps HLO size O(1) in depth, which is what makes the 95-layer
deepseek-67b and 61-layer kimi-k2 dry-runs compile quickly at 512 devices.
Heterogeneous stacks (hymba's 3 global-attention layers, xLSTM's 7:1
mLSTM:sLSTM pattern) stay scannable via (a) traced per-layer window sizes
and (b) scanned units of (k-1) mLSTM + 1 sLSTM blocks.

Decode paths use python loops over layers (graphs are tiny; heterogeneous
caches are natural) — see ``decode_step``.
"""
from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import numpy as _np
import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import KVCache, init_kv_cache
from repro.models.common import ModelConfig, trunc_normal
from repro.models.layers import (apply_mlp, apply_norm, cross_entropy,
                                 embed_tokens, embedding_logical_axes,
                                 init_embedding, init_mlp, init_norm,
                                 mlp_logical_axes, unembed)
from repro.sharding import constrain

Params = Dict[str, Any]
BIG_WINDOW = 1 << 30   # "global attention" encoded as a huge window


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    if cfg.family in ("dense", "vlm", "audio"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["mlp"] = init_mlp(ks[1], cfg)
    elif cfg.family == "moe":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    elif cfg.family == "hybrid":
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg)
        p["mlp"] = init_mlp(ks[2], cfg)
        p["norm_a"] = init_norm(cfg)
        p["norm_s"] = init_norm(cfg)
    else:
        raise ValueError(cfg.family)
    return p


def block_logical_axes(cfg: ModelConfig) -> Params:
    norm = {"scale": ("embed",)} if not cfg.use_layernorm else \
        {"scale": ("embed",), "bias": ("embed",)}
    p: Params = {"norm1": dict(norm), "norm2": dict(norm)}
    if cfg.family in ("dense", "vlm", "audio"):
        p["attn"] = attn_lib.attention_logical_axes(cfg)
        p["mlp"] = mlp_logical_axes(cfg)
    elif cfg.family == "moe":
        p["attn"] = attn_lib.attention_logical_axes(cfg)
        p["moe"] = moe_lib.moe_logical_axes(cfg)
    elif cfg.family == "hybrid":
        p["attn"] = attn_lib.attention_logical_axes(cfg)
        p["ssm"] = ssm_lib.ssm_logical_axes(cfg)
        p["mlp"] = mlp_logical_axes(cfg)
        p["norm_a"] = dict(norm)
        p["norm_s"] = dict(norm)
    return p


def apply_block(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, window=None,
                moe_mode: Optional[str] = None,
                return_kv: bool = False):
    """Returns (x', aux_loss[, (kt, vt)])."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    h = apply_norm(cfg, p["norm1"], x)
    if cfg.family == "hybrid":
        a = attn_lib.self_attention(p["attn"], h, positions, cfg,
                                    window=window)
        s, _ = ssm_lib.ssm_block(p["ssm"], h, cfg)
        a = apply_norm(cfg, p["norm_a"], a)
        s = apply_norm(cfg, p["norm_s"], s)
        x = x + 0.5 * (a + s)
    else:
        a = attn_lib.self_attention(p["attn"], h, positions, cfg,
                                    window=window, return_kv=return_kv)
        if return_kv:
            a, kv = a
        x = x + a
    x = constrain(x, "batch", "seq", None)
    h = apply_norm(cfg, p["norm2"], x)
    if cfg.family == "moe":
        y, stats = moe_lib.moe_ffn(p["moe"], h, cfg, mode=moe_mode)
        aux = aux + stats.aux_loss
    else:
        y = apply_mlp(p["mlp"], h, cfg)
    x = x + y
    x = constrain(x, "batch", "seq", None)
    if return_kv:
        return x, aux, kv
    return x, aux


# ---------------------------------------------------------------------------
# xLSTM stack (units of (k-1) mLSTM + 1 sLSTM)
# ---------------------------------------------------------------------------

def _xlstm_unit_shape(cfg: ModelConfig) -> Tuple[int, int]:
    k = cfg.slstm_every or cfg.num_layers + 1
    if k > cfg.num_layers:
        return cfg.num_layers, 0     # all-mLSTM
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return k - 1, cfg.num_layers // k


def init_xlstm_stack(key, cfg: ModelConfig) -> Params:
    m_per, units = _xlstm_unit_shape(cfg)
    if units == 0:
        keys = jax.random.split(key, cfg.num_layers)
        m = jax.vmap(lambda k: {"norm": init_norm(cfg),
                                "core": xlstm_lib.init_mlstm(k, cfg)})(keys)
        return {"m_blocks": m}
    km = jax.random.split(jax.random.fold_in(key, 0), units * m_per)
    ks = jax.random.split(jax.random.fold_in(key, 1), units)
    m = jax.vmap(lambda k: {"norm": init_norm(cfg),
                            "core": xlstm_lib.init_mlstm(k, cfg)})(km)
    m = jax.tree.map(lambda l: l.reshape(units, m_per, *l.shape[1:]), m)
    s = jax.vmap(lambda k: {"norm": init_norm(cfg),
                            "core": xlstm_lib.init_slstm(k, cfg)})(ks)
    return {"m_blocks": m, "s_blocks": s}


def xlstm_stack_logical_axes(cfg: ModelConfig) -> Params:
    m_per, units = _xlstm_unit_shape(cfg)
    norm = {"scale": ("embed",)}
    m = {"norm": dict(norm), "core": xlstm_lib.mlstm_logical_axes(cfg)}
    m = jax.tree.map(lambda ax: (("layers", "layers") if units else
                                 ("layers",)) + tuple(ax), m,
                     is_leaf=lambda t: isinstance(t, tuple))
    out = {"m_blocks": m}
    if units:
        s = {"norm": dict(norm), "core": xlstm_lib.slstm_logical_axes(cfg)}
        out["s_blocks"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), s,
            is_leaf=lambda t: isinstance(t, tuple))
    return out


def apply_xlstm_stack(p: Params, x: jnp.ndarray, cfg: ModelConfig
                      ) -> jnp.ndarray:
    m_per, units = _xlstm_unit_shape(cfg)

    def m_block(x, bp):
        h = apply_norm(cfg, bp["norm"], x)
        y, _ = xlstm_lib.mlstm_block(bp["core"], h, cfg)
        return x + y

    def s_block(x, bp):
        h = apply_norm(cfg, bp["norm"], x)
        y, _ = xlstm_lib.slstm_block(bp["core"], h, cfg)
        return x + y

    if units == 0:
        def body(x, bp):
            return (m_block(x, bp), None)
        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, p["m_blocks"])
        return x

    def unit(x, up):
        def inner(x, bp):
            return m_block(x, bp), None
        x, _ = jax.lax.scan(inner, x, up["m"])
        return s_block(x, up["s"]), None

    unit = jax.checkpoint(unit) if cfg.remat else unit
    x, _ = jax.lax.scan(unit, x, {"m": p["m_blocks"], "s": p["s_blocks"]})
    return x


# ---------------------------------------------------------------------------
# LM init / forward
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> Params:
    k_embed, k_blocks, k_extra = jax.random.split(key, 3)
    params: Params = {"embed": init_embedding(k_embed, cfg),
                      "final_norm": init_norm(cfg)}
    if cfg.family == "ssm":
        params["xlstm"] = init_xlstm_stack(k_blocks, cfg)
    else:
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks"] = jax.vmap(
            lambda k: init_block(k, cfg))(keys)
    if cfg.family == "vlm" and cfg.num_patches:
        params["patch_proj"] = trunc_normal(
            k_extra, (cfg.d_model, cfg.d_model), cfg.param_dtype)
    return params


def lm_logical_axes(cfg: ModelConfig) -> Params:
    p: Params = {"embed": embedding_logical_axes(cfg),
                 "final_norm": {"scale": ("embed",)} if not cfg.use_layernorm
                 else {"scale": ("embed",), "bias": ("embed",)}}
    if cfg.family == "ssm":
        p["xlstm"] = xlstm_stack_logical_axes(cfg)
    else:
        blocks = block_logical_axes(cfg)
        p["blocks"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), blocks,
            is_leaf=lambda t: isinstance(t, tuple))
    if cfg.family == "vlm" and cfg.num_patches:
        p["patch_proj"] = ("embed", None)
    return p


def layer_windows(cfg: ModelConfig) -> Optional[_np.ndarray]:
    """Per-layer attention window (host array: static for cache setup,
    convertible for scan).  None = uniform full attention."""
    if cfg.window is None:
        return None
    w = [cfg.window] * cfg.num_layers
    for g in cfg.global_layers:
        w[g] = BIG_WINDOW
    return _np.asarray(w, _np.int32)


def forward(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            patch_embeds: Optional[jnp.ndarray] = None,
            moe_mode: Optional[str] = None,
            return_kv: bool = False):
    """tokens: [B, S_text] -> (logits [B, S, V], aux_loss[, (K, V)]).

    For vlm, ``patch_embeds`` [B, P, d] (stub frontend output) are
    projected and prepended; S = P + S_text.  With ``return_kv`` (uniform
    full-attention stacks only) the scan also emits the per-layer KV
    stacks [L, B, KV, S, hd] — the scanned-prefill path.
    """
    x = embed_tokens(params["embed"], tokens, cfg)
    if patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    kv_stack = None

    if cfg.family == "ssm":
        assert not return_kv
        x = apply_xlstm_stack(params["xlstm"], x, cfg)
    else:
        windows = layer_windows(cfg)
        assert not (return_kv and cfg.family == "hybrid")

        def body(carry, layer_in):
            x, aux = carry
            bp = layer_in["p"]
            w = layer_in.get("w")
            out = apply_block(bp, x, positions, cfg, window=w,
                              moe_mode=moe_mode, return_kv=return_kv)
            if return_kv:
                x, a, kv = out
                return (x, aux + a), kv
            x, a = out
            return (x, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        layer_in = {"p": params["blocks"]}
        if windows is not None:
            layer_in["w"] = jnp.asarray(windows, jnp.int32)
        if cfg.scan_layers:
            (x, aux_total), kv_stack = jax.lax.scan(
                body, (x, aux_total), layer_in)
        else:
            kvs = []
            for i in range(cfg.num_layers):
                li = jax.tree.map(lambda l: l[i], layer_in)
                (x, aux_total), kv = body((x, aux_total), li)
                kvs.append(kv)
            if return_kv:
                kv_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *kvs)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)
    if return_kv:
        return logits, aux_total, kv_stack
    return logits, aux_total


def lm_loss(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig, moe_mode: Optional[str] = None,
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg,
                          patch_embeds=batch.get("patch_embeds"),
                          moe_mode=moe_mode)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:       # vlm: skip patch positions
        logits = logits[:, -labels.shape[1]:]
    loss = cross_entropy(logits, labels, batch.get("mask"))
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

class StackedKV:
    """Uniform full-attention cache: k/v [L, B, KV, S_cache, hd].

    Decode scans over layers (stacked params + stacked cache) — O(1) HLO
    in depth, which keeps the 95-layer decode_32k dry-run compile small."""

    def __init__(self, k: jnp.ndarray, v: jnp.ndarray, pos: jnp.ndarray):
        self.k, self.v, self.pos = k, v, pos

    @property
    def cache_len(self) -> int:
        return self.k.shape[3]


jax.tree_util.register_pytree_node(
    StackedKV,
    lambda c: ((c.k, c.v, c.pos), None),
    lambda _, ch: StackedKV(*ch))


def init_stacked_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=None) -> StackedKV:
    dt = dtype or cfg.param_dtype
    shape = (cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.hd)
    return StackedKV(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                     pos=jnp.zeros((), jnp.int32))


def prefill_scanned(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                    max_len: int,
                    patch_embeds: Optional[jnp.ndarray] = None,
                    moe_mode: Optional[str] = None):
    """Scanned prefill for uniform stacks (dense / moe / vlm)."""
    logits, _, (kt, vt) = forward(params, tokens, cfg,
                                  patch_embeds=patch_embeds,
                                  moe_mode=moe_mode, return_kv=True)
    s = kt.shape[3]
    L, b = kt.shape[0], kt.shape[1]
    kc = jnp.zeros((L, b, cfg.num_kv_heads, max_len, cfg.hd), kt.dtype)
    vc = jnp.zeros_like(kc)
    kc = jax.lax.dynamic_update_slice(kc, kt, (0, 0, 0, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, vt, (0, 0, 0, 0, 0))
    return logits, StackedKV(k=kc, v=vc, pos=jnp.asarray(s, jnp.int32))


def decode_step_scanned(params: Params, cache: StackedKV,
                        tokens: jnp.ndarray, cfg: ModelConfig,
                        moe_mode: Optional[str] = None):
    """tokens [B] -> (logits [B, V], cache') via lax.scan over layers."""
    x = embed_tokens(params["embed"], tokens[:, None], cfg)
    pos = cache.pos

    def body(x, per_layer):
        bp, kc, vc = per_layer["p"], per_layer["k"], per_layer["v"]
        h = apply_norm(cfg, bp["norm1"], x)
        a, k2, v2 = attn_lib.decode_attn_raw(bp["attn"], h, kc, vc, pos,
                                             cfg)
        x = x + a
        h = apply_norm(cfg, bp["norm2"], x)
        if cfg.family == "moe":
            y, _ = moe_lib.moe_ffn(bp["moe"], h, cfg, mode=moe_mode)
        else:
            y = apply_mlp(bp["mlp"], h, cfg)
        # keep the stacked-cache writeback in the cache dtype: an f32
        # update slice makes XLA convert the WHOLE cache f32 and back
        # per layer (a measured 73%-of-traffic artifact; §Perf kimi-d2)
        return x + y, (k2.astype(kc.dtype), v2.astype(vc.dtype))

    x, (k_new, v_new) = jax.lax.scan(
        body, x, {"p": params["blocks"], "k": cache.k, "v": cache.v})
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)
    return logits[:, 0], StackedKV(k=k_new, v=v_new, pos=pos + 1)


class LayerCache(NamedTuple):
    kind: str                       # static: attn | ssm | mlstm | slstm
    kv: Optional[KVCache] = None
    ssm: Optional[ssm_lib.SSMState] = None
    mls: Optional[xlstm_lib.MLSTMState] = None
    sls: Optional[xlstm_lib.SLSTMState] = None


jax.tree_util.register_pytree_node(
    LayerCache,
    lambda c: ((c.kv, c.ssm, c.mls, c.sls), c.kind),
    lambda kind, ch: LayerCache(kind, *ch))


def init_cache(cfg: ModelConfig, batch: int, max_len: int
               ) -> List[Any]:
    """Per-layer cache list.  SWA layers get ring buffers (O(window));
    SSM/xLSTM layers get O(1) recurrent state — the long_500k enabler."""
    if cfg.family in ("dense", "moe", "vlm"):
        return init_stacked_cache(cfg, batch, max_len)
    caches: List[Any] = []
    if cfg.family == "ssm":
        m_per, units = _xlstm_unit_shape(cfg)
        for u in range(max(units, 1)):
            for i in range(m_per if units else cfg.num_layers):
                caches.append(LayerCache(
                    "mlstm", mls=xlstm_lib.init_mlstm_state(cfg, batch)))
            if units:
                caches.append(LayerCache(
                    "slstm", sls=xlstm_lib.init_slstm_state(cfg, batch)))
        return caches
    windows = layer_windows(cfg)
    for i in range(cfg.num_layers):
        w = None
        if windows is not None:
            wi = int(windows[i])
            w = None if wi >= BIG_WINDOW else wi
        kv = init_kv_cache(cfg, batch, max_len, window=w)
        if cfg.family == "hybrid":
            caches.append(LayerCache(
                "hybrid", kv=kv, ssm=ssm_lib.init_ssm_state(cfg, batch)))
        else:
            caches.append(LayerCache("attn", kv=kv))
    return caches


def decode_step(params: Params, caches: Any, tokens: jnp.ndarray,
                cfg: ModelConfig, moe_mode: Optional[str] = None
                ) -> Tuple[jnp.ndarray, Any]:
    """tokens: [B] -> (logits [B, V], caches').

    StackedKV caches take the scanned path; heterogeneous list caches
    (hybrid / ssm) loop over layers."""
    if isinstance(caches, StackedKV):
        return decode_step_scanned(params, caches, tokens, cfg,
                                   moe_mode=moe_mode)
    x = embed_tokens(params["embed"], tokens[:, None], cfg)
    new_caches: List[Any] = []
    if cfg.family == "ssm":
        x = _xlstm_decode(params["xlstm"], x, cfg, caches, new_caches)
    else:
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda l: l[i], params["blocks"])
            c = caches[i]
            h = apply_norm(cfg, bp["norm1"], x)
            if cfg.family == "hybrid":
                a, kv = attn_lib.decode_attention(bp["attn"], h, c.kv, cfg)
                sout, sst = ssm_lib.ssm_block(bp["ssm"], h, cfg, state=c.ssm)
                a = apply_norm(cfg, bp["norm_a"], a)
                sout = apply_norm(cfg, bp["norm_s"], sout)
                x = x + 0.5 * (a + sout)
                new_caches.append(LayerCache("hybrid", kv=kv, ssm=sst))
            else:
                a, kv = attn_lib.decode_attention(bp["attn"], h, c.kv, cfg)
                x = x + a
                new_caches.append(LayerCache("attn", kv=kv))
            h = apply_norm(cfg, bp["norm2"], x)
            if cfg.family == "moe":
                y, _ = moe_lib.moe_ffn(bp["moe"], h, cfg, mode=moe_mode)
            else:
                y = apply_mlp(bp["mlp"], h, cfg)
            x = x + y
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg)
    return logits[:, 0], new_caches


def _xlstm_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                  caches: List[Any], new_caches: List[Any]) -> jnp.ndarray:
    m_per, units = _xlstm_unit_shape(cfg)
    ci = 0
    for u in range(max(units, 1)):
        n_m = m_per if units else cfg.num_layers
        for i in range(n_m):
            bp = jax.tree.map(
                lambda l: (l[u, i] if units else l[i]), p["m_blocks"])
            h = apply_norm(cfg, bp["norm"], x)
            y, st = xlstm_lib.mlstm_block(bp["core"], h, cfg,
                                          state=caches[ci].mls)
            x = x + y
            new_caches.append(LayerCache("mlstm", mls=st))
            ci += 1
        if units:
            bp = jax.tree.map(lambda l: l[u], p["s_blocks"])
            h = apply_norm(cfg, bp["norm"], x)
            y, st = xlstm_lib.slstm_block(bp["core"], h, cfg,
                                          state=caches[ci].sls)
            x = x + y
            new_caches.append(LayerCache("slstm", sls=st))
            ci += 1
    return x


def prefill(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: Optional[int] = None,
            patch_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, List[Any]]:
    """Run the full prompt, returning (logits [B, S, V], filled caches)."""
    b, s = tokens.shape
    max_len = max_len or s
    x = embed_tokens(params["embed"], tokens, cfg)
    if patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
        s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.family == "ssm":
        return prefill_ssm(params, tokens, cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        return prefill_scanned(params, tokens, cfg, max_len,
                               patch_embeds=patch_embeds)
    caches: List[Any] = []
    windows = layer_windows(cfg)
    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda l: l[i], params["blocks"])
        w = None
        wi_static = None
        if windows is not None:
            wi_static = int(windows[i])
            w = None if wi_static >= BIG_WINDOW else wi_static
        h = apply_norm(cfg, bp["norm1"], x)
        q, k, v = attn_lib._project_qkv(bp["attn"], h, positions, cfg)
        qt, kt, vt = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        o = attn_lib.full_attention(qt, kt, vt, causal=True, window=w,
                                    impl=attn_lib.resolve_impl(cfg, s),
                                    chunk=cfg.attn_chunk)
        a = jnp.einsum("bshk,hkd->bsd", o.transpose(0, 2, 1, 3),
                       bp["attn"]["wo"])
        kv = _fill_kv_cache(cfg, kt, vt, w, max_len, s)
        if cfg.family == "hybrid":
            from repro.sharding import active as _active
            _, _mesh = _active()
            if cfg.ssm_cp and _mesh is not None and \
                    _mesh.shape.get("model", 1) > 1 and \
                    s % int(_mesh.shape["model"]) == 0:
                from repro.models.ssm_cp import ssm_block_context_parallel
                sout = ssm_block_context_parallel(
                    bp["ssm"], h, cfg, _mesh,
                    batch_axes=tuple(a for a in ("pod", "data")
                                     if a in _mesh.shape))
                sst = ssm_lib.init_ssm_state(cfg, b)  # stateless prefill
            else:
                sout, sst = ssm_lib.ssm_block(
                    bp["ssm"], h, cfg,
                    state=ssm_lib.init_ssm_state(cfg, b))
            a2 = apply_norm(cfg, bp["norm_a"], a)
            s2 = apply_norm(cfg, bp["norm_s"], sout)
            x = x + 0.5 * (a2 + s2)
            caches.append(LayerCache("hybrid", kv=kv, ssm=sst))
        else:
            x = x + a
            caches.append(LayerCache("attn", kv=kv))
        h = apply_norm(cfg, bp["norm2"], x)
        if cfg.family == "moe":
            y, _ = moe_lib.moe_ffn(bp["moe"], h, cfg)
        else:
            y = apply_mlp(bp["mlp"], h, cfg)
        x = x + y
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(params["embed"], x, cfg), caches


def _fill_kv_cache(cfg: ModelConfig, kt, vt, window, max_len, s) -> KVCache:
    b = kt.shape[0]
    size = min(window, max_len) if window else max_len
    kc = jnp.zeros((b, cfg.num_kv_heads, size, cfg.hd), kt.dtype)
    vc = jnp.zeros_like(kc)
    if window:
        take = min(window, s)
        # ring layout: position p lives at slot p % size
        src = kt[:, :, s - take:s]
        slots = (jnp.arange(s - take, s)) % size
        kc = kc.at[:, :, slots].set(src)
        vc = vc.at[:, :, slots].set(vt[:, :, s - take:s])
    else:
        kc = jax.lax.dynamic_update_slice(kc, kt, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, vt, (0, 0, 0, 0))
    return KVCache(k=kc, v=vc, pos=jnp.asarray(s, jnp.int32),
                   window=window or 0)


def prefill_ssm(params: Params, tokens: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, List[Any]]:
    """xLSTM prefill: run blocks statefully, collecting final states."""
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    caches: List[Any] = []
    m_per, units = _xlstm_unit_shape(cfg)
    p = params["xlstm"]
    for u in range(max(units, 1)):
        n_m = m_per if units else cfg.num_layers
        for i in range(n_m):
            bp = jax.tree.map(
                lambda l: (l[u, i] if units else l[i]), p["m_blocks"])
            h = apply_norm(cfg, bp["norm"], x)
            y, st = xlstm_lib.mlstm_block(
                bp["core"], h, cfg, state=xlstm_lib.init_mlstm_state(cfg, b))
            x = x + y
            caches.append(LayerCache("mlstm", mls=st))
        if units:
            bp = jax.tree.map(lambda l: l[u], p["s_blocks"])
            h = apply_norm(cfg, bp["norm"], x)
            y, st = xlstm_lib.slstm_block(
                bp["core"], h, cfg, state=xlstm_lib.init_slstm_state(cfg, b))
            x = x + y
            caches.append(LayerCache("slstm", sls=st))
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(params["embed"], x, cfg), caches
