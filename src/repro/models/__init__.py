"""Unified model API over all families.

``build_model(cfg)`` returns a :class:`Model` namespace with
init / loss / forward / prefill / decode_step / logical_axes — the single
surface the trainer, server, dry-run and tests all use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.common import (ModelConfig, active_param_count,
                                 param_count, param_count_analytic)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]              # loss(params, batch) -> (l, m)
    forward: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    logical_axes: Callable[[], Any]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss=lambda p, b: encdec.encdec_loss(p, b, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            prefill=lambda p, b, max_len: encdec.prefill(
                p, b["frames"], b["tokens"], cfg, max_len),
            decode_step=lambda p, caches, tok: encdec.decode_step(
                p, caches, tok, cfg),
            init_cache=None,
            logical_axes=lambda: encdec.encdec_logical_axes(cfg))

    def loss(p, b):
        return transformer.lm_loss(p, b, cfg)

    def fwd(p, b):
        logits, _ = transformer.forward(
            p, b["tokens"], cfg, patch_embeds=b.get("patch_embeds"))
        return logits

    def pre(p, b, max_len):
        if cfg.family == "ssm":
            return transformer.prefill_ssm(p, b["tokens"], cfg)
        return transformer.prefill(p, b["tokens"], cfg, max_len=max_len,
                                   patch_embeds=b.get("patch_embeds"))

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_lm(key, cfg),
        loss=loss,
        forward=fwd,
        prefill=pre,
        decode_step=lambda p, caches, tok: transformer.decode_step(
            p, caches, tok, cfg),
        init_cache=lambda batch, max_len: transformer.init_cache(
            cfg, batch, max_len),
        logical_axes=lambda: transformer.lm_logical_axes(cfg))


__all__ = ["Model", "ModelConfig", "build_model", "param_count",
           "param_count_analytic", "active_param_count"]
