"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 1
    window: Optional[int] = None          # sliding-window size (SWA layers)
    global_layers: Tuple[int, ...] = ()   # full-attention layer ids (hymba)
    # xLSTM
    slstm_every: int = 0                  # 1 sLSTM per this many blocks
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend output length
    # vlm
    num_patches: int = 0                  # stub vision tokens
    # common
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    use_layernorm: bool = False           # whisper uses LN+bias
    use_gelu: bool = False                # whisper MLP
    dtype: str = "bfloat16"               # activation/param dtype
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "auto"               # ref | chunked | pallas | auto
    attn_chunk: int = 512
    ssm_chunk: int = 256
    capacity_factor: float = 1.25
    moe_mode: str = "weight_gather"   # weight_gather | token_gather
    ssm_cp: bool = False              # context-parallel SSM (seq sharded)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def param_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run long_500k?  (SSM / hybrid-with-window)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid" and self.window is not None:
            return True
        return False

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced config of the same family (per-arch smoke tests)."""
        return dataclasses.replace(self, **overrides)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig) -> int:
    """6*N*D accounting: N = active params (MoE: top-k experts only)."""
    total = param_count_analytic(cfg)
    if not cfg.is_moe:
        return total
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff
    inactive = (cfg.num_experts - cfg.experts_per_token) * expert_p
    return total - cfg.num_layers * inactive


def param_count_analytic(cfg: ModelConfig) -> int:
    """Closed-form parameter count (embedding + per-layer weights)."""
    d, hd = cfg.d_model, cfg.hd
    attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
        + cfg.num_heads * hd * d
    if cfg.is_moe:
        ffn = cfg.num_experts * 3 * d * cfg.moe_d_ff \
            + d * cfg.num_experts          # router
    elif cfg.family == "ssm":
        ffn = 0
        di = d * max(cfg.ssm_expand, 1)
        attn = 0
        # mLSTM blocks: qkv + gates + out
        attn = 3 * d * di + 2 * d + di * d
    else:
        ffn = 3 * d * cfg.d_ff
    per_layer = attn + ffn + 2 * d
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    enc = 0
    if cfg.encoder_layers:
        enc = cfg.encoder_layers * (4 * d * d + 2 * d * cfg.d_ff + 2 * d)
        per_layer += 2 * d * d + d * cfg.num_kv_heads * hd * 2  # cross-attn
    return embed + cfg.num_layers * per_layer + enc + d


def trunc_normal(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 1 else 1
    if len(shape) >= 2:
        fan_in = 1
        for s in shape[:-1]:
            fan_in *= s
    std = scale / (fan_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)
