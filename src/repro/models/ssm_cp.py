"""Context-parallel selective scan: sequence sharded over a mesh axis.

The SSM recurrence h_t = a_t h_{t-1} + bx_t is linear in the carried
state, so a sequence split across S shards needs only a tiny cross-shard
exchange (DESIGN.md §5, EXPERIMENTS §Perf hymba-prefill):

  pass 1  (local)   : h_last^s = scan(x^s, h0=0),  A^s = prod_t a_t^s
  exchange (tiny)   : all_gather of (h_last^s, A^s) — [S, B, d, n] each
  prefix  (local)   : h_in^s = sum_{r<s} (prod_{r<q<s} A^q) h_last^r
  pass 2  (local)   : y^s = scan(x^s, h0=h_in^s)

Cost: 2x local scan compute + one all_gather of O(B·d·n) — versus
replicating the whole sequence on every device.  The depthwise conv
preceding the scan gets its (width-1)-token halo from the left neighbour
via one ppermute.

This is itself MaRe-shaped: the exchange is a tiny reduce over the
sequence axis — partition-local work plus one explicit, bounded shuffle.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.ssm import ssm_scan

Params = Dict[str, Any]


def _local_decay_product(p: Params, xc: jnp.ndarray, cfg: ModelConfig
                         ) -> jnp.ndarray:
    """prod_t a_t over the local sequence: [B, d_i, n] (f32)."""
    from repro.models.ssm import _ssm_coeffs
    b, s, di = xc.shape
    chunk = min(cfg.ssm_chunk, s)
    pad = (-s) % chunk
    xp = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    nc = xp.shape[1] // chunk
    xp = xp.reshape(b, nc, chunk, di).transpose(1, 2, 0, 3)
    valid = (jnp.arange(nc * chunk) < s).reshape(nc, chunk)

    def step(acc, inp):
        xch, vch = inp
        a, _, _ = _ssm_coeffs(p, xch)
        a = jnp.where(vch[:, None, None, None], a, 1.0)
        return acc * jnp.prod(a, axis=0), None

    n = cfg.ssm_state
    acc0 = jnp.ones((b, di, n), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (xp, valid))
    return acc


def ssm_block_context_parallel(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, mesh: Mesh,
    seq_axis: str = "model",
    batch_axes: Optional[Tuple[str, ...]] = ("data",),
) -> jnp.ndarray:
    """Mamba-style block with the sequence sharded over ``seq_axis``.

    x: [B, S, d] with S sharded over ``seq_axis`` (and B over
    ``batch_axes``).  Returns y with the same sharding.  Train/prefill
    only (stateless interface; the returned final state is discarded).
    """
    n_seq = int(mesh.shape[seq_axis])
    b_dim = x.shape[0]
    b_axes = tuple(a for a in (batch_axes or ())
                   if b_dim % int(mesh.shape[a]) == 0) or None
    spec = P(b_axes, seq_axis, None)
    cw = cfg.ssm_conv

    def inner(xl):
        bl, sl, d = xl.shape
        di = d * max(cfg.ssm_expand, 1)
        xz = xl @ p["in_proj"]
        xi, z = jnp.split(xz, 2, axis=-1)
        # conv halo: last (cw-1) tokens from the left neighbour
        idx = jax.lax.axis_index(seq_axis)
        halo = jax.lax.ppermute(
            xi[:, -(cw - 1):], seq_axis,
            [(s, s + 1) for s in range(n_seq - 1)]) if cw > 1 else \
            xi[:, :0]
        halo = jnp.where(jnp.reshape(idx > 0, (1, 1, 1)), halo, 0.0)
        xin = jnp.concatenate([halo.astype(xi.dtype), xi], axis=1)
        conv = sum(xin[:, i:i + sl] * p["conv_w"][i] for i in range(cw))
        xc = jax.nn.silu(conv)
        # pass 1: local final state + decay product
        _, h_last = ssm_scan(p, xc, cfg,
                             h0=jnp.zeros((bl, di, cfg.ssm_state),
                                          jnp.float32))
        a_prod = _local_decay_product(p, xc, cfg)
        # exchange: [n_seq, B, di, n] each (tiny)
        h_all = jax.lax.all_gather(h_last, seq_axis)
        a_all = jax.lax.all_gather(a_prod, seq_axis)
        # exclusive prefix for this shard (static loop over n_seq)
        h_in = jnp.zeros_like(h_last)
        for r in range(n_seq - 1):
            # contribution of shard r to shards s > r
            decay = jnp.ones_like(a_prod)
            contrib = h_all[r]
            for s in range(r + 1, n_seq):
                active = (idx == s)
                h_in = h_in + jnp.where(active, contrib * decay, 0.0)
                decay = decay * a_all[s]
        # pass 2: corrected scan
        y, _ = ssm_scan(p, xc, cfg, h0=h_in)
        y = y * jax.nn.silu(z)
        return y @ p["out_proj"]

    return compat.shard_map(inner, mesh=mesh, in_specs=spec, out_specs=spec,
                         check_vma=False)(x)
