"""Pure-jnp oracle for MoE dispatch slotting (repartitionBy pack step)."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def dispatch_ref(assignments: jnp.ndarray, num_groups: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """assignments: [n] int group ids in [0, num_groups).

    Returns (positions [n], counts [num_groups]) where positions[i] is the
    arrival rank of token i within its group (stable order) and counts[g]
    the group size — exactly the slot layout MaRe's repartitionBy packs
    into its [group, capacity] send buffer.
    """
    onehot = (assignments[:, None] ==
              jnp.arange(num_groups)[None, :]).astype(jnp.int32)
    within = jnp.cumsum(onehot, axis=0) - onehot       # ranks before i
    positions = jnp.sum(within * onehot, axis=1)
    counts = jnp.sum(onehot, axis=0)
    return positions, counts
