from repro.kernels.moe_dispatch.ops import *  # noqa
