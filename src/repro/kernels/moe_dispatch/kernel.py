"""MoE dispatch-slotting Pallas kernel — repartitionBy's pack hot-spot.

Computes, for each token, its slot position within its destination group
(expert / shard) plus per-group counts, in one streaming pass.  This is the
integer prelude to the all_to_all in both MoE expert dispatch and MaRe's
generic repartitionBy (DESIGN.md §3.2).

TPU mapping: gathers (`counts[assign_i]`) are rewritten as one-hot matmuls
so the whole kernel is VPU/MXU reductions over a [block, groups] one-hot
tile; running per-group counts persist in VMEM scratch across the
(arbitrary) block grid.  Working set: block x groups i32 — 256 x 512 = 512
KiB, well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params


def _dispatch_kernel(assign_ref, pos_ref, counts_out_ref, counts_ref, *,
                     num_groups: int, block: int, n: int, num_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    a = assign_ref[...]                                   # [block] int32
    idx = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = idx < n
    a = jnp.where(valid, a, num_groups)                   # padding sentinel
    gid = jax.lax.broadcasted_iota(jnp.int32, (block, num_groups), 1)
    onehot = (a[:, None] == gid).astype(jnp.int32)        # [block, G]
    within = jnp.cumsum(onehot, axis=0) - onehot
    base = jnp.sum(onehot * counts_ref[...][None, :], axis=1)
    pos_ref[...] = base + jnp.sum(within * onehot, axis=1)
    counts_ref[...] = counts_ref[...] + jnp.sum(onehot, axis=0)

    @pl.when(bi == num_blocks - 1)
    def _finalize():
        counts_out_ref[...] = counts_ref[...]


def moe_dispatch_kernel(assignments: jnp.ndarray, num_groups: int,
                        block: int = 256, interpret: bool = True):
    """assignments: [n] int32 -> (positions [n], counts [num_groups])."""
    n = assignments.shape[0]
    block = min(block, n)
    nb = cdiv(n, block)
    kernel = functools.partial(_dispatch_kernel, num_groups=num_groups,
                               block=block, n=n, num_blocks=nb)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda b: (b,))],
        out_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((num_groups,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((num_groups,), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((num_groups,), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(assignments.astype(jnp.int32))
