"""jit'd wrapper for MoE dispatch slotting."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.moe_dispatch.kernel import moe_dispatch_kernel
from repro.kernels.moe_dispatch.ref import dispatch_ref


@functools.partial(jax.jit, static_argnames=("num_groups", "block",
                                             "interpret"))
def moe_dispatch(assignments: jnp.ndarray, num_groups: int,
                 block: int = 256,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    interp = use_interpret() if interpret is None else interpret
    return tuple(moe_dispatch_kernel(assignments, num_groups, block=block,
                                     interpret=interp))


__all__ = ["moe_dispatch", "dispatch_ref"]
