from repro.kernels.topk_reduce.ops import *  # noqa
