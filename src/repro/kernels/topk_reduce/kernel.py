"""Streaming top-k Pallas kernel — the MaRe ``reduce`` combiner hot-spot.

The Virtual-Screening pipeline (paper Listing 2) reduces millions of scored
records to the best 30 via sdsorter.  On TPU, the combiner becomes a
single-pass streaming selection: score blocks are staged HBM->VMEM; a
running top-k buffer lives in VMEM scratch across the (arbitrary-order)
block grid dimension; each step merges the block into the buffer with k
iterative max-extractions (VPU-friendly: max/argmax reductions + select —
no data-dependent gathers, no sort network needed for k << block).

VMEM working set: block (f32) + k-buffers — block=1024, k<=64 is ~8 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params

NEG_INF = -1e30


def _topk_kernel(scores_ref, count_ref, out_val_ref, out_idx_ref,
                 best_v_ref, best_i_ref, *, k: int, block: int, n: int,
                 num_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        best_v_ref[...] = jnp.full_like(best_v_ref, NEG_INF)
        best_i_ref[...] = jnp.full_like(best_i_ref, -1)

    s = scores_ref[...].astype(jnp.float32)              # [block]
    idx = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = (idx < n) & (idx < count_ref[0])
    s = jnp.where(valid, s, NEG_INF)

    # merge candidates = running buffer ++ block
    cand_v = jnp.concatenate([best_v_ref[...], s])
    cand_i = jnp.concatenate([best_i_ref[...], idx])

    def select_one(j, carry):
        cv, ci, bv, bi_ = carry
        m = jnp.max(cv)
        am = jnp.argmax(cv)
        sel = jax.lax.broadcasted_iota(jnp.int32, cv.shape, 0) == am
        mi = jnp.sum(jnp.where(sel, ci, 0))
        bv = jnp.where(jax.lax.broadcasted_iota(jnp.int32, bv.shape, 0) == j,
                       m, bv)
        bi_ = jnp.where(jax.lax.broadcasted_iota(jnp.int32, bi_.shape, 0) == j,
                        mi, bi_)
        cv = jnp.where(sel, NEG_INF, cv)
        return cv, ci, bv, bi_

    _, _, new_v, new_i = jax.lax.fori_loop(
        0, k, select_one,
        (cand_v, cand_i, jnp.zeros((k,), jnp.float32),
         jnp.zeros((k,), jnp.int32)))
    best_v_ref[...] = new_v
    best_i_ref[...] = new_i

    @pl.when(bi == num_blocks - 1)
    def _finalize():
        out_val_ref[...] = best_v_ref[...]
        out_idx_ref[...] = best_i_ref[...]


def topk_reduce_kernel(scores: jnp.ndarray, k: int,
                       valid_count: jnp.ndarray,
                       block: int = 1024,
                       interpret: bool = True):
    """scores: [n] -> (values [k] desc, indices [k])."""
    n = scores.shape[0]
    block = min(block, max(8, n))
    nb = cdiv(n, block)
    kernel = functools.partial(_topk_kernel, k=k, block=block, n=n,
                               num_blocks=nb)
    count = jnp.asarray(valid_count, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda b: (0,)),
            pl.BlockSpec((k,), lambda b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scores, count)
