"""Pure-jnp oracle for streaming top-k selection."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_ref(scores: jnp.ndarray, k: int,
             valid_count: jnp.ndarray | int | None = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k over [n] scores -> (values [k] desc, indices [k]).

    Invalid entries (>= valid_count) are excluded (treated as -inf)."""
    n = scores.shape[0]
    if valid_count is not None:
        mask = jnp.arange(n) < valid_count
        scores = jnp.where(mask, scores, -jnp.inf)
    return jax.lax.top_k(scores.astype(jnp.float32), k)
