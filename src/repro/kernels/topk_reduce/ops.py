"""jit'd wrapper for the streaming top-k kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.topk_reduce.kernel import topk_reduce_kernel
from repro.kernels.topk_reduce.ref import topk_ref


@functools.partial(jax.jit,
                   static_argnames=("k", "block", "interpret"))
def topk_reduce(scores: jnp.ndarray, k: int,
                valid_count: Optional[jnp.ndarray] = None,
                block: int = 1024,
                interpret: Optional[bool] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming top-k over [n] scores -> (values [k], indices [k])."""
    n = scores.shape[0]
    vc = jnp.asarray(n if valid_count is None else valid_count, jnp.int32)
    interp = use_interpret() if interpret is None else interpret
    return tuple(topk_reduce_kernel(scores, k, vc, block=block,
                                    interpret=interp))


__all__ = ["topk_reduce", "topk_ref"]
