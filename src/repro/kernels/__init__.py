"""Pallas TPU kernels for perf-critical hot-spots (validated interpret=True).

flash_attention — online-softmax attention (prefill hot-spot)
topk_reduce     — streaming top-k (MaRe reduce combiner, VS pipeline)
rmsnorm         — fused norm (memory-bound layer fusion)
moe_dispatch    — repartitionBy pack step (MoE expert dispatch)
ssm_scan        — fused selective scan (SSM/hybrid recurrence hot-spot)
segment_reduce  — bounded-key-table scatter-accumulate (reduce_by_key)
"""
from repro.kernels.flash_attention.ops import attention_ref, flash_attention
from repro.kernels.moe_dispatch.ops import dispatch_ref, moe_dispatch
from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_ref
from repro.kernels.segment_reduce.ops import segment_reduce, segment_reduce_ref
from repro.kernels.ssm_scan.ops import ssm_scan_fused, ssm_scan_ref
from repro.kernels.topk_reduce.ops import topk_ref, topk_reduce

__all__ = ["flash_attention", "attention_ref", "topk_reduce", "topk_ref",
           "rmsnorm", "rmsnorm_ref", "moe_dispatch", "dispatch_ref",
           "ssm_scan_fused", "ssm_scan_ref", "segment_reduce",
           "segment_reduce_ref"]
