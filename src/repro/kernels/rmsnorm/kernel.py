"""Fused RMSNorm Pallas kernel (row-blocked, VPU).

Norm layers are memory-bound (AI ~ O(1)); fusing square/mean/rsqrt/scale
into one VMEM pass removes two HBM round-trips vs. the unfused graph.
Rows are tiled [block_rows, d]; the weight vector is broadcast into VMEM
once per block (index_map pins it to block 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import cdiv, tpu_compiler_params


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)          # [block_rows, d]
    w = w_ref[...].astype(jnp.float32)          # [d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w[None, :]).astype(o_ref.dtype)


def rmsnorm_kernel(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = True
                   ) -> jnp.ndarray:
    """x: [rows, d], weight: [d] -> [rows, d]."""
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    nb = cdiv(rows, block_rows)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps, d=d)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
            pl.BlockSpec((d,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, weight)
