from repro.kernels.rmsnorm.ops import *  # noqa
