"""jit'd wrapper for fused RMSNorm; arbitrary leading dims."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.rmsnorm.kernel import rmsnorm_kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    shape = x.shape
    d = shape[-1]
    interp = use_interpret() if interpret is None else interpret
    out = rmsnorm_kernel(x.reshape(-1, d), weight, eps=eps,
                         block_rows=block_rows, interpret=interp)
    return out.reshape(shape)


__all__ = ["rmsnorm", "rmsnorm_ref"]
