"""Pure-jnp oracle for flash attention (GQA + optional causal/window)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  sm_scale: Optional[float] = None,
                  window: Optional[int] = None) -> jnp.ndarray:
    """Reference attention.

    q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D]; Hq % Hkv == 0 (GQA groups).
    ``window``: optional sliding-window size (attend to keys in
    (qpos - window, qpos]); implies causal.
    Returns [B, Hq, Sq, D] in q's dtype; softmax in f32.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # right-aligned positions
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal or window is not None:
        mask = mask & (qpos >= kpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)
                      ).astype(q.dtype)
