"""Flash attention Pallas TPU kernel (online softmax, VMEM-tiled).

TPU adaptation (DESIGN.md §2): instead of the CUDA warp-level algorithm,
tiles are sized to the MXU (128x128) and staged HBM->VMEM via BlockSpecs;
the online-softmax state (m, l, acc) lives in VMEM scratch across the
innermost (arbitrary-order) K-block grid dimension.  GQA is expressed in
the K/V BlockSpec index maps (q-head b maps to kv-head b // group), so
grouped KV is never materialized.

Grid: (batch*q_heads, q_blocks, k_blocks); k innermost.
The VMEM working set per step is q(bq*d) + k(bk*d) + v(bk*d) + acc(bq*d)
f32 + scratch — with bq=bk=128, d<=256 this is < 1 MiB, far under VMEM;
larger bq amortizes the q load (see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params

NEG_INF = -1e30  # avoid NaNs from (-inf) - (-inf) in fully-masked rows


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_q: int, seq_k: int,
                  num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)      # [bq, d]
    k = k_ref[0].astype(jnp.float32)      # [bk, d]
    v = v_ref[0].astype(jnp.float32)      # [bk, d]
    # zero the seq-padding rows of v: p is 0 there, but 0 * garbage = NaN
    kvalid = (ki * block_k +
              jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], 1), 0)
              ) < seq_k
    v = jnp.where(kvalid, v, 0.0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # [bq, bk]

    # positional mask: causal / sliding window / tail padding
    qpos = (qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            + (seq_k - seq_q))            # right-aligned
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal or window is not None:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None] +
                    jax.lax.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,   # [BH, Sq, D]  (batch*q_heads flattened)
    k: jnp.ndarray,   # [BKV, Sk, D] (batch*kv_heads flattened)
    v: jnp.ndarray,
    *,
    group: int,                      # q heads per kv head
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh == bkv * group, (bh, bkv, group)
    scale = sm_scale if sm_scale is not None else 1.0 / (d ** 0.5)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = cdiv(sq, block_q)
    nk = cdiv(sk, block_k)
    grid = (bh, nq, nk)

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=sq, seq_k=sk,
        num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
