"""jit'd public wrapper for the flash-attention kernel.

Handles the [B, H, S, D] <-> [B*H, S, D] flattening, GQA group math,
interpret-mode policy, and the XLA fallback used by the 512-device dry-run
(Pallas does not lower on the CPU host platform; on real TPU the kernel
path is selected automatically).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D] -> [B, Hq, S, D]."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    interp = use_interpret() if interpret is None else interpret
    out = flash_attention_kernel(
        q.reshape(b * hq, sq, d),
        k.reshape(b * hkv, sk, d),
        v.reshape(b * hkv, sk, d),
        group=group, causal=causal, window=window, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=interp)
    return out.reshape(b, hq, sq, d)


__all__ = ["flash_attention", "attention_ref"]
