"""Tiled segment-sum Pallas kernel — the ``reduce_by_key`` combiner hot-spot.

Sort-free scatter-accumulate over a bounded key table, now **tiled on both
axes**.  The grid is ``(key_tiles, record_blocks)`` with the key axis
outermost: for key tile ``kt`` only a ``[key_block, d]`` slice of the
aggregate table is resident in VMEM scratch, and the (sequential) inner
record-block axis streams ``[block, d]`` record slices HBM->VMEM and
accumulates into that resident tile.  Each step expands the block's keys
into a *tile-local* one-hot ``[block, key_block]`` matrix and accumulates
``one_hot.T @ values`` — scatter re-expressed as an MXU matmul, the same
no-data-dependent-gather discipline as the top-k kernel (XLA's scatter
expander is the measured memory hog this avoids).

Two things the untiled predecessor got wrong are fixed here:

* **VMEM honesty.**  The old kernel kept the full ``[num_keys, d]`` table
  (plus a ``[block, num_keys]`` one-hot) resident, so VMEM scaled with the
  key space; a 4**10 key table at d=128 f32 is 512 MiB and simply does not
  fit.  Now residency is ``key_block * d`` + ``block * key_block``,
  chosen to fit the VMEM budget regardless of ``num_keys``.
* **Block-range early-out.**  A record block whose key range provably
  misses the resident tile skips the matmul entirely (``@pl.when`` on the
  block's masked key min/max).  For key-sorted input each record block
  overlaps ~1 tile, collapsing MXU work from ``records x num_keys`` to
  ``~records x key_block``; for unsorted input it degrades gracefully to
  the dense schedule.

Validity is masked like ``Partition.mask``: slots beyond the partition
count and keys outside ``[0, num_keys)`` contribute nothing; out-of-range
keys are tallied into an SMEM overflow counter (on the first key tile
only, so the count is exact) instead of corrupting rows.  Sum only —
max/min take the jnp reference path (see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params


def _segment_sum_tiled_kernel(keys_ref, vals_ref, mask_ref,
                              out_tab_ref, out_cnt_ref, out_ovf_ref,
                              tab_ref, cnt_ref, ovf_ref, *,
                              block: int, n: int, num_keys: int,
                              key_block: int, num_blocks: int,
                              num_key_tiles: int):
    kt = pl.program_id(0)          # key tile (outer; owns the output tile)
    bi = pl.program_id(1)          # record block (inner, sequential)
    tile_lo = kt * key_block

    @pl.when(bi == 0)
    def _init():
        tab_ref[...] = jnp.zeros_like(tab_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    @pl.when((kt == 0) & (bi == 0))
    def _init_ovf():
        ovf_ref[0] = jnp.int32(0)

    keys = keys_ref[...]                                  # [block] i32
    ridx = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = (ridx < n) & (mask_ref[...] != 0)
    in_range = (keys >= 0) & (keys < num_keys)
    ok = valid & in_range

    @pl.when(kt == 0)
    def _count_overflow():                     # once per record block
        ovf_ref[0] += jnp.sum(valid & ~in_range).astype(jnp.int32)

    # Block-range early-out: masked key min/max vs this tile's range.
    # Invalid slots are pushed out of every tile's range so an all-masked
    # block skips cleanly.
    kmin = jnp.min(jnp.where(ok, keys, num_keys))
    kmax = jnp.max(jnp.where(ok, keys, -1))
    overlaps = (kmin < tile_lo + key_block) & (kmax >= tile_lo)

    @pl.when(overlaps)
    def _accumulate():
        local = keys - tile_lo                            # tile-local key
        kid = jax.lax.broadcasted_iota(jnp.int32, (block, key_block), 1)
        one_hot = (local[:, None] == kid) & ok[:, None]   # [block, key_block]
        # zero masked-out rows: grid padding reads garbage (NaN poisons 0*x)
        vals = jnp.where(ok[:, None], vals_ref[...], 0)   # [block, d]
        tab_ref[...] += jax.lax.dot_general(
            one_hot.astype(vals.dtype), vals,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=tab_ref.dtype)         # [key_block, d]
        cnt_ref[...] += jnp.sum(one_hot.astype(jnp.int32), axis=0)

    @pl.when(bi == num_blocks - 1)
    def _finalize():
        out_tab_ref[...] = tab_ref[...]
        out_cnt_ref[...] = cnt_ref[...]

    @pl.when((kt == num_key_tiles - 1) & (bi == num_blocks - 1))
    def _finalize_ovf():
        out_ovf_ref[0] = ovf_ref[0]


def segment_sum_tiled(keys: jnp.ndarray, values: jnp.ndarray,
                      num_keys: int, valid: jnp.ndarray,
                      block: int = 512, key_block: int = 1024,
                      interpret: bool = True):
    """Tiled Pallas segment sum.

    ``keys`` [n] i32, ``values`` [n, d], ``valid`` [n] bool ->
    ``(table [num_keys, d], counts [num_keys] i32, overflow [1] i32)``.

    ``block`` is the record-block length streamed per grid step;
    ``key_block`` is the key-table tile resident in VMEM (clamped to
    ``num_keys``; neither needs to divide its axis — edge tiles are
    masked).  Defaults suit a v5e core; the autotuner in ``tune.py``
    picks per-shape winners.
    """
    n = keys.shape[0]
    d = values.shape[1]
    block = min(block, max(8, n))
    key_block = min(key_block, num_keys)
    nb = cdiv(n, block)
    nk = cdiv(num_keys, key_block)
    kernel = functools.partial(_segment_sum_tiled_kernel, block=block, n=n,
                               num_keys=num_keys, key_block=key_block,
                               num_blocks=nb, num_key_tiles=nk)
    mask = jnp.asarray(valid).astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(nk, nb),
        in_specs=[
            pl.BlockSpec((block,), lambda k, b: (b,)),
            pl.BlockSpec((block, d), lambda k, b: (b, 0)),
            pl.BlockSpec((block,), lambda k, b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((key_block, d), lambda k, b: (k, 0)),
            pl.BlockSpec((key_block,), lambda k, b: (k,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_keys, d), values.dtype),
            jax.ShapeDtypeStruct((num_keys,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((key_block, d), values.dtype),
            pltpu.VMEM((key_block,), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(keys.astype(jnp.int32), values, mask)


#: Back-compat alias — the untiled kernel is the tiled one with the whole
#: key table as a single tile.
def segment_sum_kernel(keys: jnp.ndarray, values: jnp.ndarray,
                       num_keys: int, valid: jnp.ndarray,
                       block: int = 512, interpret: bool = True):
    return segment_sum_tiled(keys, values, num_keys, valid, block=block,
                             key_block=num_keys, interpret=interpret)
