"""Segment-sum Pallas kernel — the ``reduce_by_key`` combiner hot-spot.

Sort-free scatter-accumulate over a bounded key table: record blocks are
staged HBM->VMEM; the running ``[num_keys, d]`` aggregate table lives in
VMEM scratch across the (sequential) block grid.  Each step expands the
block's keys into a one-hot ``[block, num_keys]`` matrix and accumulates
``one_hot.T @ values`` into the table — scatter re-expressed as an MXU
matmul, the same no-data-dependent-gather discipline as the top-k kernel
(XLA's scatter expander is the measured memory hog this avoids).  Validity
is masked like ``Partition.mask``: slots beyond the partition count and
keys outside ``[0, num_keys)`` contribute nothing, and out-of-range keys
are tallied into an SMEM overflow counter instead of corrupting rows.

VMEM working set: block keys/values + the table — block=512, num_keys=4096,
d=1 f32 is ~48 KiB.  Sum only (max/min fall back to the jnp reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params


def _segment_sum_kernel(keys_ref, vals_ref, mask_ref,
                        out_tab_ref, out_cnt_ref, out_ovf_ref,
                        tab_ref, cnt_ref, ovf_ref, *,
                        block: int, n: int, num_keys: int, num_blocks: int):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        tab_ref[...] = jnp.zeros_like(tab_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        ovf_ref[0] = jnp.int32(0)

    keys = keys_ref[...]                                  # [block] i32
    ridx = bi * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
    valid = (ridx < n) & (mask_ref[...] != 0)
    in_range = (keys >= 0) & (keys < num_keys)
    ok = valid & in_range
    ovf_ref[0] += jnp.sum(valid & ~in_range).astype(jnp.int32)

    kid = jax.lax.broadcasted_iota(jnp.int32, (block, num_keys), 1)
    one_hot = (keys[:, None] == kid) & ok[:, None]        # [block, num_keys]
    # zero masked-out rows: grid padding reads garbage (NaN poisons 0*x)
    vals = jnp.where(ok[:, None], vals_ref[...], 0)       # [block, d]
    tab_ref[...] += jax.lax.dot_general(
        one_hot.astype(vals.dtype), vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=tab_ref.dtype)             # [num_keys, d]
    cnt_ref[...] += jnp.sum(one_hot.astype(jnp.int32), axis=0)

    @pl.when(bi == num_blocks - 1)
    def _finalize():
        out_tab_ref[...] = tab_ref[...]
        out_cnt_ref[...] = cnt_ref[...]
        out_ovf_ref[0] = ovf_ref[0]


def segment_sum_kernel(keys: jnp.ndarray, values: jnp.ndarray,
                       num_keys: int, valid: jnp.ndarray,
                       block: int = 512, interpret: bool = True):
    """keys [n] i32, values [n, d], valid [n] bool -> (table [num_keys, d],
    counts [num_keys] i32, overflow [1] i32)."""
    n = keys.shape[0]
    d = values.shape[1]
    block = min(block, max(8, n))
    nb = cdiv(n, block)
    kernel = functools.partial(_segment_sum_kernel, block=block, n=n,
                               num_keys=num_keys, num_blocks=nb)
    mask = jnp.asarray(valid).astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((block, d), lambda b: (b, 0)),
            pl.BlockSpec((block,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((num_keys, d), lambda b: (0, 0)),
            pl.BlockSpec((num_keys,), lambda b: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_keys, d), values.dtype),
            jax.ShapeDtypeStruct((num_keys,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_keys, d), values.dtype),
            pltpu.VMEM((num_keys,), jnp.int32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(keys.astype(jnp.int32), values, mask)
