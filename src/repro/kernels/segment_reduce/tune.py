"""First-compile autotuner for segment reduce.

``segment_reduce(..., use_kernel=None)`` doesn't hardcode a strategy: the
first time a given problem shape is traced, :func:`pick_strategy` runs
every eligible implementation on synthetic data of that exact shape,
times a few warm repetitions each, and caches the winner per

    (backend, op, n, num_keys, leaf-signature)

where the leaf signature is the tuple of ``(trailing shape, dtype)`` per
value leaf.  Tuning happens *at trace time* — candidate impls are jit'd
and executed on concrete arrays while the caller's trace is suspended,
which jax supports because ``jax.jit`` on fresh concrete inputs opens an
independent trace.  The cost is a few milliseconds per distinct shape,
paid once per process and amortized by the plan cache (a cached compiled
program never re-traces, so it never re-tunes).

Candidate set (see docs/kernels.md for the measured numbers):

* ``scatter`` — :func:`segment_reduce_ref`, one ``.at[].add`` per leaf.
* ``fused``   — :func:`segment_reduce_fused`, dtype-grouped single scatter
  (the CPU winner: XLA CPU pays per scatter op, not per column).
* ``sorted``  — :func:`segment_reduce_sorted`, argsort + cumsum + diff
  (integer leaves only; exact by wraparound cancellation).
* ``tiled[b,kb]`` — the Pallas kernel of ``kernel.py`` over a small grid
  of ``(block, key_block)`` tilings, filtered by the VMEM budget.  Only
  offered on TPU: in interpret mode (CPU) each grid step costs ~30ms of
  pure Python, so it can never win — set ``REPRO_SEGMENT_TUNE_PALLAS=1``
  to force it into the candidate set anyway (tests do, to exercise the
  plumbing).

Environment knobs:

* ``REPRO_SEGMENT_AUTOTUNE=0`` — skip measurement; return the static
  heuristic (``tiled`` on TPU, ``fused`` elsewhere) without running
  candidates.  Useful when trace determinism matters more than the last
  2x.
* ``REPRO_SEGMENT_TUNE_PALLAS=1`` — include Pallas tilings off-TPU.

:func:`tune_report` exposes everything tried this process (chosen
strategy, per-candidate timings) — ``benchmarks/kmer.py`` embeds it in
``BENCH_kmer.json`` and ``benchmarks/summary.py`` renders the tiling
table from it.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import VMEM_BYTES, use_interpret

#: strategies a Strategy.name may take (``tiled`` carries block params too)
STRATEGIES = ("scatter", "fused", "sorted", "tiled")

#: (block, key_block) tilings the tuner tries for the Pallas kernel
TILINGS = ((256, 512), (512, 1024), (512, 4096), (1024, 2048))

_WARMUP = 1
_REPS = 3

# cache + report, process-wide.  Keyed by _cache_key(); values are
# (strategy_name, block, key_block).
_CACHE: Dict[Tuple, Tuple[str, int, int]] = {}
_REPORT: List[Dict[str, Any]] = []


def _leaf_signature(values: Any) -> Tuple:
    return tuple((tuple(leaf.shape[1:]), jnp.dtype(leaf.dtype).name)
                 for leaf in jax.tree.leaves(values))


def _cache_key(backend: str, op: str, n: int, num_keys: int,
               leaf_sig: Tuple) -> Tuple:
    return (backend, op, n, num_keys, leaf_sig)


def _all_int_leaves(leaf_sig: Tuple) -> bool:
    return all(np.issubdtype(np.dtype(name), np.integer)
               for _, name in leaf_sig)


def _vmem_fits(block: int, key_block: int, d: int, itemsize: int) -> bool:
    """Rough VMEM residency of one grid step of the tiled kernel."""
    table = key_block * max(d, 1) * itemsize        # resident tile (x2: out)
    counts = key_block * 4
    one_hot = block * key_block * itemsize          # intermediate
    records = block * max(d, 1) * itemsize
    return 2 * table + 2 * counts + one_hot + records <= VMEM_BYTES // 2


def _synthetic(n: int, num_keys: int, leaf_sig: Tuple):
    """Concrete sample problem matching the traced shapes.

    Keys are a fixed permutation-ish pattern (golden-ratio stride) so every
    strategy sees realistic scatter conflicts; no RNG, so tuning is
    deterministic per shape.
    """
    idx = np.arange(max(n, 1), dtype=np.uint64)
    keys = ((idx * np.uint64(2654435761)) % np.uint64(max(num_keys, 1)))
    keys = jnp.asarray(keys.astype(np.int32))
    leaves = [jnp.ones((n,) + shape, np.dtype(name))
              for shape, name in leaf_sig]
    valid = jnp.ones((n,), bool)
    return keys, leaves, valid


def _time_callable(fn, *args) -> float:
    for _ in range(_WARMUP):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(_REPS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / _REPS


def _candidates(backend: str, op: str, n: int, num_keys: int,
                leaf_sig: Tuple) -> List[Tuple[str, int, int]]:
    cands: List[Tuple[str, int, int]] = [("fused", 0, 0), ("scatter", 0, 0)]
    if _all_int_leaves(leaf_sig):
        cands.append(("sorted", 0, 0))
    want_pallas = (backend == "tpu"
                   or os.environ.get("REPRO_SEGMENT_TUNE_PALLAS") == "1")
    if want_pallas:
        for block, key_block in TILINGS:
            d = 1
            itemsize = 4
            for shape, name in leaf_sig:
                d = max(d, int(np.prod(shape)) if shape else 1)
                itemsize = max(itemsize, np.dtype(name).itemsize)
            if _vmem_fits(block, key_block, d, itemsize):
                cands.append(("tiled", min(block, max(8, n)),
                              min(key_block, num_keys)))
    # dedupe clamped tilings
    seen = set()
    uniq = []
    for c in cands:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def _default_strategy(backend: str) -> Tuple[str, int, int]:
    if backend == "tpu":
        return ("tiled", 512, 1024)
    return ("fused", 0, 0)


def pick_strategy(op: str, n: int, num_keys: int, values: Any,
                  backend: Optional[str] = None) -> Tuple[str, int, int]:
    """Return ``(strategy, block, key_block)`` for this problem shape.

    Measured once per (backend, op, shape signature) and cached for the
    process; safe to call from inside a trace (tuning runs its own jits on
    concrete synthetic arrays).  Non-sum monoids always resolve to
    ``scatter`` — the fused/sorted/tiled paths are sum-only.
    """
    if op != "sum":
        return ("scatter", 0, 0)
    backend = backend or jax.default_backend()
    leaf_sig = _leaf_signature(values)
    key = _cache_key(backend, op, n, num_keys, leaf_sig)
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    if os.environ.get("REPRO_SEGMENT_AUTOTUNE") == "0" or n == 0:
        choice = _default_strategy(backend) if backend == "tpu" \
            else ("fused", 0, 0)
        if choice[0] == "tiled":
            choice = ("tiled", min(choice[1], max(8, n)),
                      min(choice[2], num_keys))
        _CACHE[key] = choice
        return choice
    choice = _measure(key, op, n, num_keys, leaf_sig, backend)
    _CACHE[key] = choice
    return choice


def _measure(key: Tuple, op: str, n: int, num_keys: int, leaf_sig: Tuple,
             backend: str) -> Tuple[str, int, int]:
    from repro.kernels.segment_reduce import ops as _ops
    from repro.obs import TRACER

    keys, leaves, valid = _synthetic(n, num_keys, leaf_sig)
    values = tuple(leaves)
    rows: List[Dict[str, Any]] = []
    best: Optional[Tuple[float, Tuple[str, int, int]]] = None
    with TRACER.span("segment_reduce.autotune",
                     n=n, num_keys=num_keys, backend=backend):
        for strat, block, key_block in _candidates(backend, op, n, num_keys,
                                                   leaf_sig):
            def run(k, v, m, _s=strat, _b=block, _kb=key_block):
                return _ops.segment_reduce_impl(
                    k, v, num_keys, op=op, valid=m, strategy=_s,
                    block=_b, key_block=_kb,
                    interpret=use_interpret())
            try:
                dt = _time_callable(run, keys, values, valid)
            except Exception:        # a candidate failing must not poison tune
                continue
            label = (f"tiled[{block},{key_block}]" if strat == "tiled"
                     else strat)
            rows.append({"candidate": label, "ms": round(dt * 1e3, 4)})
            if best is None or dt < best[0]:
                best = (dt, (strat, block, key_block))
    choice = best[1] if best else ("scatter", 0, 0)
    _REPORT.append({
        "backend": backend, "op": op, "n": n, "num_keys": num_keys,
        "leaves": [list(map(str, sig)) for sig in leaf_sig],
        "chosen": (f"tiled[{choice[1]},{choice[2]}]"
                   if choice[0] == "tiled" else choice[0]),
        "block": choice[1], "key_block": choice[2],
        "candidates": rows,
    })
    return choice


def tune_report() -> List[Dict[str, Any]]:
    """Everything tuned this process: one entry per distinct shape with the
    chosen strategy and all candidate timings (JSON-serializable)."""
    return list(_REPORT)


def clear_cache() -> None:
    """Drop tuning decisions + report (tests use this for isolation)."""
    _CACHE.clear()
    _REPORT.clear()
