"""Strategy-dispatched segment reduce: tiled Pallas kernel, fused/sorted
jnp paths, and a scatter reference, tuned per shape.

``segment_reduce`` is the keyed-aggregation primitive behind
``MaRe.reduce_by_key``: both the map-side combiner (pre-shuffle) and the
post-shuffle merge fold records into a bounded ``[num_keys, ...]`` key
table.  Four strategies implement the same contract (see
:func:`segment_reduce_ref` for semantics, docs/kernels.md for the why):

=========  ========================================  ==================
strategy   implementation                            availability
=========  ========================================  ==================
scatter    per-leaf ``.at[].add/.max/.min``          all monoids/dtypes
fused      dtype-grouped single-scatter sum          sum only
sorted     argsort + cumsum + boundary diff          sum, int leaves
tiled      Pallas kernel, VMEM-tiled key table       sum only
=========  ========================================  ==================

Dispatch (``use_kernel`` tri-state, back-compat with the pre-tiling API):

* ``use_kernel=True``  — force the Pallas ``tiled`` kernel.
* ``use_kernel=False`` — force the plain ``scatter`` reference (the
  bench's fallback baseline).
* ``use_kernel=None``  (the default) — ``REPRO_SEGMENT_KERNEL=1/0`` still
  forces tiled/scatter; otherwise the autotuner in ``tune.py`` measures
  the candidates at first trace for this shape and the winner is cached
  per (backend, op, n, num_keys, leaf signature).  This is the flipped
  default gated by ``kernel_vs_fallback_warm >= 1.0`` in
  ``benchmarks/kmer.py``.

Degenerate shapes short-circuit to ``scatter`` regardless: an empty
shard (``n == 0``) would give the tiled kernel a zero-length grid (its
outputs would never be written), and an empty value pytree has no leaf
to carry the kernel's count table.  Non-``sum`` monoids are scatter-only.

Overflow contract (all strategies): valid records whose key falls
outside ``[0, num_keys)`` contribute to ``result.overflow`` and nothing
else — the planner turns a nonzero count into an action-time error
instead of silently corrupting table rows.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.segment_reduce.kernel import (segment_sum_kernel,
                                                segment_sum_tiled)
from repro.kernels.segment_reduce.ref import (MONOIDS, SegmentReduceResult,
                                              monoid_identity,
                                              segment_reduce_fused,
                                              segment_reduce_ref,
                                              segment_reduce_sorted)
from repro.kernels.segment_reduce.tune import pick_strategy

STRATEGIES = ("scatter", "fused", "sorted", "tiled")


def resolve_use_kernel(explicit: Optional[bool], op: str) -> bool:
    """Back-compat predicate: would the *Pallas kernel* run?  (The full
    dispatch is :func:`resolve_strategy`; this answers only the
    tiled-vs-not question the original tri-state API exposed.)"""
    if op != "sum":
        return False
    if explicit is not None:
        return explicit
    env = os.environ.get("REPRO_SEGMENT_KERNEL")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


def resolve_strategy(use_kernel: Optional[bool], op: str, n: int,
                     num_keys: int, values: Any,
                     strategy: Optional[str] = None):
    """Map the public knobs to ``(strategy, block, key_block)``.

    ``strategy`` (when given) wins outright; otherwise ``use_kernel``
    True/False force tiled/scatter, ``REPRO_SEGMENT_KERNEL`` forces next,
    and the remaining ``None`` case asks the autotuner.  Returned block
    sizes are 0 for non-tiled strategies (callers' explicit ``block`` /
    ``key_block`` still override).
    """
    leaves = jax.tree.leaves(values)
    if op != "sum" or not leaves or n == 0:
        return ("scatter", 0, 0)
    if strategy is not None:
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown segment-reduce strategy {strategy!r};"
                             f" expected one of {STRATEGIES}")
        return (strategy, 0, 0)
    if use_kernel is True:
        return ("tiled", 0, 0)
    if use_kernel is False:
        return ("scatter", 0, 0)
    env = os.environ.get("REPRO_SEGMENT_KERNEL")
    if env is not None:
        return (("scatter", 0, 0) if env in ("0", "false", "False")
                else ("tiled", 0, 0))
    return pick_strategy(op, n, num_keys, values)


@functools.partial(jax.jit, static_argnames=("num_keys", "op", "strategy",
                                             "block", "key_block",
                                             "interpret"))
def segment_reduce_impl(keys: jnp.ndarray, values: Any, num_keys: int,
                        op: str, valid: jnp.ndarray, strategy: str,
                        block: int, key_block: int,
                        interpret: bool) -> SegmentReduceResult:
    """jit'd single-strategy implementation (``strategy`` is static — the
    autotuner times each candidate through this exact entry point)."""
    if strategy == "fused":
        return segment_reduce_fused(keys, values, num_keys, valid=valid)
    if strategy == "sorted":
        return segment_reduce_sorted(keys, values, num_keys, valid=valid)
    if strategy == "tiled":
        leaves, treedef = jax.tree.flatten(values)
        tables = []
        counts = overflow = None
        for leaf in leaves:
            tail = leaf.shape[1:]
            flat = leaf.reshape(leaf.shape[0], -1) if leaf.ndim != 2 else leaf
            tab, cnt, ovf = segment_sum_tiled(keys, flat, num_keys, valid,
                                              block=block,
                                              key_block=key_block,
                                              interpret=interpret)
            tables.append(tab.reshape((num_keys,) + tail))
            if counts is None:
                counts, overflow = cnt, ovf[0]
        return SegmentReduceResult(
            values=jax.tree.unflatten(treedef, tables),
            counts=counts, overflow=overflow)
    return segment_reduce_ref(keys, values, num_keys, op=op, valid=valid)


def segment_reduce(keys: jnp.ndarray, values: Any, num_keys: int,
                   op: str = "sum",
                   valid: Optional[jnp.ndarray] = None,
                   use_kernel: Optional[bool] = None,
                   strategy: Optional[str] = None,
                   block: int = 512,
                   key_block: Optional[int] = None,
                   interpret: Optional[bool] = None) -> SegmentReduceResult:
    """Aggregate ``values`` ([n, ...] pytree) per key into a
    ``[num_keys, ...]`` table.

    Args:
      keys: int ``[n]`` key per record; out-of-range keys count into
        ``result.overflow`` and touch no table row.
      values: pytree of ``[n, ...]`` arrays (may be empty — counts only).
      num_keys: static key-space bound; the table has exactly this many
        rows, absent keys hold the monoid identity (``counts > 0`` marks
        presence).
      op: monoid, one of ``("sum", "max", "min")``.
      valid: bool ``[n]`` record mask (``Partition.mask()``); ``None``
        means all valid.
      use_kernel: tri-state dispatch — True forces the Pallas tiled
        kernel, False forces the scatter reference, None (default)
        autotunes (see module docstring for the env overrides).
      strategy: explicit strategy name overriding ``use_kernel``
        entirely; one of ``STRATEGIES``.
      block: record-block length for the tiled kernel grid.
      key_block: key-table tile height for the tiled kernel; ``None``
        keeps the whole table resident (clamped to VMEM-safe sizes by
        the autotuner when it picks the tiling itself).
      interpret: force/forbid Pallas interpret mode; ``None`` follows
        :func:`use_interpret` (interpret everywhere but real TPU).

    Returns a :class:`SegmentReduceResult` ``(values, counts, overflow)``;
    all strategies are exact (bit-identical for int dtypes) — see
    ``tests/test_kernels_segment.py``.
    """
    n = keys.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    strat, tuned_block, tuned_kb = resolve_strategy(
        use_kernel, op, n, num_keys, values, strategy=strategy)
    if strat == "tiled":
        if tuned_block:
            block = tuned_block
        kb = key_block if key_block is not None else (tuned_kb or num_keys)
    else:
        kb = 0
        block = 0
    interp = use_interpret() if interpret is None else interpret
    return segment_reduce_impl(keys, values, num_keys, op=op, valid=valid,
                               strategy=strat, block=block, key_block=kb,
                               interpret=interp)


__all__ = ["segment_reduce", "segment_reduce_impl", "segment_reduce_ref",
           "segment_reduce_fused", "segment_reduce_sorted",
           "resolve_use_kernel", "resolve_strategy", "STRATEGIES",
           "SegmentReduceResult", "MONOIDS", "monoid_identity",
           "segment_sum_kernel", "segment_sum_tiled"]
