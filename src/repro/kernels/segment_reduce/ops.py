"""jit'd wrapper for segment reduce: Pallas kernel with a lax fallback.

``segment_reduce`` is the keyed-aggregation primitive behind
``MaRe.reduce_by_key``: both the map-side combiner (pre-shuffle) and the
post-shuffle merge scatter records into a bounded ``[num_keys, ...]`` key
table.  Dispatch policy: the Pallas kernel covers the ``sum`` monoid (the
hot path — k-mer counting, word-count-style aggregations) and is on by
default on TPU; max/min and non-TPU backends take the jnp reference path.
``REPRO_SEGMENT_KERNEL=1/0`` overrides, and ``use_kernel=`` overrides both.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.segment_reduce.kernel import segment_sum_kernel
from repro.kernels.segment_reduce.ref import (MONOIDS, SegmentReduceResult,
                                              monoid_identity,
                                              segment_reduce_ref)


def resolve_use_kernel(explicit: Optional[bool], op: str) -> bool:
    """The dispatch policy (kernel supports sum only)."""
    if op != "sum":
        return False
    if explicit is not None:
        return explicit
    env = os.environ.get("REPRO_SEGMENT_KERNEL")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_keys", "op", "use_kernel",
                                             "block", "interpret"))
def segment_reduce(keys: jnp.ndarray, values: Any, num_keys: int,
                   op: str = "sum",
                   valid: Optional[jnp.ndarray] = None,
                   use_kernel: Optional[bool] = None,
                   block: int = 512,
                   interpret: Optional[bool] = None) -> SegmentReduceResult:
    """Aggregate ``values`` ([n, ...] pytree) per key into a
    ``[num_keys, ...]`` table; see :func:`segment_reduce_ref` for semantics.
    """
    if valid is None:
        valid = jnp.ones((keys.shape[0],), bool)
    leaves, treedef = jax.tree.flatten(values)
    if not resolve_use_kernel(use_kernel, op) or not leaves:
        return segment_reduce_ref(keys, values, num_keys, op=op, valid=valid)
    interp = use_interpret() if interpret is None else interpret
    tables = []
    counts = overflow = None
    for leaf in leaves:
        tail = leaf.shape[1:]
        flat = leaf.reshape(leaf.shape[0], -1) if leaf.ndim != 2 else leaf
        tab, cnt, ovf = segment_sum_kernel(keys, flat, num_keys, valid,
                                           block=block, interpret=interp)
        tables.append(tab.reshape((num_keys,) + tail))
        if counts is None:
            counts, overflow = cnt, ovf[0]
    return SegmentReduceResult(values=jax.tree.unflatten(treedef, tables),
                               counts=counts, overflow=overflow)


__all__ = ["segment_reduce", "segment_reduce_ref", "resolve_use_kernel",
           "SegmentReduceResult", "MONOIDS", "monoid_identity"]
