from repro.kernels.segment_reduce.ops import (MONOIDS, SegmentReduceResult,
                                              monoid_identity,
                                              resolve_use_kernel,
                                              segment_reduce,
                                              segment_reduce_ref)

__all__ = ["segment_reduce", "segment_reduce_ref", "resolve_use_kernel",
           "SegmentReduceResult", "MONOIDS", "monoid_identity"]
