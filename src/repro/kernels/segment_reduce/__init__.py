from repro.kernels.segment_reduce.ops import (MONOIDS, STRATEGIES,
                                              SegmentReduceResult,
                                              monoid_identity,
                                              resolve_strategy,
                                              resolve_use_kernel,
                                              segment_reduce,
                                              segment_reduce_fused,
                                              segment_reduce_ref,
                                              segment_reduce_sorted,
                                              segment_sum_tiled)
from repro.kernels.segment_reduce.tune import (clear_cache, pick_strategy,
                                               tune_report)

__all__ = ["segment_reduce", "segment_reduce_ref", "segment_reduce_fused",
           "segment_reduce_sorted", "segment_sum_tiled", "resolve_use_kernel",
           "resolve_strategy", "STRATEGIES", "SegmentReduceResult", "MONOIDS",
           "monoid_identity", "pick_strategy", "tune_report", "clear_cache"]
