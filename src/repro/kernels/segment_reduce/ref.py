"""Pure-jnp segment-reduce strategies (oracle + tuned non-kernel paths).

All strategies aggregate records into a bounded, direct-indexed key table:
record ``i`` with key ``k`` contributes ``values[i]`` to table row ``k``
under a monoid (sum / max / min).  Records whose key falls outside
``[0, num_keys)`` are *counted* into an overflow scalar and excluded from
the table — the caller surfaces the counter through the planner's
one-sync-per-action error channel instead of silently corrupting rows.

Three implementations live here; ``segment_reduce_ref`` is the oracle the
others (and the Pallas kernel) are validated against:

* :func:`segment_reduce_ref` — one scatter-add (``.at[].add``) per value
  leaf plus one for the counts.  Handles every monoid and dtype.
* :func:`segment_reduce_fused` — sum only: value leaves are grouped by
  dtype, each group concatenated column-wise and folded in ONE scatter
  (the counts column rides along with the int32 group).  Halves scatter
  traffic for the common ``(int32 values, int32 counts)`` shape of
  ``reduce_by_key`` — the measured CPU winner (docs/kernels.md).
* :func:`segment_reduce_sorted` — sum over integer leaves only: sort by
  key, cumulative-sum, and difference at the (searchsorted) segment
  boundaries — no scatter at all.  Exact for integers (wraparound
  cancels in the difference); *not* offered for floats, where reordered
  cumulative sums change the rounding.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

MONOIDS = ("sum", "max", "min")


class SegmentReduceResult(NamedTuple):
    values: Any             # pytree of [num_keys, ...] aggregate tables
    counts: jnp.ndarray     # [num_keys] int32, records folded into each key
    overflow: jnp.ndarray   # int32 scalar, valid records with out-of-range keys


def monoid_identity(op: str, dtype) -> jnp.ndarray:
    if op == "sum":
        return jnp.zeros((), dtype)
    if op == "max":
        return (jnp.asarray(-jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.asarray(jnp.iinfo(dtype).min, dtype))
    if op == "min":
        return (jnp.asarray(jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.asarray(jnp.iinfo(dtype).max, dtype))
    raise ValueError(f"unknown segment-reduce op {op!r}; expected {MONOIDS}")


def _ok_idx_overflow(keys: jnp.ndarray, num_keys: int,
                     valid: Optional[jnp.ndarray]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared masking: validity x range check, sentinel index, overflow."""
    n = keys.shape[0]
    keys = keys.astype(jnp.int32)
    if valid is None:
        valid = jnp.ones((n,), bool)
    in_range = (keys >= 0) & (keys < num_keys)
    ok = valid & in_range
    overflow = jnp.sum(valid & ~in_range).astype(jnp.int32)
    # out-of-range / invalid records scatter to a sentinel row, sliced off
    idx = jnp.where(ok, keys, num_keys)
    return ok, idx, overflow


def segment_reduce_ref(keys: jnp.ndarray, values: Any, num_keys: int,
                       op: str = "sum",
                       valid: Optional[jnp.ndarray] = None
                       ) -> SegmentReduceResult:
    """Scatter-accumulate ``values`` into a ``[num_keys, ...]`` table.

    ``keys``: int [n]; ``values``: pytree of ``[n, ...]`` arrays; ``valid``:
    bool [n] (entries beyond a partition's count).  Rows of absent keys hold
    the monoid identity; use ``counts > 0`` to find present keys.
    """
    if op not in MONOIDS:
        raise ValueError(f"unknown segment-reduce op {op!r}; "
                         f"expected {MONOIDS}")
    ok, idx, overflow = _ok_idx_overflow(keys, num_keys, valid)
    counts = jnp.zeros((num_keys + 1,), jnp.int32).at[idx].add(1)[:num_keys]

    def reduce_leaf(leaf):
        ident = monoid_identity(op, leaf.dtype)
        okb = ok.reshape((-1,) + (1,) * (leaf.ndim - 1))
        contrib = jnp.where(okb, leaf, ident)
        tab = jnp.full((num_keys + 1,) + leaf.shape[1:], ident, leaf.dtype)
        if op == "sum":
            tab = tab.at[idx].add(contrib)
        elif op == "max":
            tab = tab.at[idx].max(contrib)
        else:
            tab = tab.at[idx].min(contrib)
        return tab[:num_keys]

    return SegmentReduceResult(values=jax.tree.map(reduce_leaf, values),
                               counts=counts, overflow=overflow)


def segment_reduce_fused(keys: jnp.ndarray, values: Any, num_keys: int,
                         valid: Optional[jnp.ndarray] = None
                         ) -> SegmentReduceResult:
    """Sum-monoid segment reduce with dtype-grouped fused scatters.

    Value leaves sharing a dtype are flattened to ``[n, d_i]`` columns and
    concatenated into one ``[n, D]`` matrix folded by a single
    ``.at[].add`` — XLA CPU/GPU pays per *scatter op*, not per column, so
    this halves (or better) the scatter count vs :func:`segment_reduce_ref`.
    The int32 counts column is appended to the int32 group when one
    exists (zero extra scatters for the ``reduce_by_key`` hot path) and
    scattered separately otherwise.  Results are bit-identical to the
    reference: same adds in the same row order, no dtype changes.
    """
    ok, idx, overflow = _ok_idx_overflow(keys, num_keys, valid)
    leaves, treedef = jax.tree.flatten(values)
    n = keys.shape[0]

    groups: dict = {}                    # dtype -> list of (leaf_pos, [n,d])
    for pos, leaf in enumerate(leaves):
        flat = leaf.reshape(n, -1)
        groups.setdefault(jnp.dtype(leaf.dtype), []).append((pos, flat))

    count_col = ok.astype(jnp.int32)[:, None]
    int32 = jnp.dtype(jnp.int32)
    if int32 not in groups:
        groups[int32] = []
    out_leaves: list = [None] * len(leaves)
    counts = None
    for dtype, members in groups.items():
        cols = [jnp.where(ok[:, None], flat, 0) for _, flat in members]
        carries_counts = dtype == int32
        if carries_counts:
            cols = cols + [count_col]
        aug = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
        tab = jnp.zeros((num_keys + 1, aug.shape[1]), dtype)
        tab = tab.at[idx].add(aug)[:num_keys]
        off = 0
        for pos, flat in members:
            d = flat.shape[1]
            out_leaves[pos] = tab[:, off:off + d].reshape(
                (num_keys,) + leaves[pos].shape[1:])
            off += d
        if carries_counts:
            counts = tab[:, -1]
    return SegmentReduceResult(values=jax.tree.unflatten(treedef, out_leaves),
                               counts=counts, overflow=overflow)


def segment_reduce_sorted(keys: jnp.ndarray, values: Any, num_keys: int,
                          valid: Optional[jnp.ndarray] = None
                          ) -> SegmentReduceResult:
    """Sort-based sum-monoid segment reduce (integer leaves only).

    ``argsort`` the (sentinel-masked) keys once, cumulative-sum every value
    column over the sorted order, then read segment totals as differences
    at the ``searchsorted`` key boundaries.  O(n log n) with zero scatter
    ops; integer wraparound cancels in the difference so results match the
    scatter paths bit-for-bit.  Callers must not pass floating leaves —
    the reordered accumulation would change rounding.
    """
    ok, idx, overflow = _ok_idx_overflow(keys, num_keys, valid)
    leaves, treedef = jax.tree.flatten(values)
    n = keys.shape[0]
    order = jnp.argsort(idx)
    sorted_keys = idx[order]
    bounds = jnp.searchsorted(sorted_keys, jnp.arange(num_keys + 1))
    counts = (bounds[1:] - bounds[:-1]).astype(jnp.int32)

    def reduce_leaf(leaf):
        flat = jnp.where(ok[:, None], leaf.reshape(n, -1), 0)[order]
        csum = jnp.concatenate(
            [jnp.zeros((1, flat.shape[1]), leaf.dtype),
             jnp.cumsum(flat, axis=0, dtype=leaf.dtype)], axis=0)
        return (csum[bounds[1:]] - csum[bounds[:-1]]).reshape(
            (num_keys,) + leaf.shape[1:])

    return SegmentReduceResult(
        values=jax.tree.unflatten(treedef,
                                  [reduce_leaf(l) for l in leaves]),
        counts=counts, overflow=overflow)
