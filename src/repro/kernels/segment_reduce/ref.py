"""Pure-jnp segment-reduce oracle (and the non-kernel fallback path).

Aggregates records into a bounded, direct-indexed key table: record ``i``
with key ``k`` contributes ``values[i]`` to table row ``k`` under a monoid
(sum / max / min).  Records whose key falls outside ``[0, num_keys)`` are
*counted* into an overflow scalar and excluded from the table — the caller
surfaces the counter through the planner's one-sync-per-action error
channel instead of silently corrupting rows.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

MONOIDS = ("sum", "max", "min")


class SegmentReduceResult(NamedTuple):
    values: Any             # pytree of [num_keys, ...] aggregate tables
    counts: jnp.ndarray     # [num_keys] int32, records folded into each key
    overflow: jnp.ndarray   # int32 scalar, valid records with out-of-range keys


def monoid_identity(op: str, dtype) -> jnp.ndarray:
    if op == "sum":
        return jnp.zeros((), dtype)
    if op == "max":
        return (jnp.asarray(-jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.asarray(jnp.iinfo(dtype).min, dtype))
    if op == "min":
        return (jnp.asarray(jnp.inf, dtype)
                if jnp.issubdtype(dtype, jnp.floating)
                else jnp.asarray(jnp.iinfo(dtype).max, dtype))
    raise ValueError(f"unknown segment-reduce op {op!r}; expected {MONOIDS}")


def segment_reduce_ref(keys: jnp.ndarray, values: Any, num_keys: int,
                       op: str = "sum",
                       valid: Optional[jnp.ndarray] = None
                       ) -> SegmentReduceResult:
    """Scatter-accumulate ``values`` into a ``[num_keys, ...]`` table.

    ``keys``: int [n]; ``values``: pytree of ``[n, ...]`` arrays; ``valid``:
    bool [n] (entries beyond a partition's count).  Rows of absent keys hold
    the monoid identity; use ``counts > 0`` to find present keys.
    """
    if op not in MONOIDS:
        raise ValueError(f"unknown segment-reduce op {op!r}; "
                         f"expected {MONOIDS}")
    n = keys.shape[0]
    keys = keys.astype(jnp.int32)
    if valid is None:
        valid = jnp.ones((n,), bool)
    in_range = (keys >= 0) & (keys < num_keys)
    ok = valid & in_range
    overflow = jnp.sum(valid & ~in_range).astype(jnp.int32)
    # out-of-range / invalid records scatter to a sentinel row, sliced off
    idx = jnp.where(ok, keys, num_keys)
    counts = jnp.zeros((num_keys + 1,), jnp.int32).at[idx].add(1)[:num_keys]

    def reduce_leaf(leaf):
        ident = monoid_identity(op, leaf.dtype)
        okb = ok.reshape((-1,) + (1,) * (leaf.ndim - 1))
        contrib = jnp.where(okb, leaf, ident)
        tab = jnp.full((num_keys + 1,) + leaf.shape[1:], ident, leaf.dtype)
        if op == "sum":
            tab = tab.at[idx].add(contrib)
        elif op == "max":
            tab = tab.at[idx].max(contrib)
        else:
            tab = tab.at[idx].min(contrib)
        return tab[:num_keys]

    return SegmentReduceResult(values=jax.tree.map(reduce_leaf, values),
                               counts=counts, overflow=overflow)
