"""Shared kernel utilities: interpret-mode policy and block helpers.

This container is CPU-only; TPU v5e is the compile target.  Kernels are
written with explicit BlockSpec VMEM tiling for the MXU/VPU and validated
under ``interpret=True`` (Python execution of the kernel body) against the
pure-jnp oracles in each kernel's ``ref.py``.
"""
from __future__ import annotations

import os

import jax

# v5e hardware model used for block-size reasoning (see DESIGN.md).
VMEM_BYTES = 128 * 1024 * 1024        # ~128 MiB VMEM per core (v5e: 128MB)
MXU_DIM = 128                          # systolic array tile
VPU_LANES = 128
SUBLANE = 8


def use_interpret() -> bool:
    """Pallas interpret mode: on unless running on a real TPU backend or
    explicitly overridden via REPRO_PALLAS_INTERPRET=0/1."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Version-tolerant Pallas TPU compiler-params constructor.

    ``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` across
    JAX releases; resolve whichever this installation provides.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover - ancient/renamed-again JAX
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams")
    return cls(**kwargs)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_block(n: int, preferred: int, align: int = MXU_DIM) -> int:
    """Largest MXU-aligned block <= preferred that does not over-pad n."""
    if n <= align:
        return round_up(max(n, 1), SUBLANE)
    b = min(preferred, round_up(n, align))
    while b > align and round_up(n, b) - n >= b // 2:
        b //= 2
    return max(align, b)


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
