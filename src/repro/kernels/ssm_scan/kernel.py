"""Fused selective-scan Pallas kernel (the SSM/hybrid hot-spot).

The XLA fallback (models/ssm.py) is memory-bound: `associative_scan`
materializes O(log T) full [T, d, n] levels in HBM (~55% of hymba's
train traffic — EXPERIMENTS.md §Perf hymba-stop).  This kernel computes
the selective-SSM coefficients AND the recurrence inside VMEM: the only
HBM traffic is x in ([chunk, d]) and y out ([chunk, d]) — O(T·d) instead
of O(T·d·n·log T).

Grid: (batch, num_chunks); the chunk axis is sequential ("arbitrary")
with the [d, n] recurrent state carried in VMEM scratch.  Within a chunk
the recurrence runs as a fori_loop of VPU ops on the [d, n] tile
(d=1600, n=16 → 100 KiB f32 state; coefficient tiles a/bx are
[chunk, d, n] ≈ 26 MiB at chunk=256 — comfortably inside VMEM).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, tpu_compiler_params


def _ssm_kernel(xc_ref, xproj_ref, dtb_ref, alog_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, chunk: int, seq: int,
                num_chunks: int, n: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    xc = xc_ref[0].astype(jnp.float32)               # [chunk, d]
    xproj = xproj_ref[...].astype(jnp.float32)       # [d, 2n+1]
    proj = jnp.dot(xc, xproj,
                   preferred_element_type=jnp.float32)   # [chunk, 2n+1]
    bb = proj[:, :n]                                 # [chunk, n]
    cc = proj[:, n:2 * n]
    dt = jax.nn.softplus(proj[:, 2 * n][:, None] + dtb_ref[...][None, :])
    a = jnp.exp(-jnp.exp(alog_ref[...])[None] * dt[..., None])
    bx = (dt * xc)[..., None] * bb[:, None, :]       # [chunk, d, n]
    # mask padded tail: identity update
    tpos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = (tpos < seq)[:, None, None]
    a = jnp.where(valid, a, 1.0)
    bx = jnp.where(valid, bx, 0.0)

    def body(t, carry):
        h, ys = carry
        h = a[t] * h + bx[t]                         # [d, n]
        y_t = jnp.sum(h * cc[t][None, :], axis=-1)   # [d]
        sel = (jax.lax.broadcasted_iota(jnp.int32, ys.shape, 0) == t)
        ys = jnp.where(sel, y_t[None, :], ys)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros((chunk, xc.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, body, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _done():
        hout_ref[0] = h_ref[...]


def ssm_scan_kernel(xc: jnp.ndarray, x_proj: jnp.ndarray,
                    dt_bias: jnp.ndarray, a_log: jnp.ndarray,
                    h0: jnp.ndarray, chunk: int = 128,
                    interpret: bool = True
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xc: [B, T, d] -> (y [B, T, d] f32, h_final [B, d, n] f32)."""
    b, t, d = xc.shape
    n = a_log.shape[1]
    chunk = min(chunk, t)
    nc = cdiv(t, chunk)
    kernel = functools.partial(_ssm_kernel, chunk=chunk, seq=t,
                               num_chunks=nc, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((d, 2 * n + 1), lambda bi, ci: (0, 0)),
            pl.BlockSpec((d,), lambda bi, ci: (0,)),
            pl.BlockSpec((d, n), lambda bi, ci: (0, 0)),
            pl.BlockSpec((1, d, n), lambda bi, ci: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, d, n), lambda bi, ci: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc * chunk, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xc, x_proj, dt_bias, a_log, h0)
