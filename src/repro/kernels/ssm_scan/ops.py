"""jit'd wrapper for the fused selective-scan kernel."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ref import ssm_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_fused(xc: jnp.ndarray, x_proj: jnp.ndarray,
                   dt_bias: jnp.ndarray, a_log: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None, chunk: int = 128,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, t, d = xc.shape
    n = a_log.shape[1]
    if h0 is None:
        h0 = jnp.zeros((b, d, n), jnp.float32)
    interp = use_interpret() if interpret is None else interpret
    y, h = ssm_scan_kernel(xc, x_proj, dt_bias, a_log, h0, chunk=chunk,
                           interpret=interp)
    return y[:, :t], h


__all__ = ["ssm_scan_fused", "ssm_scan_ref"]
