"""Pure-jnp oracle for the fused selective-scan kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssm_scan_ref(xc: jnp.ndarray, x_proj: jnp.ndarray,
                 dt_bias: jnp.ndarray, a_log: jnp.ndarray,
                 h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential reference.

    xc: [B, T, d] post-conv activations; x_proj: [d, 2n+1];
    dt_bias: [d]; a_log: [d, n]; h0: [B, d, n].
    Returns (y [B, T, d] f32, h_final [B, d, n] f32)."""
    n = a_log.shape[1]
    proj = xc.astype(jnp.float32) @ x_proj.astype(jnp.float32)
    bb, cc, dtr = proj[..., :n], proj[..., n:2 * n], proj[..., 2 * n]
    dt = jax.nn.softplus(dtr[..., None] + dt_bias)          # [B, T, d]
    a = jnp.exp(-jnp.exp(a_log) * dt[..., None])            # [B, T, d, n]
    bx = (dt * xc.astype(jnp.float32))[..., None] * bb[..., None, :]

    def step(h, inp):
        a_t, bx_t, c_t = inp
        h = a_t * h + bx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (a.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
         cc.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h
