from repro.kernels.ssm_scan.ops import *  # noqa
