"""Global-norm gradient clipping."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2)
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm
