"""Gradient compression (int8 + error feedback) — beyond-paper DP trick.

Before the MaRe tree all-reduce ships gradients between shards, each leaf
is quantized to int8 with a per-tensor scale; the quantization residual is
carried in an error-feedback buffer and added to the next step's gradient
(Seide et al. 2014 / Karimireddy et al. 2019), so the compressed SGD still
converges.  Cuts the reduce-tree's collective bytes by ~4x for f32 / ~2x
for bf16 — see EXPERIMENTS.md §Perf for the collective-term arithmetic.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_feedback_compress(grads: Any, residual: Any
                            ) -> Tuple[Any, Any, Any]:
    """Apply EF int8 compression leaf-wise.

    Returns (quantized leaves (q, scale) tree, dequantized grads to reduce,
    new residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return (q, s), deq, gf - deq

    out = jax.tree.map(one, grads, residual)
    flat, treedef = jax.tree.flatten(
        out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
        and isinstance(x[0], tuple))
    qs = treedef.unflatten([o[0] for o in flat])
    deq = treedef.unflatten([o[1] for o in flat])
    res = treedef.unflatten([o[2] for o in flat])
    return qs, deq, res


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
