"""Adafactor (Shazeer & Stern, 2018) — factored second moments.

The memory-frugal optimizer for the 1T-parameter cells: second-moment state
is O(rows + cols) instead of O(rows * cols), and first moment is optional —
see EXPERIMENTS.md §Dry-run for the kimi-k2 memory budget this enables.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


class AdafactorState(NamedTuple):
    v_row: Any          # factored stats ([..., r] rows) or full v for 1-D
    v_col: Any
    m: Any              # momentum (empty tuple leaves if disabled)
    count: jnp.ndarray


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor(decay: float = 0.8, eps1: float = 1e-30, eps2: float = 1e-3,
              clip_threshold: float = 1.0, momentum: Optional[float] = None,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def vrow(p):
            if _factored(p.shape):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)     # full v

        def vcol(p):
            if _factored(p.shape):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)        # unused

        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if momentum else jax.tree.map(lambda p: jnp.zeros((1,),
                                                              jnp.float32),
                                          params)
        return AdafactorState(v_row=jax.tree.map(vrow, params),
                              v_col=jax.tree.map(vcol, params),
                              m=m, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        beta2 = 1.0 - c.astype(jnp.float32) ** (-decay)

        def upd(g, vr, vc, m, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps1
            if _factored(g.shape):
                vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr2 / jnp.maximum(
                    jnp.mean(vr2, axis=-1, keepdims=True), eps1)
                u = gf / (jnp.sqrt(r)[..., None] *
                          jnp.sqrt(vc2)[..., None, :] + eps1)
            else:
                vr2 = beta2 * vr + (1 - beta2) * g2
                vc2 = vc
                u = gf / (jnp.sqrt(vr2) + eps1)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if momentum:
                m2 = momentum * m + (1 - momentum) * u
                u = m2
            else:
                m2 = m
            u = u + weight_decay * p.astype(jnp.float32)
            return -lr * u, vr2, vc2, m2

        out = jax.tree.map(upd, grads, state.v_row, state.v_col, state.m,
                           params)
        flat, treedef = jax.tree.flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 4)
        pick = lambda i: treedef.unflatten([o[i] for o in flat])  # noqa
        return pick(0), AdafactorState(v_row=pick(1), v_col=pick(2),
                                       m=pick(3), count=c)

    return Optimizer(init=init, update=update)


def _rms(x):
    return jnp.sqrt(jnp.mean(x.astype(jnp.float32) ** 2))
