from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.base import Optimizer, apply_updates
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compression import (compress_int8, decompress_int8,
                                     error_feedback_compress)
from repro.optim.schedule import constant, cosine_warmup, linear_warmup

__all__ = ["Optimizer", "apply_updates", "adamw", "adafactor",
           "clip_by_global_norm", "global_norm", "cosine_warmup",
           "linear_warmup", "constant", "compress_int8", "decompress_int8",
           "error_feedback_compress"]
