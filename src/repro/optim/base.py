"""Minimal functional optimizer interface (optax-style, self-contained)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]                    # params -> state
    update: Callable[..., Any]                    # (grads, state, params,
    #                                                lr) -> (updates, state)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree.map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def cast_state(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)
