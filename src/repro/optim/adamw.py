"""AdamW with f32 moments (state shardings follow param shardings)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        cf = c.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            mhat = m2 / (1 - b1 ** cf)
            vhat = v2 / (1 - b2 ** cf)
            step = mhat / (jnp.sqrt(vhat) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return -lr * step, m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x:
                                         isinstance(x, tuple) and
                                         len(x) == 3 and
                                         not isinstance(x, list))
        ups = treedef.unflatten([o[0] for o in flat])
        ms = treedef.unflatten([o[1] for o in flat])
        vs = treedef.unflatten([o[2] for o in flat])
        return ups, AdamWState(m=ms, v=vs, count=c)

    return Optimizer(init=init, update=update)
