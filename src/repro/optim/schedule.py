"""LR schedules (step -> lr, pure jnp)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    return fn


def cosine_warmup(lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return lr * warm * cos
    return fn
