"""repro.serve — the multi-tenant interactive query service.

Layering: :mod:`repro.core` builds lazy plans, :mod:`repro.runtime`
executes actions against one pair of caches, and **this package puts a
service boundary above the runtime**: N concurrent sessions (tenants)
share one executor, one materialization cache and one compile cache,
with the policies a shared deployment needs — admission control and
deficit-round-robin fairness (:mod:`~repro.serve.scheduler`),
cross-session batching of identical queries (:mod:`~repro.serve.batching`),
per-tenant cache-budget partitions, and per-tenant report streams
(:mod:`~repro.serve.service`, :mod:`~repro.serve.session`).

Entry points: ``QueryService(config=ServiceConfig(...))`` then
``svc.session("alice").mare(data)...collect()``; or a standalone
``Session(tenant="alice")`` for the single-tenant case.  The serving
loop is ``python -m repro.launch.serve --service``; the load benchmark
is ``benchmarks/serve.py`` (docs/serving.md walks through both).
"""
from repro.serve.batching import BatchKey, Pending, batch_key
from repro.serve.scheduler import AdmissionError, DeficitRoundRobin
from repro.serve.service import QueryService, ServiceConfig
from repro.serve.session import Session

__all__ = [
    "AdmissionError", "BatchKey", "DeficitRoundRobin", "Pending",
    "QueryService", "ServiceConfig", "Session", "batch_key",
]
