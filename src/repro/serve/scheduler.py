"""Admission control + deficit-round-robin fair queueing across tenants.

The scheduler is the service's front door: :meth:`DeficitRoundRobin.offer`
either enqueues an action under its tenant or raises :class:`AdmissionError`
(per-tenant or total backlog limit hit — the caller sees the rejection
immediately instead of a silently growing queue), and
:meth:`DeficitRoundRobin.take` hands the pump thread the next action to
dispatch under deficit round robin [Shreedhar & Varghese '96]: each
non-empty tenant in rotation accrues ``quantum`` credit per visit and is
served while the credit covers the head action's cost (we cost an action
by its pending stage count, so a tenant burning 10-stage chains cannot
starve one issuing 1-stage lookups).  A tenant's credit resets when its
queue drains — idle tenants cannot bank credit.

The scheduler is deliberately free of service concerns: no metrics, no
batching, no executor — it queues opaque items with a ``cost`` and picks
fairly.  Batching support is the one extension: :meth:`extract` removes
every queued item matching a predicate (the service pulls same-key
actions out of ALL tenant queues to coalesce them into one dispatch).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional


class AdmissionError(RuntimeError):
    """Raised by :meth:`DeficitRoundRobin.offer` when a backlog limit is
    hit.  Carries ``tenant`` and ``scope`` (``"tenant"`` or ``"total"``)
    so callers/tests can distinguish which limit rejected."""

    def __init__(self, message: str, tenant: str, scope: str) -> None:
        super().__init__(message)
        self.tenant = tenant
        self.scope = scope


class DeficitRoundRobin:
    """Thread-safe per-tenant FIFO queues served in DRR order."""

    def __init__(self, quantum: float = 4.0,
                 max_queued_per_tenant: int = 8,
                 max_queued_total: int = 64,
                 weights: Optional[Dict[str, float]] = None,
                 default_weight: float = 1.0) -> None:
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        if default_weight <= 0:
            raise ValueError(
                f"default_weight must be > 0, got {default_weight}")
        for tenant, w in (weights or {}).items():
            if w <= 0:
                raise ValueError(
                    f"weight for tenant {tenant!r} must be > 0, got {w}")
        self.quantum = quantum
        self.max_queued_per_tenant = max_queued_per_tenant
        self.max_queued_total = max_queued_total
        self.default_weight = default_weight
        self._weights: Dict[str, float] = dict(weights or {})
        self._cond = threading.Condition()
        self._queues: Dict[str, Deque[Any]] = {}
        self._costs: Dict[str, Deque[float]] = {}
        self._deficits: Dict[str, float] = {}
        self._rotation: Deque[str] = deque()
        self._total = 0
        self._total_cost = 0.0

    # -- per-tenant weights (priority tiers) ----------------------------------

    def weight(self, tenant: str) -> float:
        """This tenant's service weight: its quantum per rotation visit is
        ``quantum * weight``, so over saturation a weight-3 tenant gets ~3x
        the served cost of a weight-1 tenant (priority tiers; cost-based
        starvation protection is unchanged — every weight is > 0)."""
        with self._cond:
            return self._weights.get(tenant, self.default_weight)

    def set_weight(self, tenant: str, weight: float) -> None:
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        with self._cond:
            self._weights[tenant] = weight

    # -- producer side -------------------------------------------------------

    def offer(self, tenant: str, item: Any, cost: float = 1.0) -> None:
        """Enqueue ``item`` under ``tenant`` or raise AdmissionError."""
        with self._cond:
            q = self._queues.get(tenant)
            depth = len(q) if q is not None else 0
            if depth >= self.max_queued_per_tenant:
                raise AdmissionError(
                    f"tenant {tenant!r} backlog full "
                    f"({depth}/{self.max_queued_per_tenant} queued)",
                    tenant, "tenant")
            if self._total >= self.max_queued_total:
                raise AdmissionError(
                    f"service backlog full "
                    f"({self._total}/{self.max_queued_total} queued)",
                    tenant, "total")
            if q is None:
                q = self._queues[tenant] = deque()
                self._costs[tenant] = deque()
            if not q and tenant not in self._rotation:
                self._rotation.append(tenant)
            q.append(item)
            self._costs[tenant].append(max(cost, 0.0))
            self._total += 1
            self._total_cost += max(cost, 0.0)
            self._cond.notify()

    # -- consumer side (the service pump) ------------------------------------

    def take(self, timeout: Optional[float] = None) -> Optional[Any]:
        """Next item under DRR policy; blocks up to ``timeout`` for one to
        arrive (None on timeout / empty)."""
        with self._cond:
            if self._total == 0 and not self._cond.wait_for(
                    lambda: self._total > 0, timeout):
                return None
            return self._take_locked()

    def _take_locked(self) -> Optional[Any]:
        # Each pass over the rotation grants every non-empty tenant one
        # quantum, so any head item (finite cost) becomes servable after
        # at most ceil(max_cost / quantum) passes — the loop terminates.
        while self._rotation:
            tenant = self._rotation[0]
            q = self._queues.get(tenant)
            if not q:
                self._rotation.popleft()
                self._deficits[tenant] = 0.0
                continue
            cost = self._costs[tenant][0]
            if cost <= self._deficits.get(tenant, 0.0):
                item = q.popleft()
                self._costs[tenant].popleft()
                self._total -= 1
                self._total_cost = max(0.0, self._total_cost - cost)
                if q:
                    self._deficits[tenant] = self._deficits[tenant] - cost
                else:
                    self._rotation.popleft()
                    self._deficits[tenant] = 0.0  # no banking while idle
                return item
            # weighted DRR: a visit grants quantum * weight, so relative
            # served cost under saturation tracks the weight ratio
            self._deficits[tenant] = self._deficits.get(tenant, 0.0) \
                + self.quantum * self._weights.get(tenant,
                                                   self.default_weight)
            self._rotation.rotate(-1)
        return None

    def extract(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Remove and return every queued item with ``pred(item)`` true —
        the batching hook: the service coalesces same-plan actions from
        ALL tenants into the leader's dispatch.  Extraction does not
        touch deficits: a batched follower rides for free (its execution
        is shared, so charging its tenant would double-bill)."""
        out: List[Any] = []
        with self._cond:
            for tenant, q in self._queues.items():
                if not q:
                    continue
                keep: Deque[Any] = deque()
                keep_costs: Deque[float] = deque()
                for item, cost in zip(q, self._costs[tenant]):
                    if pred(item):
                        out.append(item)
                        self._total -= 1
                        self._total_cost = max(0.0, self._total_cost - cost)
                    else:
                        keep.append(item)
                        keep_costs.append(cost)
                self._queues[tenant] = keep
                self._costs[tenant] = keep_costs
        return out

    # -- introspection -------------------------------------------------------

    def depth(self, tenant: str) -> int:
        with self._cond:
            q = self._queues.get(tenant)
            return len(q) if q is not None else 0

    def total_cost(self) -> float:
        """Summed cost of everything queued (all tenants) — the backlog
        size in cost units, which the service's latency-aware admission
        multiplies by the observed per-cost-unit service rate."""
        with self._cond:
            return self._total_cost

    def depths(self) -> Dict[str, int]:
        with self._cond:
            return {t: len(q) for t, q in self._queues.items() if q}

    def __len__(self) -> int:
        return self._total
