"""Cross-session dispatch coalescing: the batch key and the queue record.

Interactive multi-tenant load is repetitive: dashboards and notebooks
from different sessions fire the *same* compiled plan over the *same*
persisted dataset.  Executing each copy serially through the executor
wastes the device; the service instead groups queued actions whose
results are provably identical and dispatches the group ONCE — the
leader executes, every member's handle resolves to the shared value.

"Provably identical" is :func:`batch_key`:

* the **result lineage digest** — root fingerprint of the underlying
  dataset extended by the pending plan's canonical stage signatures.
  Two sessions batch only when they act on the same source through the
  same logical stages (module-level ``key_by``/``value_by`` callables
  keep signatures equal across sessions; lambdas defeat coalescing the
  same way they defeat the compile cache);
* the **finalize identity** — ``collect()`` vs ``collect(shard=0)``
  produce different host values, so the per-shard finalizers are cached
  module-level partials (one object per shard index) and the sync path
  always uses ``finalize=None``;
* the **fuse flag** and **plan-cache identity** — different execution
  configurations never share a dispatch, even though their values would
  match (keeps per-config diagnostics honest).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Tuple

from repro.core.plan import Plan
from repro.core.dataset import ShardedDataset
from repro.runtime.executor import ActionHandle
from repro.runtime.lineage import Lineage
from repro.runtime.reports import ReportLog

#: (lineage digest, fuse, finalize id, plan-cache id)
BatchKey = Tuple[str, bool, Optional[int], Optional[int]]


def batch_key(root: Lineage, plan: Plan, *, fuse: bool,
              finalize: Optional[Callable], plan_cache: Any) -> BatchKey:
    """Key under which queued actions may share one dispatch."""
    lineage = root if plan.empty else root.extend(plan)
    return (lineage.digest(), fuse,
            id(finalize) if finalize is not None else None,
            id(plan_cache) if plan_cache is not None else None)


@dataclasses.dataclass
class Pending:
    """One admitted, not-yet-dispatched action in a tenant's queue."""

    key: BatchKey
    tenant: str
    ds: ShardedDataset
    plan: Plan
    fuse: bool
    plan_cache: Any
    finalize: Optional[Callable[[ShardedDataset], Any]]
    reports: Optional[ReportLog]          # the session's report stream
    label: Optional[str]
    cost: float                           # DRR cost (pending stage count)
    handle: ActionHandle                  # resolved at dispatch completion
    submitted_at: float = dataclasses.field(
        default_factory=time.monotonic)
