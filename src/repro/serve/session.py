"""Session: a tenant-scoped handle over the shared QueryService.

A session looks like plain single-user repro — ``session.mare(data)``
returns a normal :class:`~repro.core.mare.MaRe` with the full primitive
and action API — but every action the chain fires is routed through the
service: admitted (or rejected) at the tenant's backlog limit, scheduled
fairly against other tenants, batched with identical queries from other
sessions, and reported into the session's own
:class:`~repro.runtime.reports.ReportStream`.

The routing trick is the executor seam MaRe already has: MaRe talks to
"its executor" through five calls (``run`` / ``submit_action`` /
``persist`` / ``ensure_lineage`` / ``cached_prefix``).
:class:`_TenantExecutor` implements exactly that surface, stamping the
session's tenant on every call — sync actions submit with
``finalize=None`` (key-stable, so identical sync queries from different
sessions coalesce) and block on the handle; ``persist`` charges the
entry to the tenant's cache partition.  MaRe itself is unchanged and
unaware of tenancy.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

from repro.core.dataset import ShardedDataset
from repro.core.mare import MaRe
from repro.core.plan import Plan
from repro.runtime.executor import ActionHandle
from repro.runtime.lineage import Lineage
from repro.runtime.reports import ActionReport, ReportStream
from repro.serve.service import QueryService


class _TenantExecutor:
    """Executor-shaped proxy: MaRe's runtime surface, routed through the
    service with the session's tenant attached.  Intentionally NOT an
    Executor subclass — anything outside the seam (queue internals,
    ``submit``) stays on the real executor via delegation below."""

    def __init__(self, session: "Session") -> None:
        self._session = session
        self._service = session.service

    # MaRe._materialize: sync action -> admitted + scheduled + batched,
    # then block.  finalize=None keeps the batch key identical across
    # sessions issuing the same sync query.
    def run(self, ds: ShardedDataset, plan: Plan, *, fuse: bool = True,
            plan_cache: Any = None, reports: Any = None,
            label: Optional[str] = None, queue_wait_s: float = 0.0,
            tenant: Optional[str] = None
            ) -> Tuple[ShardedDataset, ActionReport]:
        handle = self._service.submit(
            tenant=self._session.tenant, ds=ds, plan=plan, finalize=None,
            fuse=fuse, plan_cache=plan_cache, reports=reports, label=label)
        out = handle.result()
        return out, handle.report

    # MaRe.collect(asynchronous=True)
    def submit_action(self, ds: ShardedDataset, plan: Plan, *,
                      finalize: Optional[Callable[[ShardedDataset], Any]]
                      = None,
                      fuse: bool = True, plan_cache: Any = None,
                      reports: Any = None, label: Optional[str] = None,
                      tenant: Optional[str] = None) -> ActionHandle:
        return self._service.submit(
            tenant=self._session.tenant, ds=ds, plan=plan,
            finalize=finalize, fuse=fuse, plan_cache=plan_cache,
            reports=reports, label=label)

    # MaRe.persist: charge the entry to this tenant's cache partition
    def persist(self, ds: ShardedDataset, tier: str = "device",
                owner: Optional[str] = None):
        return self._service.executor.persist(
            ds, tier=tier,
            owner=owner if owner is not None else self._session.tenant)

    # key/bookkeeping lookups need no scheduling — straight through
    def ensure_lineage(self, ds: ShardedDataset) -> Lineage:
        return self._service.executor.ensure_lineage(ds)

    def cached_prefix(self, ds: ShardedDataset, plan: Plan):
        return self._service.executor.cached_prefix(ds, plan)

    @property
    def mat_cache(self):
        return self._service.executor.mat_cache

    @property
    def plan_cache(self):
        return self._service.executor.plan_cache

    @property
    def reports(self):
        """The EXECUTOR's global history (every tenant's dispatches);
        per-session history lives on ``Session.reports``."""
        return self._service.executor.reports


class Session:
    """One tenant's interactive handle on a shared QueryService.

    .. code-block:: python

        svc = QueryService(config=ServiceConfig(
            tenant_device_budget_bytes=64 << 20))
        alice = svc.session("alice")
        data = alice.mare(shared_dataset).map(image=..., command=...)
        pinned = data.persist()            # charged to alice's partition
        rows = pinned.collect(shard=0)     # fair-scheduled + batched

    Constructing ``Session(tenant="alice")`` without a service spins up a
    private one (single-tenant convenience; pass ``service=`` to share).
    ``reports`` is the session's live :class:`ReportStream`: every action
    this session runs appends exactly one report (with ``tenant``,
    ``batch_size``, per-member ``queue_wait_s``) — :meth:`follow` blocks
    for reports not yet seen.
    """

    def __init__(self, tenant: str,
                 service: Optional[QueryService] = None) -> None:
        if not tenant:
            raise ValueError("tenant must be a non-empty string")
        self.tenant = tenant
        self.service = service if service is not None else QueryService()
        self.reports: ReportStream = ReportStream()
        self.executor = _TenantExecutor(self)

    def mare(self, data: Any, **kwargs: Any) -> MaRe:
        """A MaRe chain whose actions route through this session (accepts
        every ``MaRe(...)`` keyword except ``executor``/``_reports``,
        which the session owns)."""
        for reserved in ("executor", "_reports"):
            if reserved in kwargs:
                raise TypeError(
                    f"Session.mare() manages {reserved!r}; it cannot be "
                    f"overridden per chain")
        return MaRe(data, executor=self.executor, _reports=self.reports,
                    **kwargs)

    __call__ = mare

    # -- report stream -------------------------------------------------------

    def report(self) -> Optional[ActionReport]:
        """Newest report of any chain in this session."""
        return self.reports.latest

    def follow(self, seen: int = 0, timeout: Optional[float] = None
               ) -> List[ActionReport]:
        """Reports appended after the first ``seen`` (blocks until one
        arrives or ``timeout``); cursor pattern: ``seen += len(batch)``."""
        return self.reports.next_after(seen, timeout)

    # -- streaming -----------------------------------------------------------

    def stream(self, source: Any, build: Callable[[MaRe], MaRe], *,
               window: Optional[int] = None, slide: int = 1,
               label: Optional[str] = None, **kwargs: Any):
        """A session-scoped incremental query over a
        :class:`~repro.stream.source.ContinuousSource` (docs/streaming.md).

        Every epoch's delta action routes through this session — admitted
        at the tenant's limits, fair-scheduled, batched — and every
        refresh appends one report (with ``stream.*`` counters) to
        :attr:`reports`, so :meth:`follow` wakes per refresh: wrap the
        returned query in a :class:`~repro.stream.live.LiveQuery` for a
        live dashboard.  ``window=None`` maintains the full-history
        aggregate (:class:`~repro.stream.incremental.IncrementalQuery`);
        ``window=S`` a sliding window of S epochs emitting every
        ``slide`` arrivals (:class:`~repro.stream.windows.WindowedQuery`;
        ``slide=S`` makes it tumbling).
        """
        # deferred: serve must stay importable without the stream package
        from repro.stream import IncrementalQuery, WindowedQuery
        for reserved in ("executor", "reports"):
            if reserved in kwargs:
                raise TypeError(f"Session.stream() manages {reserved!r}; "
                                f"it cannot be overridden per query")
        label = label if label is not None else f"{self.tenant}/stream"
        if window is None:
            return IncrementalQuery(source, build, executor=self.executor,
                                    reports=self.reports, label=label,
                                    **kwargs)
        return WindowedQuery(source, build, size=window, slide=slide,
                             executor=self.executor, reports=self.reports,
                             label=label, **kwargs)

    # -- introspection -------------------------------------------------------

    def queue_depth(self) -> int:
        """Actions of THIS tenant currently queued (admitted, not yet
        dispatched)."""
        return self.service.scheduler.depth(self.tenant)

    def cache_bytes(self) -> dict:
        """This tenant's materialization-cache footprint per tier."""
        return self.service.executor.mat_cache.owner_bytes().get(
            self.tenant, {"device": 0, "host": 0})

    def __repr__(self) -> str:
        return (f"Session(tenant={self.tenant!r}, "
                f"queued={self.queue_depth()}, "
                f"actions={self.reports.appended})")
