"""QueryService: the shared multi-tenant engine behind every Session.

One service owns one :class:`~repro.runtime.executor.Executor` and puts
three policies between sessions and it:

1. **Admission + fairness** — submissions pass the
   :class:`~repro.serve.scheduler.DeficitRoundRobin` front door
   (per-tenant and total backlog limits raise
   :class:`~repro.serve.scheduler.AdmissionError`), and a single pump
   thread drains the tenant queues in DRR order into the executor's
   *bounded* dispatch queue — when the device is saturated the pump
   blocks on that queue, backlog accumulates under per-tenant limits,
   and overload surfaces as rejections at the offending tenant instead
   of unbounded latency for everyone.
2. **Batching** — after taking a leader the pump waits
   ``batch_window_s`` and extracts every queued action with the same
   :func:`~repro.serve.batching.batch_key` (any tenant), executing the
   group as ONE executor action; every member's handle resolves to the
   shared value and receives its own per-tenant
   :class:`~repro.runtime.reports.ActionReport` clone (``batch_size``,
   ``batch_leader``, own ``queue_wait_s``).
3. **Cache partitioning** — the config's per-tenant budgets are applied
   to the executor's :class:`~repro.runtime.cache.MaterializationCache`,
   and ``Session.persist`` charges entries to the owning tenant, so one
   tenant's persists can only evict that tenant's entries; *reads* of a
   common lineage prefix stay shared across tenants (counted as
   ``shared_hits``).

Metrics (process registry): ``serve.queue_depth.<tenant>`` gauges,
``serve.admission_rejected`` counter, ``serve.dispatches`` counter,
``serve.batched_followers`` counter, ``serve.batch_occupancy``
histogram (mean = average actions per dispatch),
``serve.service_s_per_cost`` histogram (the latency-admission rate
estimate's samples) and ``serve.latency_rejected`` counter (rejections
from the predicted-delay bound specifically).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional

from repro.core.dataset import ShardedDataset
from repro.core.plan import Plan
from repro.obs import METRICS
from repro.runtime.executor import ActionHandle, Executor
from repro.serve.batching import Pending, batch_key
from repro.serve.scheduler import AdmissionError, DeficitRoundRobin


@dataclasses.dataclass
class ServiceConfig:
    """Knobs of one QueryService (all enforced per service instance)."""

    #: Admission: max actions queued per tenant / across all tenants.
    max_queued_per_tenant: int = 8
    max_queued_total: int = 64
    #: DRR credit granted per rotation visit (stage-count units).
    quantum: float = 4.0
    #: Priority tiers: per-tenant DRR weight (a visit grants
    #: ``quantum * weight``, so a weight-3 tenant is served ~3x the cost
    #: of a weight-1 tenant under saturation).  Unlisted tenants get
    #: ``default_weight``.
    tenant_weights: Optional[dict] = None
    default_weight: float = 1.0
    #: Latency-aware admission: reject a submission when its *predicted
    #: queue delay* — (queued cost + its own cost) x the recently observed
    #: seconds-per-cost-unit service rate — exceeds this bound.  None
    #: disables the check (backlog-count limits still apply).  Cold start
    #: admits: with no completed dispatches yet there is no rate to
    #: predict from.
    max_predicted_delay_s: Optional[float] = None
    #: How many recent dispatches the service-rate estimate averages over.
    service_rate_window: int = 32
    #: How long the pump lingers after taking a leader before harvesting
    #: same-key followers.  0 disables batching (strict DRR order).
    batch_window_s: float = 0.01
    #: Per-tenant materialization-cache partitions (None = tier budget is
    #: the only limit).  Applied to the executor's cache at construction.
    tenant_device_budget_bytes: Optional[int] = None
    tenant_host_budget_bytes: Optional[int] = None
    #: Bound of the underlying executor's dispatch queue when the service
    #: constructs its own executor (ignored for a passed-in executor).
    executor_max_pending: int = 2


class QueryService:
    """Shared engine: admission -> fair queue -> batch -> executor.

    Context-manager friendly (``with QueryService() as svc:``) — exit
    stops the pump thread.  All state is per-instance; two services
    never share queues (they may share an executor, though that forfeits
    cross-service fairness).
    """

    def __init__(self, executor: Optional[Executor] = None,
                 config: Optional[ServiceConfig] = None) -> None:
        self.config = config if config is not None else ServiceConfig()
        if executor is None:
            executor = Executor(
                max_pending=self.config.executor_max_pending)
        self.executor = executor
        cache = executor.mat_cache
        if self.config.tenant_device_budget_bytes is not None:
            cache.tenant_device_budget_bytes = \
                self.config.tenant_device_budget_bytes
        if self.config.tenant_host_budget_bytes is not None:
            cache.tenant_host_budget_bytes = \
                self.config.tenant_host_budget_bytes
        self.scheduler = DeficitRoundRobin(
            quantum=self.config.quantum,
            max_queued_per_tenant=self.config.max_queued_per_tenant,
            max_queued_total=self.config.max_queued_total,
            weights=self.config.tenant_weights,
            default_weight=self.config.default_weight)
        # recent (wall_s / cost) samples for latency-aware admission
        self._rate_lock = threading.Lock()
        self._rate_samples: deque = deque(
            maxlen=max(1, self.config.service_rate_window))
        self._stop = threading.Event()
        self._pump: Optional[threading.Thread] = None
        self._pump_lock = threading.Lock()

    # -- session factory -----------------------------------------------------

    def session(self, tenant: str) -> "Session":
        """A :class:`~repro.serve.session.Session` bound to this service."""
        from repro.serve.session import Session  # session imports service
        return Session(tenant, service=self)

    # -- submission (called by sessions, any thread) -------------------------

    def submit(self, *, tenant: str, ds: ShardedDataset, plan: Plan,
               finalize: Optional[Callable[[ShardedDataset], Any]] = None,
               fuse: bool = True, plan_cache: Any = None,
               reports: Any = None,
               label: Optional[str] = None) -> ActionHandle:
        """Admit one action for ``tenant`` and return its handle.

        Raises :class:`AdmissionError` when the tenant's (or the total)
        backlog limit is hit — nothing is queued in that case.
        """
        root = self.executor.ensure_lineage(ds)
        key = batch_key(root, plan, fuse=fuse, finalize=finalize,
                        plan_cache=plan_cache)
        handle = ActionHandle(label=label)
        handle.submitted_at = time.monotonic()
        item = Pending(key=key, tenant=tenant, ds=ds, plan=plan, fuse=fuse,
                       plan_cache=plan_cache, finalize=finalize,
                       reports=reports, label=label,
                       cost=max(1, len(plan.stages)), handle=handle,
                       submitted_at=handle.submitted_at)
        bound = self.config.max_predicted_delay_s
        if bound is not None:
            rate = self.service_rate()
            if rate is not None:
                # backlog cost (everything already admitted, any tenant)
                # plus this action, at the recently observed pace
                predicted = (self.scheduler.total_cost() + item.cost) * rate
                if predicted > bound:
                    METRICS.counter("serve.admission_rejected").inc()
                    METRICS.counter("serve.latency_rejected").inc()
                    raise AdmissionError(
                        f"predicted queue delay {predicted:.3f}s exceeds "
                        f"max_predicted_delay_s={bound:.3f}s "
                        f"(backlog cost {self.scheduler.total_cost():.1f} "
                        f"at {rate * 1e3:.2f}ms/cost-unit)",
                        tenant, "latency")
        try:
            self.scheduler.offer(tenant, item, cost=item.cost)
        except AdmissionError:
            METRICS.counter("serve.admission_rejected").inc()
            raise
        METRICS.gauge(f"serve.queue_depth.{tenant}").add(1)
        self._ensure_pump()
        return handle

    # -- latency-aware admission ---------------------------------------------

    def service_rate(self) -> Optional[float]:
        """Mean seconds per cost unit over the recent dispatch window
        (None until the first dispatch completes — cold start admits)."""
        with self._rate_lock:
            if not self._rate_samples:
                return None
            return sum(self._rate_samples) / len(self._rate_samples)

    def observe_service_rate(self, wall_s: float, cost: float) -> None:
        """Record one completed dispatch's pace.  Called by the dispatch
        path; exposed so tests can seed the estimator deterministically."""
        sample = max(0.0, wall_s) / max(cost, 1e-9)
        with self._rate_lock:
            self._rate_samples.append(sample)
        METRICS.histogram("serve.service_s_per_cost").observe(sample)

    # -- the pump thread -----------------------------------------------------

    def _ensure_pump(self) -> None:
        with self._pump_lock:
            if self._pump is None or not self._pump.is_alive():
                self._stop.clear()
                self._pump = threading.Thread(
                    target=self._pump_loop, name="repro-serve-pump",
                    daemon=True)
                self._pump.start()

    def _pump_loop(self) -> None:
        while not self._stop.is_set():
            leader = self.scheduler.take(timeout=0.1)
            if leader is None:
                continue
            group = [leader]
            if self.config.batch_window_s > 0:
                # linger: same-key submissions racing with the take get
                # to join this dispatch instead of paying their own
                time.sleep(self.config.batch_window_s)
                key = leader.key
                group += self.scheduler.extract(lambda p: p.key == key)
            for member in group:
                METRICS.gauge(
                    f"serve.queue_depth.{member.tenant}").add(-1)
            METRICS.counter("serve.dispatches").inc()
            METRICS.histogram("serve.batch_occupancy").observe(len(group))
            if len(group) > 1:
                METRICS.counter("serve.batched_followers").inc(
                    len(group) - 1)
            self._dispatch(group)

    def _dispatch(self, group: List[Pending]) -> None:
        """Hand one coalesced group to the executor (blocks on its
        bounded queue — the backpressure layer)."""
        leader = group[0]

        def action(_h: ActionHandle) -> None:
            started = time.monotonic()
            try:
                out, report = self.executor.run(
                    leader.ds, leader.plan, fuse=leader.fuse,
                    plan_cache=leader.plan_cache, reports=None,
                    label=leader.label,
                    queue_wait_s=max(0.0, started - leader.submitted_at),
                    tenant=leader.tenant)
                value = (leader.finalize(out)
                         if leader.finalize is not None else out)
                self.observe_service_rate(report.wall_s, leader.cost)
            except BaseException as e:
                # the whole group shares one plan, so it shares the
                # failure; OTHER keys/tenants are untouched
                for member in group:
                    member.handle.started_at = started
                    member.handle._finish(error=e)
                return None
            for member in group:
                clone = dataclasses.replace(
                    report,
                    tenant=member.tenant, label=member.label,
                    queue_wait_s=max(0.0,
                                     started - member.submitted_at),
                    batch_size=len(group),
                    batch_leader=report.action_id)
                if member.reports is not None:
                    clone = dataclasses.replace(
                        clone, action_id=member.reports.new_id())
                    member.reports.append(clone)
                member.handle.report = clone
                member.handle.started_at = started
                member.handle._finish(value=value)
            return None

        self.executor.submit(action, label=leader.label)

    # -- lifecycle -----------------------------------------------------------

    def close(self, timeout: float = 2.0) -> None:
        """Stop the pump thread (queued-but-undispatched actions stay
        queued; their handles never resolve — close after draining)."""
        self._stop.set()
        pump = self._pump
        if pump is not None and pump.is_alive():
            pump.join(timeout)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
