from repro.train.step import (StepConfig, TrainState, init_train_state,
                              make_eval_step, make_serve_steps,
                              make_train_step)
from repro.train.trainer import FailureInjector, Trainer, TrainerConfig

__all__ = ["StepConfig", "TrainState", "init_train_state", "make_train_step",
           "make_eval_step", "make_serve_steps", "Trainer", "TrainerConfig",
           "FailureInjector"]
