"""Trainer loop: checkpoint/restart fault tolerance + failure injection.

The loop is deliberately restart-oriented (large-scale reality: any step
may die).  ``FailureInjector`` lets tests kill arbitrary steps; ``run``
catches the failure, restores the last checkpoint and replays — the
Spark-lineage analogue at checkpoint granularity (DESIGN.md §2.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.train.step import TrainState


class FailureInjector:
    """Deterministically fail at given steps (once each)."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    max_restarts: int = 3


class Trainer:
    def __init__(self, train_step: Callable, state: TrainState,
                 batches: Iterator, manager: CheckpointManager,
                 cfg: TrainerConfig = TrainerConfig(),
                 injector: Optional[FailureInjector] = None,
                 batch_fn: Optional[Callable[[int], Any]] = None):
        """``batches``: iterator of batches; OR ``batch_fn(step)`` for
        deterministic replay after restart (preferred for fault
        tolerance — an iterator cannot rewind)."""
        self.train_step = train_step
        self.state = state
        self.batches = batches
        self.batch_fn = batch_fn
        self.manager = manager
        self.cfg = cfg
        self.injector = injector or FailureInjector()
        self.history: List[Dict[str, float]] = []
        self.restarts = 0

    def _batch_for(self, step: int):
        if self.batch_fn is not None:
            return self.batch_fn(step)
        return next(self.batches)

    def run(self) -> TrainState:
        while True:
            try:
                self._run_from(int(self.state.step))
                break
            except RuntimeError as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                latest = self.manager.latest_step()
                if latest is None:
                    # no checkpoint yet: restart from step 0 state
                    continue
                self.manager.wait()
                self.state = self.manager.restore(self.state)
                print(f"[trainer] restart #{self.restarts} from step "
                      f"{int(self.state.step)} after: {e}")
        self.manager.wait()
        return self.state

    def _run_from(self, start: int):
        for step in range(start, self.cfg.total_steps):
            self.injector.maybe_fail(step)
            batch = self._batch_for(step)
            t0 = time.monotonic()
            self.state, metrics = self.train_step(self.state, batch)
            if (step + 1) % self.cfg.log_every == 0 or step == 0:
                m = {k: float(jax.device_get(v))
                     for k, v in metrics.items()}
                m["step"] = step + 1
                m["dt"] = time.monotonic() - t0
                self.history.append(m)
                print(f"[trainer] step {step+1} "
                      + " ".join(f"{k}={v:.4g}" for k, v in m.items()
                                 if k != "step"))
            if (step + 1) % self.cfg.checkpoint_every == 0:
                self.manager.save(step + 1, self.state)
