"""train_step / serve_step factories.

Gradient synchronization is a first-class MaRe feature (DESIGN.md §3.1):

* ``grad_sync="fused"``    — beyond-paper: params carry NamedShardings
  (FSDP/TP); XLA emits fused reduce-scatter/all-gather collectives and
  overlaps them with the backward pass.  Default for all large cells.
* ``grad_sync="mare_tree"`` — paper-faithful: the whole value-and-grad runs
  inside shard_map with replicated params; gradients are combined with the
  K-level ppermute tree (``tree_allreduce``, default K=2) exactly like the
  paper's reduce primitive.  DP-only (small archs), optionally with int8
  error-feedback compression on the wire.
* ``grad_sync="hierarchical"`` — the paper's K=2 tree at mesh granularity
  on multi-pod meshes: psum over "data", then over "pod".

Microbatching (gradient accumulation) runs as a ``lax.scan`` over the
leading microbatch axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
from repro import compat
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.tree_reduce import tree_allreduce
from repro.models import Model
from repro.optim import Optimizer, apply_updates, clip_by_global_norm
from repro.optim.compression import error_feedback_compress, init_residual
from repro.sharding import Rules, use_rules


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray
    residual: Any = ()       # error-feedback buffer (compression only)


def init_train_state(model: Model, optimizer: Optimizer, rng,
                     compression: bool = False) -> TrainState:
    params = model.init(rng)
    res = init_residual(params) if compression else ()
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32), residual=res)


@dataclasses.dataclass(frozen=True)
class StepConfig:
    grad_sync: str = "fused"            # fused | mare_tree | hierarchical
    tree_depth: int = 2                 # MaRe reduce K
    microbatch: int = 1                 # gradient-accumulation factor
    clip_norm: float = 1.0
    compression: bool = False           # int8 EF (mare_tree only)
    moe_mode: str = "weight_gather"


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model: Model, optimizer: Optimizer,
                    lr_schedule: Callable,
                    step_cfg: StepConfig = StepConfig(),
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Rules] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    The caller jits it (with in/out shardings for the fused path)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if step_cfg.microbatch > 1:
            mb = _split_microbatches(batch, step_cfg.microbatch)

            def acc(carry, b1):
                g_acc, l_acc = carry
                (l, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b1)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g, l), _ = jax.lax.scan(acc, (zeros, jnp.zeros((),
                                                            jnp.float32)),
                                     mb)
            n = step_cfg.microbatch
            return jax.tree.map(lambda x: x / n, g), l / n, {}
        (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return g, l, metrics

    def apply(state: TrainState, grads, loss, metrics):
        grads, gnorm = clip_by_global_norm(grads, step_cfg.clip_norm)
        lr = lr_schedule(state.step)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, lr)
        params = apply_updates(state.params, updates)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                       **{k: v for k, v in metrics.items()}}
        return TrainState(params=params, opt_state=opt_state,
                          step=state.step + 1,
                          residual=state.residual), out_metrics

    if step_cfg.grad_sync in ("fused", "hierarchical"):
        def train_step(state: TrainState, batch):
            with use_rules(rules, mesh):
                grads, loss, metrics = grads_of(state.params, batch)
                if step_cfg.grad_sync == "hierarchical" and mesh is not None \
                        and "pod" in mesh.shape:
                    # paper K=2 tree at mesh granularity is implicit in the
                    # (pod, data) sharding — XLA emits the hierarchical
                    # reduce; nothing to do beyond the sharding constraint.
                    pass
                return apply(state, grads, loss, metrics)
        return train_step

    if step_cfg.grad_sync == "mare_tree":
        assert mesh is not None, "mare_tree needs a mesh"
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        axis_sizes = {a: int(mesh.shape[a]) for a in batch_axes}

        def train_step(state: TrainState, batch):
            def inner(state, batch):
                grads, loss, metrics = grads_of(state.params, batch)
                residual = state.residual
                if step_cfg.compression:
                    _, grads, residual = error_feedback_compress(
                        grads, state.residual)
                # K-level MaRe reduce per batch axis (innermost first —
                # the paper's intra-node-then-cross-node tree)
                n_total = 1
                for ax in reversed(batch_axes):
                    grads = tree_allreduce(grads, ax, axis_sizes[ax],
                                           depth=step_cfg.tree_depth)
                    n_total *= axis_sizes[ax]
                grads = jax.tree.map(lambda g: g / n_total, grads)
                loss = jax.lax.pmean(loss, batch_axes)
                metrics = jax.tree.map(
                    lambda m: jax.lax.pmean(m, batch_axes), metrics)
                state = state._replace(residual=residual)
                new_state, out = apply(state, grads, loss, metrics)
                return new_state, out

            in_batch_spec = jax.tree.map(
                lambda _: P(batch_axes if len(batch_axes) > 1
                            else batch_axes[0]), batch)
            return compat.shard_map(
                inner, mesh=mesh,
                in_specs=(P(), in_batch_spec),
                out_specs=(P(), P()),
                check_vma=False,
            )(state, batch)
        return train_step

    raise ValueError(step_cfg.grad_sync)


def make_eval_step(model: Model, mesh=None, rules=None):
    def eval_step(params, batch):
        with use_rules(rules, mesh):
            loss, metrics = model.loss(params, batch)
        return metrics
    return eval_step


def make_serve_steps(model: Model, mesh=None, rules=None,
                     max_len: int = 2048):
    """(prefill_fn, decode_fn) for batched serving."""

    def prefill_fn(params, batch):
        with use_rules(rules, mesh):
            return model.prefill(params, batch, max_len)

    def decode_fn(params, caches, tokens):
        with use_rules(rules, mesh):
            return model.decode_step(params, caches, tokens)

    return prefill_fn, decode_fn
