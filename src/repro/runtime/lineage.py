"""Lineage fingerprints: the identity of a materialized dataset.

A :class:`Lineage` names a dataset by *how it was produced*: a root
source id plus the canonical signature of every stage applied since that
root — the RDD-lineage idea from the MapReduce survey literature
(Sakr et al., 1302.2966), reduced to a hashable cache key.  Two MaRe
handles forked from the same base dataset share a lineage prefix, so a
materialization registered by ``persist()`` on one handle is found by
*any* handle whose plan prefix reaches the same lineage node (see
:mod:`repro.runtime.cache`).

Roots come in two flavors:

* **host roots** (:func:`host_root`) — a process-unique token per
  ``from_host``-style dataset.  Content identity of arbitrary host
  arrays is unknown, so equal arrays parallelized twice get distinct
  roots (conservative: never a false cache hit).
* **source roots** (:func:`source_root`) — a content digest over a
  :class:`~repro.io.source.DataSource`'s resolved splits and pack
  geometry.  Re-ingesting the same byte ranges of the same files yields
  the SAME root, so an interactive session can re-open a source and
  still hit materializations persisted earlier.  This assumes sources
  are immutable while cached (the HDFS/object-store model the paper
  targets); mutating a file in place under a live cache is undetected.

Stage signatures reuse :meth:`repro.core.plan.Plan.signature` — the same
canonical form the compile cache keys on — so the two caches agree on
when two pipelines are "the same", including the callable-identity
caveats for ``key_by`` documented there.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Hashable, Iterable, Optional, TYPE_CHECKING, Tuple

if TYPE_CHECKING:
    from repro.core.plan import Plan

_HOST_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class Lineage:
    """Root source id + canonical signatures of every stage applied."""

    source: Hashable
    stages: Tuple[Hashable, ...] = ()

    def extend(self, plan: Plan, upto: Optional[int] = None) -> "Lineage":
        """Lineage after applying ``plan``'s first ``upto`` stages (all
        stages when ``upto`` is None)."""
        stages = plan.stages if upto is None else plan.stages[:upto]
        return Lineage(self.source,
                       self.stages + tuple(st.signature() for st in stages))

    @property
    def depth(self) -> int:
        return len(self.stages)

    def digest(self) -> str:
        """Short stable-ish hex tag for logs and ``describe()`` output
        (identity-keyed stage signatures make it process-local)."""
        h = hashlib.sha1(repr((self.source, self.stages)).encode())
        return h.hexdigest()[:8]

    def describe(self) -> str:
        root = self.source[0] if isinstance(self.source, tuple) \
            else self.source
        return f"lineage[{root}+{self.depth} stages @{self.digest()}]"


def host_root(tag: str = "host") -> Lineage:
    """Fresh process-unique root for a host-parallelized dataset."""
    return Lineage(source=(tag, next(_HOST_IDS)))


def source_root(backend_name: str, fmt_name: str, splits: Iterable,
                capacity: int, width: int) -> Lineage:
    """Content-keyed root for an ingested DataSource: same backend,
    format, byte ranges and pack geometry -> same root."""
    h = hashlib.sha1()
    h.update(f"{backend_name}|{fmt_name}|{capacity}|{width}".encode())
    for sp in splits:
        h.update(f"|{sp.path}:{sp.start}:{sp.stop}:{sp.file_size}".encode())
    return Lineage(source=("source", h.hexdigest()))


def stream_root(base: Lineage, epoch: int) -> Lineage:
    """Snapshot-generation root for an incrementally maintained aggregate
    (:mod:`repro.stream`): the base lineage of the maintained query plus
    the epoch watermark folded in so far.  Distinct epochs are distinct
    cache keys — a persisted generation N materialization can never be
    mistaken for generation N+1 — while the same (base, epoch) pair from
    any handle reaches the same entry."""
    return Lineage(source=("stream", base.source, base.stages, epoch))
