"""repro.runtime — the action engine extracted from core.

Layering (bottom-up): ``repro.io`` ingests bytes into sharded datasets,
``repro.core.plan`` accumulates lazy stage DAGs with plan-time type
inference, ``repro.core.planner`` lowers a DAG into one memoized
``shard_map`` program, and **this package executes actions**: lineage
fingerprints (:mod:`~repro.runtime.lineage`), the budgeted device/host
materialization cache behind ``MaRe.persist()``
(:mod:`~repro.runtime.cache`), the dispatch/counter-sync/report engine
with async action handles (:mod:`~repro.runtime.executor`), and
structured per-action diagnostics (:mod:`~repro.runtime.reports`).
"""
from repro.runtime.cache import (DEVICE_BUDGET_DEFAULT, HOST_BUDGET_DEFAULT,
                                 CacheEntry, MaterializationCache,
                                 estimate_nbytes)
from repro.runtime.executor import (DEFAULT_EXECUTOR, ActionHandle,
                                    Executor, check_counters, execute)
from repro.runtime.lineage import (Lineage, host_root, source_root,
                                   stream_root)
from repro.runtime.reports import ActionReport, ReportLog, ReportStream

__all__ = [
    "ActionHandle", "ActionReport", "CacheEntry", "DEFAULT_EXECUTOR",
    "DEVICE_BUDGET_DEFAULT", "Executor", "HOST_BUDGET_DEFAULT", "Lineage",
    "MaterializationCache", "ReportLog", "ReportStream", "check_counters",
    "estimate_nbytes", "execute", "host_root", "source_root",
    "stream_root",
]
