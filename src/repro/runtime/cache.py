"""Lineage-keyed materialization cache with budgeted device/host tiers.

The result-cache half of interactive processing (paper §Conclusions /
Fig. 6): ``MaRe.persist()`` registers a materialized
:class:`~repro.core.dataset.ShardedDataset` under its lineage
fingerprint, and any later action whose plan *prefix* reaches a cached
lineage node starts from the cached dataset and only executes the
suffix — the Spark ``RDD.cache()`` contract, which the compile cache
alone (PR 2) could not provide.

Budgeting: entry size is estimated from the dataset's record *schema* ×
capacity × shard count (the PR 4 manifest machinery — no device sync
needed), and each tier is a byte-budgeted LRU:

* ``device`` — entries hold live sharded arrays; evicting spills the
  entry to the ``host`` tier (one ``device_get``), mirroring Spark's
  ``MEMORY -> DISK`` storage-level ladder (tmpfs -> staging dir in the
  paper's container terms).
* ``host`` — entries hold numpy copies plus the mesh geometry needed to
  re-``device_put`` them on a hit; evicting drops the entry (it can
  always be recomputed from lineage).

Multi-tenant partitions (the serving layer): entries carry an ``owner``
tag and, when per-tenant budgets are configured, each owner's resident
bytes are bounded *independently* of everyone else's — one tenant
persisting past its partition evicts ITS OWN least-recent entries
(device spills to host, host drops), never a neighbor's.  Lookups stay
shared and read-only: any tenant whose plan prefix reaches a cached
lineage node hits it regardless of who paid for it (counted as
``shared_hits`` when owner and reader differ) — common prefixes over a
shared persisted dataset are paid once, which is the whole point of the
interactive service.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dataset import ShardedDataset
from repro.core.plan import Plan
from repro.core.schema import schema_of_records
from repro.obs import METRICS, instant, span
from repro.runtime.lineage import Lineage

TIERS = ("device", "host")


def estimate_nbytes(ds: ShardedDataset) -> int:
    """Schema-based size estimate: itemsize x record shape x capacity x
    shards per leaf, plus the counts vector (no device transfer)."""
    schema = schema_of_records(ds.records)
    rows = ds.capacity * ds.num_shards
    total = ds.num_shards * 4    # counts: int32 per shard
    for f in jax.tree.leaves(schema.fields):
        per_record = int(np.prod(f.shape)) if f.shape else 1
        total += np.dtype(f.dtype).itemsize * per_record * rows
    return int(total)


@dataclasses.dataclass
class CacheEntry:
    """One materialized lineage node, resident in exactly one tier."""

    lineage: Lineage
    tier: str                        # "device" | "host"
    nbytes: int
    dataset: Optional[ShardedDataset] = None       # device tier
    host_records: Any = None                       # host tier (numpy)
    host_counts: Optional[np.ndarray] = None
    mesh: Any = None
    axis: str = "data"
    #: Tenant charged for this entry's bytes (None = unowned/shared pool).
    owner: Optional[str] = None


#: Default per-tier budgets: every ``persist()``/``cache()`` pins its
#: materialization in the process-wide store, so the defaults are FINITE
#: — without them, a loop persisting distinct lineages would grow device
#: memory monotonically with no eviction.  Raise (or pass ``None`` for
#: unbounded) on machines where more residency is wanted.
DEVICE_BUDGET_DEFAULT = 1 << 30   # 1 GiB estimated device-resident bytes
HOST_BUDGET_DEFAULT = 4 << 30     # 4 GiB spilled host copies


class MaterializationCache:
    """Budgeted two-tier LRU store of materialized datasets by lineage.

    ``device_budget_bytes`` / ``host_budget_bytes`` bound the estimated
    resident bytes per tier; ``None`` means unbounded.  One shared LRU
    order spans both tiers (a device hit and a host hit both refresh
    recency), but budgets and eviction are per tier: device evicts by
    spilling to host, host evicts by dropping.
    """

    def __init__(self,
                 device_budget_bytes: Optional[int] = DEVICE_BUDGET_DEFAULT,
                 host_budget_bytes: Optional[int] = HOST_BUDGET_DEFAULT,
                 tenant_device_budget_bytes: Optional[int] = None,
                 tenant_host_budget_bytes: Optional[int] = None
                 ) -> None:
        self.device_budget_bytes = device_budget_bytes
        self.host_budget_bytes = host_budget_bytes
        #: Per-OWNER partition bounds (None = partitions unbounded; the
        #: global budgets still apply).  Enforced against each owner's
        #: charged bytes independently: an over-budget owner only ever
        #: evicts its own entries.
        self.tenant_device_budget_bytes = tenant_device_budget_bytes
        self.tenant_host_budget_bytes = tenant_host_budget_bytes
        self._entries: "OrderedDict[Lineage, CacheEntry]" = OrderedDict()
        # persist() runs on the caller's thread while async actions hit
        # the store from the executor's dispatch thread — every public
        # method takes this lock
        self._lock = threading.RLock()
        self.hits = 0
        self.host_hits = 0
        self.shared_hits = 0      # reader != owner on an owned entry
        self.misses = 0
        self.puts = 0
        self.spills = 0
        self.drops = 0
        #: Explicit :meth:`drop` removals (streaming generation GC /
        #: window eviction) — separate from LRU ``drops``.
        self.invalidations = 0
        #: Count of enforcement passes that left some owner partition
        #: over its budget (impossible by construction — the serve
        #: benchmark asserts it stays 0).
        self.tenant_budget_violations = 0

    # -- accounting ----------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def tier_bytes(self, tier: str, owner: Any = Ellipsis) -> int:
        """Resident estimated bytes in ``tier`` (``owner=`` filters to one
        owner's charged entries; the default counts everyone's)."""
        with self._lock:
            return sum(e.nbytes for e in self._entries.values()
                       if e.tier == tier
                       and (owner is Ellipsis or e.owner == owner))

    def owner_bytes(self) -> Dict[Optional[str], Dict[str, int]]:
        """Per-owner charged bytes by tier — the serve benchmark's
        cross-tenant budget-violation check reads this."""
        with self._lock:
            out: Dict[Optional[str], Dict[str, int]] = {}
            for e in self._entries.values():
                per = out.setdefault(e.owner, {t: 0 for t in TIERS})
                per[e.tier] += e.nbytes
            return out

    def entry(self, lineage: Lineage) -> Optional[CacheEntry]:
        """Peek without touching recency or stats (describe/tests)."""
        with self._lock:
            return self._entries.get(lineage)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"entries": len(self._entries),
                    "device_bytes": self.tier_bytes("device"),
                    "host_bytes": self.tier_bytes("host"),
                    "hits": self.hits, "host_hits": self.host_hits,
                    "shared_hits": self.shared_hits,
                    "misses": self.misses, "puts": self.puts,
                    "spills": self.spills, "drops": self.drops,
                    "invalidations": self.invalidations,
                    "tenant_budget_violations":
                        self.tenant_budget_violations}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def drop(self, lineage: Lineage) -> bool:
        """Explicitly remove one entry (any tier), returning whether it
        was resident.  This is *invalidation*, not eviction: the
        streaming layer drops superseded snapshot generations and expired
        window epochs the moment they can no longer be served, instead of
        letting dead entries age out of the LRU while charging their
        owner's budget."""
        with self._lock:
            entry = self._entries.pop(lineage, None)
            if entry is None:
                return False
            self.invalidations += 1
            instant("cache.invalidate", nbytes=entry.nbytes,
                    lineage=lineage.digest())
            METRICS.counter("mat_cache.invalidations").inc()
            return True

    # -- put / eviction ------------------------------------------------------

    def put(self, ds: ShardedDataset, tier: str = "device",
            owner: Optional[str] = None) -> CacheEntry:
        """Register a materialized dataset under its lineage (idempotent
        per lineage: a re-persist refreshes recency, and promotes a
        host-tier entry when asked for device residency).  ``owner``
        charges the entry's bytes to that tenant's budget partition;
        a re-persist of an existing lineage keeps the original owner —
        first payer wins, later tenants share read-only."""
        if tier not in TIERS:
            raise ValueError(f"unknown persist tier {tier!r}; "
                             f"expected one of {TIERS}")
        if ds.lineage is None:
            raise ValueError("dataset has no lineage fingerprint; persist "
                             "through MaRe/Executor, not raw datasets")
        with self._lock:
            existing = self._entries.get(ds.lineage)
            if existing is not None and existing.tier == tier:
                self._entries.move_to_end(ds.lineage)
                return existing
            entry = CacheEntry(lineage=ds.lineage, tier=tier,
                               nbytes=estimate_nbytes(ds),
                               mesh=ds.mesh, axis=ds.axis,
                               owner=existing.owner if existing is not None
                               else owner)
            if tier == "device":
                entry.dataset = ds
            else:
                self._to_host(entry, ds)
            self._entries[ds.lineage] = entry
            self._entries.move_to_end(ds.lineage)
            self.puts += 1
            METRICS.counter(f"mat_cache.{tier}.puts").inc()
            self._enforce_budgets()
            return entry

    def _to_host(self, entry: CacheEntry, ds: ShardedDataset) -> None:
        # NB: runs under self._lock (put/_enforce_budgets), so a large
        # spill stalls concurrent lookups for the device_get's duration —
        # the price of atomic tier accounting; budgets keep spills rare
        entry.host_records = jax.tree.map(
            lambda leaf: np.asarray(jax.device_get(leaf)), ds.records)
        entry.host_counts = np.asarray(jax.device_get(ds.counts))
        entry.dataset = None
        entry.tier = "host"

    def _spill_lru(self, owner: Any = Ellipsis) -> bool:
        """Spill the least-recent device entry (of ``owner``, when given)
        to the host tier; False when that tier has no candidate."""
        victim = next((e for e in self._entries.values()
                       if e.tier == "device"
                       and (owner is Ellipsis or e.owner == owner)), None)
        if victim is None:
            return False
        with span("cache.spill", nbytes=victim.nbytes,
                  lineage=victim.lineage.digest()):
            self._to_host(victim, victim.dataset)
        self.spills += 1
        METRICS.counter("mat_cache.device.evictions").inc()
        return True

    def _drop_lru(self, owner: Any = Ellipsis) -> bool:
        """Drop the least-recent host entry (of ``owner``, when given)."""
        victim_key = next((k for k, e in self._entries.items()
                           if e.tier == "host"
                           and (owner is Ellipsis or e.owner == owner)),
                          None)
        if victim_key is None:
            return False
        instant("cache.drop", nbytes=self._entries[victim_key].nbytes,
                lineage=victim_key.digest())
        del self._entries[victim_key]
        self.drops += 1
        METRICS.counter("mat_cache.host.evictions").inc()
        return True

    def _enforce_budgets(self) -> None:
        # per-owner partitions first: an over-budget owner evicts within
        # its OWN entries, so one tenant's persist pressure can never
        # push a neighbor's materializations out
        if self.tenant_device_budget_bytes is not None or \
                self.tenant_host_budget_bytes is not None:
            owners = {e.owner for e in self._entries.values()
                      if e.owner is not None}
            for owner in owners:
                if self.tenant_device_budget_bytes is not None:
                    while (self.tier_bytes("device", owner)
                           > self.tenant_device_budget_bytes):
                        if not self._spill_lru(owner):
                            break
                if self.tenant_host_budget_bytes is not None:
                    while (self.tier_bytes("host", owner)
                           > self.tenant_host_budget_bytes):
                        if not self._drop_lru(owner):
                            break
                over = ((self.tenant_device_budget_bytes is not None
                         and self.tier_bytes("device", owner)
                         > self.tenant_device_budget_bytes)
                        or (self.tenant_host_budget_bytes is not None
                            and self.tier_bytes("host", owner)
                            > self.tenant_host_budget_bytes))
                if over:
                    self.tenant_budget_violations += 1
                    METRICS.counter(
                        "mat_cache.tenant_budget_violations").inc()
        # device -> host spill, LRU first
        if self.device_budget_bytes is not None:
            while self.tier_bytes("device") > self.device_budget_bytes:
                if not self._spill_lru():
                    break
        # host drop, LRU first
        if self.host_budget_bytes is not None:
            while self.tier_bytes("host") > self.host_budget_bytes:
                if not self._drop_lru():
                    break

    # -- lookup --------------------------------------------------------------

    def get(self, lineage: Lineage, tenant: Optional[str] = None
            ) -> Optional[ShardedDataset]:
        """Dataset for an exact lineage node, or None.  Host-tier hits are
        re-placed onto the mesh (and stay host-resident — promotion back
        to the device tier is the caller's persist decision).  ``tenant``
        identifies the reader: a hit on an entry someone ELSE paid for is
        additionally counted as a shared (read-only) hit."""
        with self._lock:
            entry = self._entries.get(lineage)
            if entry is None:
                self.misses += 1
                METRICS.counter("mat_cache.misses").inc()
                return None
            self._entries.move_to_end(lineage)
            self.hits += 1
            METRICS.counter(f"mat_cache.{entry.tier}.hits").inc()
            if entry.owner is not None and tenant != entry.owner:
                self.shared_hits += 1
                METRICS.counter("mat_cache.shared_hits").inc()
            if entry.tier == "device":
                return entry.dataset
            self.host_hits += 1
            with span("cache.host_restore", nbytes=entry.nbytes):
                sharding = NamedSharding(entry.mesh, P(entry.axis))
                records = jax.tree.map(
                    lambda leaf: jax.device_put(leaf, sharding),
                    entry.host_records)
                counts = jax.device_put(entry.host_counts, sharding)
            return ShardedDataset(records=records, counts=counts,
                                  mesh=entry.mesh, axis=entry.axis,
                                  lineage=lineage)

    def longest_prefix(self, root: Lineage, plan: Plan
                       ) -> Tuple[int, Optional[Lineage]]:
        """Longest plan prefix (stage count, lineage) materialized here.

        Scans from the full plan down to one stage; ``(0, None)`` when no
        prefix (not even the whole plan) is cached.  Pure lookup on keys —
        no data touched and no stats touched, so ``describe()`` may call
        it freely.
        """
        with self._lock:
            for i in range(len(plan.stages), 0, -1):
                lin = root.extend(plan, upto=i)
                if lin in self._entries:
                    return i, lin
            return 0, None

    def lookup_prefix(self, root: Lineage, plan: Plan,
                      tenant: Optional[str] = None
                      ) -> Tuple[int, Optional[str],
                                 Optional[ShardedDataset]]:
        """Atomic longest-prefix lookup + fetch for an action: returns
        ``(stages, tier, dataset)``, or ``(0, None, None)`` — counted as
        one miss — when no prefix is materialized.  Atomicity matters:
        a concurrent ``persist()`` may evict the entry between a bare
        ``longest_prefix`` and ``get``, which would mis-report the
        serving tier."""
        with self._lock:
            k, lin = self.longest_prefix(root, plan)
            if not k:
                self.misses += 1
                METRICS.counter("mat_cache.misses").inc()
                return 0, None, None
            tier = self._entries[lin].tier
            return k, tier, self.get(lin, tenant=tenant)
