"""The action engine: dispatch, counter sync, prefix reuse, async actions.

:mod:`repro.core.planner` stops at *lowering* — turning a stage plan into
a memoized compiled program.  Everything that happens when an action
actually fires lives here:

* **Prefix reuse** — before dispatching, the executor looks up the
  longest plan prefix whose lineage node is materialized in the
  :class:`~repro.runtime.cache.MaterializationCache`; the action starts
  from that cached dataset and only executes the suffix.  This is the
  interactive-processing half of the paper's claim (many queries over
  one persisted dataset pay the shared prefix once).
* **Counter sync** — stage counters (shuffle drops, key-table overflow,
  exchange volume) come back as outputs of the dispatched program and
  are checked ONCE per action, here, not per stage.
* **Structured diagnostics** — every action appends an
  :class:`~repro.runtime.reports.ActionReport` to a bounded history
  (``Executor.reports``) instead of overwriting a single dict.
* **Async actions** — :meth:`Executor.submit_action` queues the action
  on a single dispatch thread behind a *bounded* queue, returning an
  :class:`ActionHandle`; callers (e.g. the wave runner) overlap
  ingestion and host-side packing with compile + device execution while
  backpressure keeps at most ``max_pending`` actions in flight.

The eager path (``MaRe.collect``), the interactive prefix-cached path and
the out-of-core wave loop (:mod:`repro.io.waves`) all funnel through
:meth:`Executor.run` — one engine, one diagnostics channel.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import planner as planner_lib
from repro.core.dataset import ShardedDataset
from repro.core.plan import Plan
from repro.obs import METRICS, span, timed
from repro.runtime.cache import MaterializationCache
from repro.runtime.lineage import Lineage, host_root
from repro.runtime.reports import ActionReport, ReportLog

#: Guards the check-then-set of ShardedDataset.lineage: an async action on
#: the dispatch thread and a describe()/action on the caller thread may
#: race to root the SAME dataset object — two distinct roots would orphan
#: whatever gets persisted under the losing one.
_LINEAGE_LOCK = threading.Lock()

#: Counter kinds reduced with max across shards instead of sum (bounds,
#: not totals) — see :func:`repro.core.plan.stage_counter_kinds`.
MAX_COUNTER_KINDS = frozenset({"max_send_count"})


def check_counters(counter_vec: jax.Array, specs, num_shards: int,
                   diagnostics: Optional[Dict[str, int]] = None,
                   stage_offset: int = 0) -> None:
    """One host sync for ALL stage counters, after the single dispatch.

    Error kinds (shuffle drops, keyed overflow) raise; informational
    kinds land in ``diagnostics`` (as do the error kinds, keyed
    ``"stage<i>.<kind>"``).  ``stage_offset`` shifts reported stage
    indices when the dispatched program was a suffix of a longer plan
    (prefix served from the materialization cache).

    Most kinds are totals and sum across shards; ``max_send_count`` is a
    bound and max-reduces instead — its diagnostic is the tightest
    per-destination ``capacity=`` that would have been lossless for any
    shard this run (the capacity-feedback knob for re-planning a skewed
    exchange).
    """
    grid = np.asarray(jax.device_get(counter_vec)).reshape(
        num_shards, len(specs))
    per = [int(grid[:, i].max()) if kind in MAX_COUNTER_KINDS
           else int(grid[:, i].sum())
           for i, (_, kind) in enumerate(specs)]
    for (stage_idx, kind), total in zip(specs, per):
        METRICS.counter(f"counters.{kind}").inc(int(total))
    if diagnostics is not None:
        for (stage_idx, kind), total in zip(specs, per):
            diagnostics[f"stage{stage_idx + stage_offset}.{kind}"] = \
                int(total)
    drops = [(stage_idx + stage_offset, int(total))
             for (stage_idx, kind), total in zip(specs, per)
             if kind == "shuffle_dropped" and total]
    if drops:
        total = sum(t for _, t in drops)
        raise RuntimeError(
            f"repartition_by overflow: {total} records dropped "
            f"(per stage: {drops}); raise `capacity` (paper analogue: "
            "partition exceeded tmpfs capacity — fall back to a larger "
            "staging area)")
    key_ovf = [(stage_idx + stage_offset, int(total))
               for (stage_idx, kind), total in zip(specs, per)
               if kind == "key_overflow" and total]
    if key_ovf:
        total = sum(t for _, t in key_ovf)
        raise RuntimeError(
            f"reduce_by_key key-table overflow: {total} records had keys "
            f"outside [0, num_keys) (per stage: {key_ovf}); raise "
            "`num_keys` or fix `key_by`")


def execute(ds: ShardedDataset, plan: Plan, *,
            cache: Optional["planner_lib.PlanCache"] = None,
            fuse: bool = True,
            diagnostics: Optional[Dict[str, int]] = None,
            stage_offset: int = 0,
            phases: Optional[Dict[str, float]] = None) -> ShardedDataset:
    """Dispatch a plan against a dataset (no lineage/report bookkeeping —
    that is :meth:`Executor.run`; this is the bare engine under it).

    ``fuse=True`` (default): one compiled program for the entire DAG,
    counters checked once after the single dispatch.  ``fuse=False``:
    stage-at-a-time execution (each stage its own program, counters
    synced after each stage) — the pre-planner schedule, kept for
    debugging and benchmarking.  ``diagnostics``, when given, is filled
    with per-counter totals keyed ``"stage<i>.<kind>"``; ``phases``,
    when given, accumulates the per-phase wall breakdown (lower /
    compile / dispatch / device_wait / counter_sync) that
    :class:`~repro.runtime.reports.ActionReport.phases` surfaces.
    """
    if plan.empty:
        return ds
    if not fuse:
        for i, stage in enumerate(plan.stages):
            ds = execute(ds, Plan(stages=(stage,)), cache=cache, fuse=True,
                         diagnostics=diagnostics,
                         stage_offset=stage_offset + i, phases=phases)
        return ds
    prog = planner_lib.compile_plan(plan, ds, cache, phases=phases)
    # AOT split: lowering + XLA compile become their own phases/spans
    # (zero on a plan-cache hit) instead of hiding in the first dispatch
    prog.ensure_compiled(ds.records, ds.counts, phases)
    with timed("dispatch", phases, stages=len(plan.stages)):
        outs = prog(ds.records, ds.counts)
    if prog.num_counters:
        out_records, out_counts, counter_vec = outs
    else:
        out_records, out_counts = outs
    # the dispatch above returns asynchronously-executing arrays; waiting
    # here attributes device time to the action that spent it rather
    # than to whoever touches the values first (collect, counter sync)
    with timed("device_wait", phases):
        jax.block_until_ready((out_records, out_counts))
    if prog.num_counters:
        with timed("counter_sync", phases,
                   num_counters=prog.num_counters):
            check_counters(counter_vec, prog.counters, ds.num_shards,
                           diagnostics, stage_offset)
    return ShardedDataset(records=out_records, counts=out_counts,
                          mesh=ds.mesh, axis=ds.axis)


class ActionHandle:
    """Future-like handle to an asynchronously dispatched action."""

    def __init__(self, label: Optional[str] = None) -> None:
        self.label = label
        self.report: Optional[ActionReport] = None
        #: Set by Executor.submit / the dispatch worker: when the action
        #: entered the queue and when the worker dequeued it.
        self.submitted_at: Optional[float] = None
        self.started_at: Optional[float] = None
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    @property
    def queue_wait_s(self) -> float:
        """Seconds spent queued behind earlier actions (0.0 until the
        dispatch worker picks this action up)."""
        if self.submitted_at is None or self.started_at is None:
            return 0.0
        return max(0.0, self.started_at - self.submitted_at)

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        """Block for the action's value.  A ``TimeoutError`` does NOT
        poison the handle: a later ``result()`` call still succeeds once
        the action completes."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"action {self.label or ''} still pending")
        if self._error is not None:
            raise self._error
        return self._value

    # -- producer side (executor thread only) --------------------------------

    def _finish(self, value: Any = None,
                error: Optional[BaseException] = None) -> None:
        self._value = value
        self._error = error
        self._done.set()


class Executor:
    """Owns action dispatch against one pair of caches.

    ``plan_cache`` — compiled-program memoization (defaults to the
    process-wide :data:`repro.core.planner.DEFAULT_CACHE`; a per-action
    override may be passed to :meth:`run`, which MaRe uses to honor its
    ``plan_cache=`` knob).  ``mat_cache`` — the lineage-keyed
    materialization store that ``persist()`` feeds and prefix lookup
    reads.  ``max_pending`` bounds the async dispatch queue (submitting
    beyond it blocks the caller — backpressure, not unbounded buffering).
    """

    def __init__(self, plan_cache: Optional["planner_lib.PlanCache"] = None,
                 mat_cache: Optional[MaterializationCache] = None,
                 max_pending: int = 2,
                 report_history: int = 256) -> None:
        self.plan_cache = plan_cache
        self.mat_cache = mat_cache if mat_cache is not None \
            else MaterializationCache()
        self.reports = ReportLog(report_history)
        self.max_pending = max_pending
        self._run_lock = threading.RLock()
        self._queue: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self._worker: Optional[threading.Thread] = None
        self._worker_lock = threading.Lock()

    # -- lineage -------------------------------------------------------------

    def ensure_lineage(self, ds: ShardedDataset) -> Lineage:
        """Dataset's lineage root, assigning a fresh host root once for
        datasets of unknown provenance (mutates ``ds`` in place so every
        handle over the same dataset object shares the root)."""
        if ds.lineage is None:
            with _LINEAGE_LOCK:
                if ds.lineage is None:
                    ds.lineage = host_root()
        return ds.lineage

    def cached_prefix(self, ds: ShardedDataset, plan: Plan
                      ) -> Tuple[int, Optional[Lineage]]:
        """(stage count, lineage) of the longest materialized plan prefix
        — key lookup only, safe for ``describe()``."""
        if plan.empty:
            return 0, None
        return self.mat_cache.longest_prefix(self.ensure_lineage(ds), plan)

    # -- synchronous actions -------------------------------------------------

    def run(self, ds: ShardedDataset, plan: Plan, *,
            fuse: bool = True,
            plan_cache: Optional["planner_lib.PlanCache"] = None,
            reports: Optional[ReportLog] = None,
            label: Optional[str] = None,
            queue_wait_s: float = 0.0,
            tenant: Optional[str] = None
            ) -> Tuple[ShardedDataset, ActionReport]:
        """Run one action: prefix lookup, suffix dispatch, counter check,
        report.  Returns the materialized dataset (lineage = root +
        whole plan) and the action's report.  ``queue_wait_s`` is the
        async path's measured time-on-queue, recorded on the report
        (execution wall time starts here, not at submit); ``tenant``
        tags the report and the cache lookup with the serving-layer
        session that issued the action."""
        cache = plan_cache if plan_cache is not None else self.plan_cache
        cache = cache if cache is not None else planner_lib.DEFAULT_CACHE
        with self._run_lock, span("action", plan=plan.describe(),
                                  label=label) as action_span:
            t0 = time.monotonic()
            before = cache.stats()
            root = self.ensure_lineage(ds)
            result_lineage = root.extend(plan)
            counters: Dict[str, int] = {}
            phases: Dict[str, float] = {}
            cached_stages, cache_tier = 0, None
            if not plan.empty:
                with timed("cache_lookup", phases):
                    k, tier, cached = self.mat_cache.lookup_prefix(
                        root, plan, tenant=tenant)
                if cached is not None:
                    ds = cached
                    cached_stages = k
                    cache_tier = tier
                ds = execute(ds, plan.drop(cached_stages), cache=cache,
                             fuse=fuse, diagnostics=counters,
                             stage_offset=cached_stages, phases=phases)
                ds.lineage = result_lineage
            after = cache.stats()
            report = ActionReport(
                action_id=self.reports.new_id(),
                plan=plan.describe(),
                total_stages=len(plan.stages),
                cached_stages=cached_stages,
                cache_tier=cache_tier,
                lineage=ds.lineage.digest() if ds.lineage else None,
                counters=counters,
                programs_compiled=after["misses"] - before["misses"],
                program_cache_hits=after["hits"] - before["hits"],
                wall_s=time.monotonic() - t0,
                phases=phases,
                queue_wait_s=queue_wait_s,
                label=label,
                tenant=tenant)
            action_span.set(action_id=report.action_id,
                            cached_stages=cached_stages)
            METRICS.counter("executor.actions").inc()
            for phase, s in phases.items():
                METRICS.histogram(f"phase.{phase}").observe(s)
            if queue_wait_s:
                METRICS.histogram("phase.queue_wait").observe(queue_wait_s)
            self.reports.append(report)
            if reports is not None:
                reports.append(report)
            return ds, report

    def persist(self, ds: ShardedDataset, tier: str = "device",
                owner: Optional[str] = None):
        """Register a materialized dataset in the materialization cache
        under its lineage (``MaRe.persist()``'s engine half).  ``owner``
        charges the entry to that tenant's cache-budget partition."""
        self.ensure_lineage(ds)
        return self.mat_cache.put(ds, tier=tier, owner=owner)

    # -- async actions -------------------------------------------------------

    def _ensure_worker(self) -> None:
        with self._worker_lock:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(
                    target=self._drain, name="repro-runtime-executor",
                    daemon=True)
                self._worker.start()

    def _drain(self) -> None:
        while True:
            handle, fn = self._queue.get()
            METRICS.gauge("executor.queue_depth").set(self._queue.qsize())
            handle.started_at = time.monotonic()
            try:
                handle._finish(value=fn(handle))
            except BaseException as e:          # delivered via result()
                handle._finish(error=e)
            finally:
                self._queue.task_done()

    def submit(self, fn: Callable[[ActionHandle], Any],
               label: Optional[str] = None) -> ActionHandle:
        """Queue ``fn(handle)`` on the dispatch thread (FIFO, bounded:
        blocks when ``max_pending`` actions are already queued)."""
        self._ensure_worker()
        handle = ActionHandle(label=label)
        handle.submitted_at = time.monotonic()
        self._queue.put((handle, fn))
        METRICS.gauge("executor.queue_depth").set(self._queue.qsize())
        METRICS.counter("executor.submitted").inc()
        return handle

    def submit_action(self, ds: ShardedDataset, plan: Plan, *,
                      finalize: Optional[Callable[[ShardedDataset], Any]]
                      = None,
                      fuse: bool = True,
                      plan_cache: Optional["planner_lib.PlanCache"] = None,
                      reports: Optional[ReportLog] = None,
                      label: Optional[str] = None,
                      tenant: Optional[str] = None) -> ActionHandle:
        """Async :meth:`run`: dispatch the plan on the executor thread and
        (optionally) post-process the materialized dataset with
        ``finalize`` (e.g. ``dataset.collect``); the handle resolves to
        ``finalize(ds)`` (or the dataset itself).  Queue wait (submit ->
        worker dequeue) is measured separately from execution and lands
        in ``report.queue_wait_s`` — a backed-up queue no longer makes
        an action's ``wall_s`` look idle-fast."""

        def action(handle: ActionHandle) -> Any:
            out, report = self.run(ds, plan, fuse=fuse,
                                   plan_cache=plan_cache, reports=reports,
                                   label=label,
                                   queue_wait_s=handle.queue_wait_s,
                                   tenant=tenant)
            handle.report = report
            return finalize(out) if finalize is not None else out

        return self.submit(action, label=label)


#: Process-wide default engine: MaRe actions and WaveRunner waves share it
#: (and, through it, the planner's DEFAULT_CACHE), so interactive handles,
#: eager actions and out-of-core waves see one materialization cache and
#: one report history.
DEFAULT_EXECUTOR = Executor()
