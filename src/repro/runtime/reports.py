"""Structured per-action diagnostics: a history, not an overwritten dict.

Every action the executor dispatches produces one :class:`ActionReport`
— the plan that ran, how much of its prefix was served from the
materialization cache, the program's counter totals (shuffle drops,
key-table overflow, exchanged-record volume), and compile-cache deltas.
Reports accumulate in a bounded :class:`ReportLog`, so an interactive
session can inspect *every* query it ran; ``MaRe.last_diagnostics``
remains as a back-compat view over the newest report's counters.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Dict, Iterator, List, Optional


@dataclasses.dataclass
class ActionReport:
    """Diagnostics of one executed action (one plan dispatch)."""

    action_id: int
    plan: str                       # human-readable stage chain
    total_stages: int
    cached_stages: int = 0          # prefix stages served from the cache
    cache_tier: Optional[str] = None   # tier the prefix hit came from
    lineage: Optional[str] = None   # result lineage digest
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    programs_compiled: int = 0      # compile-cache misses this action
    program_cache_hits: int = 0
    wall_s: float = 0.0
    #: Per-phase wall breakdown (seconds): cache_lookup, plan.lower,
    #: plan.compile, dispatch, device_wait, counter_sync — the phases
    #: sum to ~wall_s (everything outside them is report bookkeeping).
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: Async path only: seconds spent queued behind earlier actions
    #: BEFORE the dispatch worker started this one (NOT part of wall_s,
    #: which times execution only — a backed-up queue no longer makes a
    #: slow action look fast).
    queue_wait_s: float = 0.0
    label: Optional[str] = None     # e.g. "wave 3" on the wave path
    #: Serving layer: tenant whose session issued this action (None for
    #: direct single-user executor use).
    tenant: Optional[str] = None
    #: Serving layer: number of coalesced same-plan actions this dispatch
    #: served (1 = not batched) and, on a follower's report, the
    #: action_id of the batch leader whose execution it shared.
    batch_size: int = 1
    batch_leader: Optional[int] = None

    @property
    def executed_stages(self) -> int:
        return self.total_stages - self.cached_stages

    @property
    def diagnostics(self) -> Dict[str, int]:
        """Per-stage counter totals, keyed ``"stage<i>.<kind>"`` — the
        view the deprecated ``MaRe.last_diagnostics`` dict exposed."""
        return self.counters

    def describe(self) -> str:
        hit = (f", cached_prefix={self.cached_stages}/{self.total_stages}"
               f" ({self.cache_tier})" if self.cached_stages else "")
        tag = f" [{self.label}]" if self.label else ""
        who = f" tenant={self.tenant}" if self.tenant else ""
        qw = (f", queue_wait={self.queue_wait_s * 1e3:.1f}ms"
              if self.queue_wait_s else "")
        batched = (f", batch={self.batch_size}" if self.batch_size > 1
                   else "")
        return (f"action#{self.action_id}{tag}:{who} {self.plan}{hit}, "
                f"compiled={self.programs_compiled}, "
                f"wall={self.wall_s * 1e3:.1f}ms{qw}{batched}")


class ReportLog:
    """Bounded FIFO history of :class:`ActionReport`."""

    def __init__(self, maxlen: int = 256) -> None:
        self._reports: Deque[ActionReport] = deque(maxlen=maxlen)
        self._next_id = 0
        #: Lifetime append count (NOT bounded by ``maxlen`` — use this,
        #: not ``len()``, to count actions over a long run).
        self.appended = 0

    def new_id(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    def append(self, report: ActionReport) -> None:
        self._reports.append(report)
        self.appended += 1

    @property
    def latest(self) -> Optional[ActionReport]:
        return self._reports[-1] if self._reports else None

    def __len__(self) -> int:
        return len(self._reports)

    def __iter__(self) -> Iterator[ActionReport]:
        return iter(self._reports)

    def __getitem__(self, i) -> ActionReport:
        return list(self._reports)[i]

    def total(self, counter: str) -> int:
        """Sum of one counter kind across all retained reports (suffix
        matching: ``total("exchanged_records")`` sums every stage)."""
        acc = 0
        for r in self._reports:
            for key, v in r.counters.items():
                if key == counter or key.endswith("." + counter):
                    acc += v
        return acc

    def phase_totals(self) -> Dict[str, float]:
        """Summed per-phase seconds across all retained reports."""
        acc: Dict[str, float] = {}
        for r in self._reports:
            for phase, s in r.phases.items():
                acc[phase] = acc.get(phase, 0.0) + s
        return acc

    def summary(self) -> str:
        """Session-level aggregate: action/compile/cache totals plus a
        phase-breakdown table over the retained history (the interactive
        "where did my time go" view)."""
        reports = list(self._reports)
        if not reports:
            return "ReportLog: no actions recorded"
        wall = sum(r.wall_s for r in reports)
        queued = sum(r.queue_wait_s for r in reports)
        compiled = sum(r.programs_compiled for r in reports)
        cached = sum(r.cached_stages for r in reports)
        stages = sum(r.total_stages for r in reports)
        lines = [
            f"ReportLog: {len(reports)} retained / {self.appended} total "
            f"actions, wall={wall:.3f}s"
            + (f", queue_wait={queued:.3f}s" if queued else ""),
            f"  stages: {stages} planned, {cached} served from cache; "
            f"programs compiled: {compiled}",
        ]
        totals = self.phase_totals()
        if totals:
            lines.append(f"  {'phase':<16} {'total':>10} {'mean':>10} "
                         f"{'share':>7}")
            for phase, s in sorted(totals.items(), key=lambda kv: -kv[1]):
                lines.append(
                    f"  {phase:<16} {s:>9.3f}s "
                    f"{s / len(reports) * 1e3:>8.2f}ms "
                    f"{s / wall * 100 if wall else 0:>6.1f}%")
        return "\n".join(lines)


class ReportStream(ReportLog):
    """A :class:`ReportLog` that consumers can *wait on* — the per-tenant
    report channel of the serving layer.

    Producers (the service's dispatch path) ``append`` from worker
    threads; a session-side consumer blocks in :meth:`wait_for` /
    :meth:`next_after` for reports it has not seen yet, turning the log
    into a live stream without polling.  All ReportLog accessors remain
    available (and are made thread-safe here).
    """

    def __init__(self, maxlen: int = 256) -> None:
        super().__init__(maxlen)
        self._cond = threading.Condition()

    def new_id(self) -> int:
        with self._cond:
            return super().new_id()

    def append(self, report: ActionReport) -> None:
        with self._cond:
            super().append(report)
            self._cond.notify_all()

    def wait_for(self, appended: int, timeout: Optional[float] = None
                 ) -> bool:
        """Block until the stream's lifetime append count reaches
        ``appended`` (False on timeout)."""
        with self._cond:
            return self._cond.wait_for(lambda: self.appended >= appended,
                                       timeout)

    def next_after(self, seen: int, timeout: Optional[float] = None
                   ) -> List[ActionReport]:
        """Reports appended after the first ``seen`` (blocking until at
        least one arrives, or ``[]`` on timeout).  Consumer-side cursor
        pattern: ``seen += len(batch)`` after each call.  Reports that
        aged out of the bounded history before being read are skipped."""
        with self._cond:
            if not self._cond.wait_for(lambda: self.appended > seen,
                                       timeout):
                return []
            missed = self.appended - seen
            return list(self._reports)[-min(missed, len(self._reports)):]
