"""Structured per-action diagnostics: a history, not an overwritten dict.

Every action the executor dispatches produces one :class:`ActionReport`
— the plan that ran, how much of its prefix was served from the
materialization cache, the program's counter totals (shuffle drops,
key-table overflow, exchanged-record volume), and compile-cache deltas.
Reports accumulate in a bounded :class:`ReportLog`, so an interactive
session can inspect *every* query it ran; ``MaRe.last_diagnostics``
remains as a back-compat view over the newest report's counters.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterator, Optional


@dataclasses.dataclass
class ActionReport:
    """Diagnostics of one executed action (one plan dispatch)."""

    action_id: int
    plan: str                       # human-readable stage chain
    total_stages: int
    cached_stages: int = 0          # prefix stages served from the cache
    cache_tier: Optional[str] = None   # tier the prefix hit came from
    lineage: Optional[str] = None   # result lineage digest
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    programs_compiled: int = 0      # compile-cache misses this action
    program_cache_hits: int = 0
    wall_s: float = 0.0
    #: Per-phase wall breakdown (seconds): cache_lookup, plan.lower,
    #: plan.compile, dispatch, device_wait, counter_sync — the phases
    #: sum to ~wall_s (everything outside them is report bookkeeping).
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: Async path only: seconds spent queued behind earlier actions
    #: BEFORE the dispatch worker started this one (NOT part of wall_s,
    #: which times execution only — a backed-up queue no longer makes a
    #: slow action look fast).
    queue_wait_s: float = 0.0
    label: Optional[str] = None     # e.g. "wave 3" on the wave path

    @property
    def executed_stages(self) -> int:
        return self.total_stages - self.cached_stages

    def describe(self) -> str:
        hit = (f", cached_prefix={self.cached_stages}/{self.total_stages}"
               f" ({self.cache_tier})" if self.cached_stages else "")
        tag = f" [{self.label}]" if self.label else ""
        qw = (f", queue_wait={self.queue_wait_s * 1e3:.1f}ms"
              if self.queue_wait_s else "")
        return (f"action#{self.action_id}{tag}: {self.plan}{hit}, "
                f"compiled={self.programs_compiled}, "
                f"wall={self.wall_s * 1e3:.1f}ms{qw}")


class ReportLog:
    """Bounded FIFO history of :class:`ActionReport`."""

    def __init__(self, maxlen: int = 256) -> None:
        self._reports: Deque[ActionReport] = deque(maxlen=maxlen)
        self._next_id = 0
        #: Lifetime append count (NOT bounded by ``maxlen`` — use this,
        #: not ``len()``, to count actions over a long run).
        self.appended = 0

    def new_id(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    def append(self, report: ActionReport) -> None:
        self._reports.append(report)
        self.appended += 1

    @property
    def latest(self) -> Optional[ActionReport]:
        return self._reports[-1] if self._reports else None

    def __len__(self) -> int:
        return len(self._reports)

    def __iter__(self) -> Iterator[ActionReport]:
        return iter(self._reports)

    def __getitem__(self, i) -> ActionReport:
        return list(self._reports)[i]

    def total(self, counter: str) -> int:
        """Sum of one counter kind across all retained reports (suffix
        matching: ``total("exchanged_records")`` sums every stage)."""
        acc = 0
        for r in self._reports:
            for key, v in r.counters.items():
                if key == counter or key.endswith("." + counter):
                    acc += v
        return acc

    def phase_totals(self) -> Dict[str, float]:
        """Summed per-phase seconds across all retained reports."""
        acc: Dict[str, float] = {}
        for r in self._reports:
            for phase, s in r.phases.items():
                acc[phase] = acc.get(phase, 0.0) + s
        return acc

    def summary(self) -> str:
        """Session-level aggregate: action/compile/cache totals plus a
        phase-breakdown table over the retained history (the interactive
        "where did my time go" view)."""
        reports = list(self._reports)
        if not reports:
            return "ReportLog: no actions recorded"
        wall = sum(r.wall_s for r in reports)
        queued = sum(r.queue_wait_s for r in reports)
        compiled = sum(r.programs_compiled for r in reports)
        cached = sum(r.cached_stages for r in reports)
        stages = sum(r.total_stages for r in reports)
        lines = [
            f"ReportLog: {len(reports)} retained / {self.appended} total "
            f"actions, wall={wall:.3f}s"
            + (f", queue_wait={queued:.3f}s" if queued else ""),
            f"  stages: {stages} planned, {cached} served from cache; "
            f"programs compiled: {compiled}",
        ]
        totals = self.phase_totals()
        if totals:
            lines.append(f"  {'phase':<16} {'total':>10} {'mean':>10} "
                         f"{'share':>7}")
            for phase, s in sorted(totals.items(), key=lambda kv: -kv[1]):
                lines.append(
                    f"  {phase:<16} {s:>9.3f}s "
                    f"{s / len(reports) * 1e3:>8.2f}ms "
                    f"{s / wall * 100 if wall else 0:>6.1f}%")
        return "\n".join(lines)
