"""repro.core — the paper's contribution: MaRe on TPU.

MapReduce-oriented primitives (map / reduce / repartition_by) over
mesh-sharded datasets, with ContainerOps (named, registered, self-contained
transformations) standing in for Docker images.  See DESIGN.md.
"""
from repro.core.container import (ContainerOp, Partition, Registry,
                                  DEFAULT_REGISTRY, container_op,
                                  make_partition, pull, register)
from repro.core.dataset import (ShardedDataset, collect,
                                collect_first_shard, from_host)
from repro.core.manifests import (ArgSpec, CommandSpec, Contract,
                                  ImageManifest, PRESERVE, PlanTypeError,
                                  SAME)
from repro.core.mare import MaRe
from repro.core.mounts import (BinaryFiles, FileSetMount, Mount, RecordMount,
                               TextFile)
from repro.core.plan import (KEYED_MONOIDS, KeyedReduceStage, MapStage, Plan,
                             ReduceStage, ShuffleStage, StageState,
                             infer_states)
from repro.core.schema import (Field, Schema, SchemaMismatch,
                               bytes_record_schema, field, schema_of_records)
from repro.core.planner import (DEFAULT_CACHE, PlanCache, compile_plan,
                                program_key)
from repro.core.shuffle import (ShuffleResult, grouped_all_to_all, hash_keys,
                                keyed_bucket_capacity, shuffle_partition)
from repro.core.tree_reduce import (broadcast_from_zero, fused_allreduce,
                                    hierarchical_allreduce,
                                    keyed_combine_partition,
                                    keyed_merge_partition,
                                    segment_table_to_partition,
                                    split_factors, tree_allreduce,
                                    tree_reduce_partition)
from repro.core import images as _images  # registers standard images


def __getattr__(name):
    # execution moved to the runtime layer (PR 5); `execute` stays
    # importable from repro.core for back-compat, resolved lazily so
    # neither package requires the other at module-import time
    if name == "execute":
        from repro.runtime.executor import execute
        return execute
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "MaRe", "ContainerOp", "Partition", "Registry", "DEFAULT_REGISTRY",
    "container_op", "make_partition", "pull", "register",
    "ShardedDataset", "collect", "collect_first_shard", "from_host",
    "Mount", "RecordMount", "FileSetMount", "TextFile", "BinaryFiles",
    "Plan", "MapStage", "ShuffleStage", "ReduceStage", "KeyedReduceStage",
    "KEYED_MONOIDS", "StageState", "infer_states",
    "ImageManifest", "CommandSpec", "ArgSpec", "Contract", "PlanTypeError",
    "PRESERVE", "SAME",
    "Field", "Schema", "SchemaMismatch", "bytes_record_schema", "field",
    "schema_of_records",
    "PlanCache", "DEFAULT_CACHE", "compile_plan", "execute", "program_key",
    "ShuffleResult", "grouped_all_to_all", "hash_keys", "shuffle_partition",
    "keyed_bucket_capacity",
    "broadcast_from_zero", "fused_allreduce", "hierarchical_allreduce",
    "split_factors", "tree_allreduce", "tree_reduce_partition",
    "keyed_combine_partition", "keyed_merge_partition",
    "segment_table_to_partition",
]
