"""K-level tree reduction over mesh axes — MaRe's ``reduce`` primitive.

Paper semantics (§1.2.2, Fig. 2): given a user depth K (default 2), records
are aggregated level by level: within-partition combine (mapPartitions),
then ``repartition`` to fewer partitions — K shuffles total — until a single
partition remains.  The combiner must be associative + commutative.

TPU mapping: partitions are shards along a mesh axis of size ``n``.  The
axis size is factored into K near-equal group sizes ``[g_1..g_K]``; at level
``i`` every group of ``g_i`` shards ships its partition to the group leader
with ``g_i - 1`` strided ``ppermute`` sends (the explicit "shuffle"), and the
leader runs the combiner over the concatenated records.  After K levels the
result lives on shard 0 and is tree-broadcast back (log-doubling) so the
returned array is replicated — the analogue of the paper's single-partition
RDD'.

This schedule is intentionally *paper-faithful*: it materializes each level
like Spark's repartition does.  The beyond-paper fused path (psum /
reduce-scatter+all-gather, overlap-friendly) lives in
:func:`fused_allreduce` and is compared against the tree in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.container import Partition, make_partition


def split_factors(n: int, depth: int) -> List[int]:
    """Factor ``n`` into ``depth`` integer factors, each as near n^(1/K) as
    possible (paper: "the records in the RDD are aggregated using a
    tree-like algorithm ... K levels").  Excess depth yields trailing 1s.
    """
    if n <= 0:
        raise ValueError(f"axis size must be positive, got {n}")
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    factors: List[int] = []
    remaining = n
    for level in range(depth, 0, -1):
        if remaining == 1:
            factors.append(1)
            continue
        target = round(remaining ** (1.0 / level))
        target = max(2, target)
        # find a divisor of `remaining` closest to target
        divs = [d for d in range(1, remaining + 1) if remaining % d == 0]
        g = min((d for d in divs if d > 1),
                key=lambda d: (abs(d - target), d)) if remaining > 1 else 1
        factors.append(g)
        remaining //= g
    if remaining != 1:
        factors[-1] *= remaining
    assert _prod(factors) == n, (factors, n)
    return factors


def _prod(xs: Sequence[int]) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def _level_pairs(axis_size: int, stride: int, group: int, j: int):
    """ppermute pairs sending member ``j`` of each group to its leader."""
    leaders = range(0, axis_size, stride * group)
    return [(l + j * stride, l) for l in leaders if l + j * stride < axis_size]


def broadcast_from_zero(x: Any, axis_name: str, axis_size: int) -> Any:
    """Replicate shard 0's value to all shards via log-doubling ppermute."""
    k = 1
    while k < axis_size:
        pairs = [(s, s + k) for s in range(min(k, axis_size - k))]

        def send(leaf):
            return jax.lax.ppermute(leaf, axis_name, pairs)

        received = jax.tree.map(send, x)
        idx = jax.lax.axis_index(axis_name)
        in_wave = (idx >= k) & (idx < 2 * k)

        def sel(r, cur):
            return jnp.where(in_wave, r, cur)

        x = jax.tree.map(sel, received, x)
        k *= 2
    return x


# ---------------------------------------------------------------------------
# Record-level tree reduce (the MaRe.reduce primitive, shard_map interior)
# ---------------------------------------------------------------------------

def _fit_capacity(part: Partition, out_cap: int) -> Partition:
    """Pad or truncate a (front-compacted) partition to a fixed capacity."""
    cap = part.capacity
    if cap == out_cap:
        return part
    if cap < out_cap:
        rec = jax.tree.map(
            lambda l: jnp.concatenate(
                [l, jnp.zeros((out_cap - cap,) + l.shape[1:], l.dtype)],
                axis=0), part.records)
    else:
        rec = jax.tree.map(lambda l: l[:out_cap], part.records)
    return Partition(records=rec,
                     count=jnp.minimum(part.count, out_cap))


def tree_reduce_partition(
    part: Partition,
    combine: Callable[[Partition], Partition],
    axis_name: str,
    axis_size: int,
    depth: int = 2,
    broadcast_result: bool = True,
    out_capacity: Optional[int] = None,
) -> Partition:
    """Run MaRe's K-level reduce over partitions sharded on ``axis_name``.

    ``combine`` maps a partition of up-to ``g * out_cap`` records to one of
    ``out_cap`` records (it must be mask-aware: ignore records beyond
    ``count``).  Must be associative + commutative (paper requirement).

    ``out_capacity`` fixes the per-level record capacity.  Size-reducing
    combiners (sum, top-k) infer it from the local pre-combine; identity /
    concatenating combiners (the paper's vcf-concat) need
    ``out_capacity = axis_size * input_capacity`` so the single surviving
    partition can hold every record — MaRe.reduce infers this.
    """
    factors = split_factors(axis_size, depth)
    in_cap = part.capacity
    # Level 0: local pre-combine (paper: mapPartitions before first shuffle).
    part = combine(part)
    if out_capacity is None and part.capacity >= in_cap:
        out_capacity = axis_size * in_cap        # concat-like combiner
    out_cap = out_capacity or part.capacity
    part = _fit_capacity(part, out_cap)
    stride = 1
    for g in factors:
        if g == 1:
            stride *= g
            continue
        rec_parts = [part.records]
        counts = [part.count]
        for j in range(1, g):
            pairs = _level_pairs(axis_size, stride, g, j)
            rec_parts.append(jax.tree.map(
                lambda leaf: jax.lax.ppermute(leaf, axis_name, pairs),
                part.records))
            counts.append(jax.lax.ppermute(part.count, axis_name, pairs))
        gathered = jax.tree.map(
            lambda *leaves: jnp.concatenate(leaves, axis=0), *rec_parts)
        # Non-leaders received zeros; their counts are zero so the combiner's
        # mask discards the garbage.  Re-stack counts into a validity layout:
        # records of member j occupy [j*out_cap, j*out_cap + count_j).
        total = jnp.zeros((), jnp.int32)
        mask = jnp.zeros((g * out_cap,), bool)
        pos = jnp.arange(out_cap)
        for j, c in enumerate(counts):
            seg = (pos < c)
            mask = mask.at[j * out_cap:(j + 1) * out_cap].set(seg)
            total = total + c
        # Compact valid records to the front so `count` semantics hold.
        order = jnp.argsort(~mask, stable=True)
        gathered = jax.tree.map(lambda leaf: jnp.take(leaf, order, axis=0, mode="clip"),
                                gathered)
        combined = _fit_capacity(combine(make_partition(gathered, total)),
                                 out_cap)
        idx = jax.lax.axis_index(axis_name)
        is_leader = (idx % (stride * g)) == 0

        def sel(new, old):
            # scalar predicate broadcasts over any record shape
            return jnp.where(is_leader, new, old)

        part = Partition(
            records=jax.tree.map(sel, combined.records, part.records),
            count=jnp.where(is_leader, combined.count, part.count))
        stride *= g
    if broadcast_result:
        part = Partition(
            records=broadcast_from_zero(part.records, axis_name, axis_size),
            count=broadcast_from_zero(part.count, axis_name, axis_size))
    return part


# ---------------------------------------------------------------------------
# Keyed aggregation (the MaRe.reduce_by_key primitive, shard_map interior)
# ---------------------------------------------------------------------------

def segment_table_to_partition(tables: Any, counts: jax.Array,
                               num_keys: int) -> Partition:
    """Compact a direct-indexed key table into partition records.

    Present keys (``counts > 0``) move to the front; output records are the
    3-tuple ``(keys int32, values pytree, counts int32)`` with
    ``count = #present`` — the record layout keyed stages exchange and
    ultimately return to the user.
    """
    present = counts > 0
    order = jnp.argsort(~present, stable=True)   # present keys first
    keys = order.astype(jnp.int32)               # table index IS the key
    vals = jax.tree.map(
        lambda t: jnp.take(t, order, axis=0, mode="clip"), tables)
    cnts = jnp.take(counts, order, mode="clip")
    return make_partition((keys, vals, cnts),
                          jnp.sum(present).astype(jnp.int32))


def keyed_combine_partition(keys: jax.Array, values: Any,
                            valid: jax.Array, num_keys: int,
                            op: str = "sum",
                            use_kernel: Optional[bool] = None):
    """Map-side combiner: locally fold (key, value) records into at most
    ``num_keys`` partial-aggregate records.  Returns ``(partition,
    overflow)`` where overflow counts valid records whose key fell outside
    ``[0, num_keys)`` (surfaced at action time, never silently dropped)."""
    from repro.kernels.segment_reduce.ops import segment_reduce
    res = segment_reduce(keys, values, num_keys, op=op, valid=valid,
                         use_kernel=use_kernel)
    return (segment_table_to_partition(res.values, res.counts, num_keys),
            res.overflow)


def keyed_merge_partition(part: Partition, num_keys: int,
                          op: str = "sum",
                          use_kernel: Optional[bool] = None):
    """Post-shuffle merge: fold received ``(keys, values, counts)`` partial
    aggregates into final per-key records on the owning shard.  Per-key
    record counts always merge with ``sum`` (they count source records, not
    values).  For the sum monoid the counts ride the same segment-reduce
    call as the values (one fused scatter / kernel launch instead of two);
    max/min need a second sum-reduce for the counts.  Returns
    ``(partition, overflow)``."""
    from repro.kernels.segment_reduce.ops import segment_reduce
    rkeys, rvalues, rcounts = part.records
    mask = part.mask()
    if op == "sum":
        leaves, treedef = jax.tree.flatten(rvalues)
        merged = segment_reduce(rkeys, tuple(leaves) + (rcounts,), num_keys,
                                op="sum", valid=mask, use_kernel=use_kernel)
        vals = jax.tree.unflatten(treedef, list(merged.values[:-1]))
        out = segment_table_to_partition(vals, merged.values[-1], num_keys)
        return out, merged.overflow
    merged = segment_reduce(rkeys, rvalues, num_keys, op=op, valid=mask,
                            use_kernel=use_kernel)
    counts = segment_reduce(rkeys, (rcounts,), num_keys, op="sum",
                            valid=mask, use_kernel=False)
    out = segment_table_to_partition(merged.values, counts.values[0],
                                     num_keys)
    return out, merged.overflow


def merge_keyed_tables(state: Partition, delta: Partition, num_keys: int,
                       op: str = "sum",
                       use_kernel: Optional[bool] = None) -> Partition:
    """Fold two keyed-result partitions of the SAME shard into one.

    Both inputs are ``(keys, values, counts)`` record partitions as
    produced by a ``reduce_by_key`` merge — front-compacted, capacity
    ``num_keys``, keys already hashed to this shard.  This is the
    incremental-maintenance primitive (repro.stream): a persisted
    aggregate and a new epoch's delta are partitioned identically (the
    owner shard of a key is ``hash(key) % axis_size`` either way), so the
    fold is shard-local — no exchange, one segment-reduce over the
    concatenated rows.

    Unlike :func:`keyed_merge_partition` this cannot rely on
    ``Partition.mask()``: the concatenation of two front-compacted tables
    is NOT front-compacted, so validity is rebuilt per half.  Per-key
    record counts always fold with ``sum`` (they count source records);
    for the sum monoid they ride the same segment-reduce call.  The
    output is front-compacted in ascending key order — bit-identical to
    what a one-shot ``reduce_by_key`` over the union of inputs produces
    on this shard (for int values; float sums reassociate).
    """
    from repro.kernels.segment_reduce.ops import segment_reduce
    skeys, svalues, scounts = state.records
    dkeys, dvalues, dcounts = delta.records
    keys = jnp.concatenate([skeys, dkeys])
    pos = jnp.arange(num_keys)
    valid = jnp.concatenate([pos < state.count, pos < delta.count])
    cat = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                       (svalues, scounts), (dvalues, dcounts))
    values, counts = cat
    if op == "sum":
        leaves, treedef = jax.tree.flatten(values)
        merged = segment_reduce(keys, tuple(leaves) + (counts,), num_keys,
                                op="sum", valid=valid, use_kernel=use_kernel)
        vals = jax.tree.unflatten(treedef, list(merged.values[:-1]))
        return segment_table_to_partition(vals, merged.values[-1], num_keys)
    merged = segment_reduce(keys, values, num_keys, op=op, valid=valid,
                            use_kernel=use_kernel)
    cnt = segment_reduce(keys, (counts,), num_keys, op="sum", valid=valid,
                         use_kernel=False)
    return segment_table_to_partition(merged.values, cnt.values[0], num_keys)


# ---------------------------------------------------------------------------
# Dense-gradient tree all-reduce (the trainer's paper-faithful grad sync)
# ---------------------------------------------------------------------------

def tree_allreduce(
    x: Any,
    axis_name: str,
    axis_size: int,
    depth: int = 2,
    factors: Optional[Sequence[int]] = None,
) -> Any:
    """Paper-faithful K-level tree all-reduce of a pytree of arrays.

    Each level ships whole partials to group leaders (g-1 strided ppermute
    sends) and sums; the total is tree-broadcast from shard 0.  Used as the
    MaRe-style gradient synchronizer (grad_sync="mare_tree").
    """
    if axis_size == 1:
        return x
    factors = list(factors) if factors is not None else split_factors(
        axis_size, depth)
    stride = 1
    for g in factors:
        if g == 1:
            stride *= g
            continue
        acc = x
        for j in range(1, g):
            pairs = _level_pairs(axis_size, stride, g, j)
            recv = jax.tree.map(
                lambda leaf: jax.lax.ppermute(leaf, axis_name, pairs), x)
            acc = jax.tree.map(jnp.add, acc, recv)
        x = acc  # valid at leaders; non-leaders carry garbage but never send
        stride *= g
    return broadcast_from_zero(x, axis_name, axis_size)


def fused_allreduce(x: Any, axis_name: str) -> Any:
    """Beyond-paper path: let XLA emit a fused (ring/tree) all-reduce."""
    return jax.tree.map(partial(jax.lax.psum, axis_name=axis_name), x)


def hierarchical_allreduce(x: Any, inner_axis: str, outer_axis: str) -> Any:
    """Two-level tree across mesh axes: intra-pod then inter-pod psum.

    This is the paper's K=2 tree expressed at mesh granularity — the natural
    schedule on a (pod, data, ...) mesh: reduce over fast ICI first, then
    over the slower pod interconnect (DESIGN.md §3.1).
    """
    x = jax.tree.map(partial(jax.lax.psum, axis_name=inner_axis), x)
    return jax.tree.map(partial(jax.lax.psum, axis_name=outer_axis), x)


def collective_bytes_tree(nbytes: int, axis_size: int, depth: int = 2) -> int:
    """Napkin-math helper: bytes moved per shard-link by the K-level tree
    (used by benchmarks/reduce_depth.py and EXPERIMENTS §Perf)."""
    factors = split_factors(axis_size, depth)
    total = 0
    shards = axis_size
    for g in factors:
        senders = shards - shards // g
        total += senders * nbytes
        shards //= g
    # log-doubling broadcast
    k = 1
    while k < axis_size:
        total += min(k, axis_size - k) * nbytes
        k *= 2
    return total
