"""Record schemas: dtype + per-record shape pytrees (the typed half of a
container's mount contract).

A :class:`Schema` describes the records of one partition *without* the
capacity dimension: a pytree mirroring the record pytree whose leaves are
:class:`Field` (dtype + per-record shape).  Dimensions may be symbolic
(``"W"``) so an image can declare a contract over any record width and a
capacity transfer function can reference the width that actually arrives
(``kmer-stats``: ``out_capacity = cap * (W - k + 1)``).

Schemas unify the three places this repo states record contracts:

* mount points (``RecordMount``/``FileSetMount`` — user-site assertions),
* image manifests (``ImageManifest.input_schema``/``output_schema`` —
  tool-side declarations, checked at plan-build time), and
* ``repro.io`` formats (``RecordFormat.schema`` — what ``pack_records``
  produces: :func:`bytes_record_schema`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
import numpy as np

Dim = Union[int, str]   # int = concrete extent, str = symbolic dimension


class SchemaMismatch(TypeError):
    """A concrete record layout violates a declared schema."""


@dataclasses.dataclass(frozen=True)
class Field:
    """One record leaf: dtype (``None`` = any) + per-record shape.

    ``shape`` excludes the leading capacity dimension; entries are ints or
    symbolic dimension names that bind on first match.
    """

    dtype: Optional[str] = None
    shape: Tuple[Dim, ...] = ()

    def describe(self) -> str:
        base = _SHORT_DTYPES.get(self.dtype, self.dtype) if self.dtype \
            else "*"
        if not self.shape:
            return base
        return base + "[" + ",".join(str(d) for d in self.shape) + "]"


_SHORT_DTYPES = {
    "uint8": "u8", "uint16": "u16", "uint32": "u32", "uint64": "u64",
    "int8": "i8", "int16": "i16", "int32": "i32", "int64": "i64",
    "float16": "f16", "bfloat16": "bf16", "float32": "f32",
    "float64": "f64", "bool": "bool",
}


def field(dtype: Any = None, shape: Tuple[Dim, ...] = ()) -> Field:
    """Build a :class:`Field`, normalizing ``dtype`` to a numpy name."""
    name = None if dtype is None else np.dtype(dtype).name
    return Field(dtype=name, shape=tuple(shape))


@dataclasses.dataclass(frozen=True)
class Schema:
    """A pytree of :class:`Field` mirroring a record pytree's structure."""

    fields: Any

    @property
    def concrete(self) -> bool:
        """True when every dim is an int and every dtype is declared."""
        return all(f.dtype is not None
                   and all(isinstance(d, int) for d in f.shape)
                   for f in jax.tree.leaves(self.fields))

    def structs(self, capacity: int) -> Any:
        """``ShapeDtypeStruct`` pytree with a leading ``capacity`` dim
        (for :func:`jax.eval_shape` of keyBy/value selectors at plan time);
        requires a concrete schema."""
        if not self.concrete:
            raise ValueError(f"schema {self.describe()} is not concrete")
        return jax.tree.map(
            lambda f: jax.ShapeDtypeStruct((capacity,) + tuple(f.shape),
                                           np.dtype(f.dtype)),
            self.fields)

    def describe(self) -> str:
        return _describe(self.fields)


def _describe(node: Any) -> str:
    if isinstance(node, Field):
        return node.describe()
    if isinstance(node, dict):
        inner = ", ".join(f"{k}: {_describe(v)}"
                          for k, v in sorted(node.items()))
        return "{" + inner + "}"
    if isinstance(node, (tuple, list)):
        return "(" + ", ".join(_describe(v) for v in node) + ")"
    return repr(node)


def schema_of_records(records: Any) -> Schema:
    """Concrete schema of actual record arrays (leading dim dropped)."""
    return Schema(jax.tree.map(
        lambda l: Field(np.dtype(l.dtype).name,
                        tuple(int(d) for d in l.shape[1:])),
        records))


def bytes_record_schema(width: Dim = "W") -> Schema:
    """The packed byte-record contract shared by ``repro.io`` formats and
    the byte-oriented images: ``{"data": u8[width], "len": i32}``."""
    return Schema({"data": Field("uint8", (width,)),
                   "len": Field("int32", ())})


def _leaf_paths(fields: Any):
    leaves, _ = jax.tree_util.tree_flatten_with_path(fields)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]


def unify(declared: Schema, actual: Schema,
          env: Optional[Dict[str, int]] = None) -> Dict[str, int]:
    """Match a concrete ``actual`` schema against a ``declared`` one.

    Returns ``env`` extended with bindings for the declared schema's
    symbolic dims; raises :class:`SchemaMismatch` (structure, dtype or
    shape) with the offending leaf path in the message.

    Structure must match exactly, with one leniency: a SINGLE-leaf
    declared schema accepts any single-leaf actual pytree regardless of
    the container — images that read "the one record array" via
    ``jax.tree.leaves`` work identically over ``(x,)``, a bare array, or
    ``{"x": ...}``, and their contracts say so.
    """
    env = dict(env) if env else {}
    d_paths = _leaf_paths(declared.fields)
    a_paths = _leaf_paths(actual.fields)
    d_struct = jax.tree.structure(declared.fields)
    a_struct = jax.tree.structure(actual.fields)
    if d_struct != a_struct and not (len(d_paths) == 1
                                     and len(a_paths) == 1):
        raise SchemaMismatch(
            f"record structure mismatch: declared {declared.describe()} "
            f"vs actual {actual.describe()}")
    for (path, d), (_, a) in zip(d_paths, a_paths):
        where = f"field {path or '<root>'}"
        if d.dtype is not None and a.dtype is not None and d.dtype != a.dtype:
            raise SchemaMismatch(
                f"{where}: dtype {a.dtype} != declared {d.dtype}")
        if len(d.shape) != len(a.shape):
            raise SchemaMismatch(
                f"{where}: record rank {len(a.shape)} != declared "
                f"{len(d.shape)} ({d.describe()})")
        for dim_d, dim_a in zip(d.shape, a.shape):
            if isinstance(dim_d, str):
                bound = env.get(dim_d)
                if bound is None:
                    env[dim_d] = dim_a
                elif bound != dim_a:
                    raise SchemaMismatch(
                        f"{where}: dim {dim_d}={dim_a} conflicts with "
                        f"earlier binding {dim_d}={bound}")
            elif dim_d != dim_a:
                raise SchemaMismatch(
                    f"{where}: record shape dim {dim_a} != declared "
                    f"{dim_d}")
    return env


def substitute(schema: Schema, env: Dict[str, int]) -> Schema:
    """Replace bound symbolic dims with their concrete extents."""

    def sub(f: Field) -> Field:
        return Field(f.dtype, tuple(env.get(d, d) if isinstance(d, str)
                                    else d for d in f.shape))

    return Schema(jax.tree.map(sub, schema.fields))
