"""Lazy execution plan — the Spark-DAG/stage analogue.

MaRe inherits Spark's lazy evaluation: chained ``map`` calls generate a
single stage (one ``mapPartitions`` chain, no shuffle); ``reduce`` and
``repartitionBy`` are stage boundaries.  Here a :class:`Plan` accumulates
ContainerOps; :func:`execute_map_stage` fuses the pending map chain into a
single ``shard_map`` + ``jit`` computation — one XLA module, zero
collectives, locality preserved by construction (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.container import ContainerOp, Partition, make_partition
from repro.core.dataset import ShardedDataset


@dataclasses.dataclass
class Plan:
    """A pending chain of fused map ops (one stage)."""

    ops: Tuple[ContainerOp, ...] = ()

    def then(self, op: ContainerOp) -> "Plan":
        return Plan(ops=self.ops + (op,))

    @property
    def empty(self) -> bool:
        return not self.ops

    def describe(self) -> str:
        return " | ".join(op.name for op in self.ops) or "<identity>"


def _apply_chain(ops: Tuple[ContainerOp, ...], records: Any,
                 count: jax.Array) -> Partition:
    part = make_partition(records, count)
    for op in ops:
        if op.input_mount is not None:
            op.input_mount.validate(part.records)
        part = op(part)
        if op.output_mount is not None:
            op.output_mount.validate(part.records)
    return part


def execute_map_stage(ds: ShardedDataset, plan: Plan) -> ShardedDataset:
    """Fuse and run the pending map chain as one shard_map stage."""
    if plan.empty:
        return ds
    mesh, axis = ds.mesh, ds.axis

    def stage(records, counts):
        part = _apply_chain(plan.ops, records, counts[0])
        return part.records, part.count[None]

    fn = jax.jit(compat.shard_map(
        stage, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis))))
    out_records, out_counts = fn(ds.records, ds.counts)
    return ds.with_records(out_records, out_counts)


def stage_fn_for_specs(plan: Plan):
    """Return the raw shard-interior function (for dry-run lowering)."""
    def stage(records, counts):
        part = _apply_chain(plan.ops, records, counts[0])
        return part.records, part.count[None]
    return stage
