"""Lazy execution plan — the Spark-DAG/stage analogue, now a stage DAG.

MaRe inherits Spark's lazy evaluation: chained ``map`` calls generate a
single stage (one ``mapPartitions`` chain, no shuffle); ``reduce`` and
``repartitionBy`` are stage *boundaries* — but not execution boundaries.
A :class:`Plan` accumulates a linear DAG of :class:`MapStage` /
:class:`ShuffleStage` / :class:`ReduceStage` nodes; nothing runs until an
action.  :mod:`repro.core.planner` lowers the whole DAG into a **single**
``shard_map`` + ``jit`` program — map ops fused into their downstream
shuffle/reduce, one XLA module per pipeline shape, locality preserved by
construction (DESIGN.md §2) — and memoizes compiled programs so
interactive re-execution (paper Fig. 6) pays zero re-trace.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Hashable, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.container import ContainerOp, Partition, make_partition
from repro.core.manifests import PlanTypeError
from repro.core.schema import Field, Schema, SchemaMismatch
from repro.obs import span


class _IdKey:
    """Identity-based hashable wrapper for unhashable op params.

    Param values are baked into the traced program, so two pipelines may
    only share a compiled program when their params hold the same value —
    a repr() fallback could collide (e.g. numpy's truncated repr of large
    arrays) and silently reuse a program compiled with different
    constants.  Holding a strong reference keeps ``id`` from being
    recycled for as long as the cache key lives.  CAVEAT: identity keying
    means in-place mutation of the param object goes unseen (the cached
    program keeps the old baked-in value) — numpy arrays are therefore
    keyed by content digest in :func:`_freeze`; anything that falls
    through to ``_IdKey`` must be treated as immutable, matching
    ``jax.jit``'s own semantics for closed-over constants.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any) -> None:
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, _IdKey) and other.obj is self.obj

    def __repr__(self) -> str:
        return f"_IdKey({type(self.obj).__name__}@{id(self.obj):#x})"


def _freeze(value: Any) -> Hashable:
    """Hashable view of an op parameter.

    Hashable values key on themselves; numpy arrays key on a content
    digest (so in-place mutation correctly misses the cache); any other
    unhashable value keys on object identity and must not be mutated.
    """
    try:
        hash(value)
        return value
    except TypeError:
        pass
    if isinstance(value, np.ndarray):
        arr = np.ascontiguousarray(value)
        digest = hashlib.sha1(arr.tobytes()).hexdigest()
        return ("ndarray", arr.shape, str(arr.dtype), digest)
    return _IdKey(value)


def op_signature(op: ContainerOp) -> Tuple:
    """Hashable identity of a ContainerOp for plan/compile-cache keying.

    Two ops with the same registry function, command, params and mounts
    trace to the same jaxpr, so they may share a compiled program.
    """
    params = tuple(sorted((k, _freeze(v)) for k, v in op.params.items()))
    return (op.image, op.tag, op.command, op.fn, op.out_capacity,
            repr(op.input_mount), repr(op.output_mount), params)


@dataclasses.dataclass(frozen=True)
class MapStage:
    """A fused chain of per-partition ContainerOps (no collectives)."""

    ops: Tuple[ContainerOp, ...]

    def signature(self) -> Tuple:
        return ("map",) + tuple(op_signature(op) for op in self.ops)

    def describe(self) -> str:
        return "map[" + " | ".join(op.name for op in self.ops) + "]"


@dataclasses.dataclass(frozen=True)
class ShuffleStage:
    """Hash repartition by a vectorized keyBy (one ``all_to_all``)."""

    key_by: Callable[[Any], jax.Array]
    capacity: Optional[int] = None
    num_partitions: Optional[int] = None

    def signature(self) -> Tuple:
        # key_by keys on the callable object: two equal lambdas miss the
        # cache, and (as with jax.jit) values it closes over are baked in
        # at trace time — mutating them without a new callable goes unseen.
        return ("shuffle", self.key_by, self.capacity, self.num_partitions)

    def describe(self) -> str:
        extra = (f", parts={self.num_partitions}"
                 if self.num_partitions is not None else "")
        return f"shuffle(cap={self.capacity}{extra})"


@dataclasses.dataclass(frozen=True)
class ReduceStage:
    """K-level tree aggregation of all partitions down to one."""

    op: ContainerOp
    depth: int = 2

    def signature(self) -> Tuple:
        return ("reduce", op_signature(self.op), self.depth)

    def describe(self) -> str:
        return f"reduce[{self.op.name}, depth={self.depth}]"


#: Monoids a KeyedReduceStage can fold values with (segment-reduce table).
KEYED_MONOIDS = ("sum", "max", "min")


@dataclasses.dataclass(frozen=True)
class KeyedReduceStage:
    """Grouped aggregation: fold records with equal keys into one record.

    ``key_by(records) -> int array [capacity]`` (vectorized keyBy); keys
    must lie in ``[0, num_keys)`` — the bounded key table is the static-SPMD
    price of sort-free aggregation, and out-of-range keys are counted into
    the action-time error channel rather than silently dropped.
    ``value_by`` selects the value pytree to fold (default: the whole
    record).  With ``combiner=True`` each shard pre-aggregates its records
    per key *before* the exchange (the classic map-side combiner), so
    shuffle volume scales with distinct keys, not records.  With
    ``combiner=False``, ``salt > 1`` splits hot keys over ``salt``
    destination shards (round-robin by record slot) and re-exchanges the
    per-key partials in a second, combiner-style hop — the skew defense
    when one key dominates the raw record stream.
    """

    key_by: Callable[[Any], jax.Array]
    op: str
    num_keys: int
    value_by: Optional[Callable[[Any], Any]] = None
    combiner: bool = True
    capacity: Optional[int] = None
    use_kernel: Optional[bool] = None
    salt: int = 1

    def signature(self) -> Tuple:
        # key_by/value_by key on callable identity, like ShuffleStage.key_by
        return ("keyed_reduce", self.key_by, self.value_by, self.op,
                self.num_keys, self.combiner, self.capacity, self.use_kernel,
                self.salt)

    def describe(self) -> str:
        comb = "on" if self.combiner else "off"
        extra = f", salt={self.salt}" if self.salt > 1 else ""
        return (f"reduce_by_key[{self.op}, keys={self.num_keys}, "
                f"combiner={comb}{extra}]")


Stage = Union[MapStage, ShuffleStage, ReduceStage, KeyedReduceStage]


#: Counter kinds that abort the action with RuntimeError when non-zero
#: (the rest are informational diagnostics, e.g. exchanged-record volume).
COUNTER_ERROR_KINDS = frozenset({"shuffle_dropped", "key_overflow"})


def stage_counter_kinds(stage: Stage) -> Tuple[str, ...]:
    """Diagnostic counters a stage contributes to the fused program's
    output vector (one int32 scalar per shard per kind, in this order).

    ``max_send_count`` is max-reduced across shards (not summed, unlike
    the rest): it is the tightest per-destination ``capacity=`` that would
    have been lossless for this run — the runtime capacity-feedback knob.
    ``exchange_buffer_rows`` is the *static* per-shard exchange buffer
    allocation (rows) so skewed-vs-salted buffer volume is observable.
    """
    if isinstance(stage, ShuffleStage):
        return ("shuffle_dropped",)
    if isinstance(stage, KeyedReduceStage):
        return ("key_overflow", "shuffle_dropped", "exchanged_records",
                "max_send_count", "exchange_buffer_rows")
    return ()


@dataclasses.dataclass
class Plan:
    """A pending linear DAG of stages (immutable builder)."""

    stages: Tuple[Stage, ...] = ()

    def then(self, op: ContainerOp) -> "Plan":
        """Append a map op, fusing into a trailing MapStage if present."""
        if self.stages and isinstance(self.stages[-1], MapStage):
            head, last = self.stages[:-1], self.stages[-1]
            return Plan(stages=head + (MapStage(last.ops + (op,)),))
        return Plan(stages=self.stages + (MapStage((op,)),))

    def then_shuffle(self, key_by: Callable[[Any], jax.Array],
                     capacity: Optional[int] = None,
                     num_partitions: Optional[int] = None) -> "Plan":
        return Plan(stages=self.stages + (
            ShuffleStage(key_by, capacity, num_partitions),))

    def then_reduce(self, op: ContainerOp, depth: int = 2) -> "Plan":
        return Plan(stages=self.stages + (ReduceStage(op, depth),))

    def then_keyed_reduce(self, key_by: Callable[[Any], jax.Array],
                          op: str, num_keys: int,
                          value_by: Optional[Callable[[Any], Any]] = None,
                          combiner: bool = True,
                          capacity: Optional[int] = None,
                          use_kernel: Optional[bool] = None,
                          salt: int = 1) -> "Plan":
        return Plan(stages=self.stages + (KeyedReduceStage(
            key_by=key_by, op=op, num_keys=num_keys, value_by=value_by,
            combiner=combiner, capacity=capacity, use_kernel=use_kernel,
            salt=salt),))

    def drop(self, n: int) -> "Plan":
        """Plan with the first ``n`` stages removed (the suffix left to
        execute after a materialization-cache prefix hit)."""
        return Plan(stages=self.stages[n:]) if n else self

    @property
    def empty(self) -> bool:
        return not self.stages

    @property
    def ops(self) -> Tuple[ContainerOp, ...]:
        """All pending map ops (legacy view of a map-only plan)."""
        return tuple(op for st in self.stages
                     if isinstance(st, MapStage) for op in st.ops)

    @property
    def num_shuffles(self) -> int:
        """ShuffleStage count (legacy view; keyed stages shuffle too — the
        program's counter-vector layout lives in :meth:`counter_specs`)."""
        return sum(isinstance(st, ShuffleStage) for st in self.stages)

    def counter_specs(self) -> Tuple[Tuple[int, str], ...]:
        """(stage_index, kind) for every diagnostic counter the fused
        program outputs, in program-output order."""
        return tuple((i, kind) for i, st in enumerate(self.stages)
                     for kind in stage_counter_kinds(st))

    def signature(self) -> Tuple:
        """Hashable pipeline shape — the compile-cache key component."""
        return tuple(st.signature() for st in self.stages)

    def describe(self) -> str:
        return " -> ".join(st.describe() for st in self.stages) \
            or "<identity>"


def _apply_chain(ops: Tuple[ContainerOp, ...], records: Any,
                 count: jax.Array, stage_idx: Optional[int] = None
                 ) -> Partition:
    where = f"stage {stage_idx}" if stage_idx is not None else "stage"
    part = make_partition(records, count)
    for op in ops:
        if op.input_mount is not None:
            try:
                op.input_mount.validate(part.records)
            except ValueError as e:
                raise ValueError(
                    f"{where} (map[{op.name}]): input mount validation "
                    f"failed: {e}") from e
        part = op(part)
        if op.output_mount is not None:
            try:
                op.output_mount.validate(part.records)
            except ValueError as e:
                raise ValueError(
                    f"{where} (map[{op.name}]): output mount validation "
                    f"failed: {e}") from e
    return part


# ---------------------------------------------------------------------------
# Plan-time schema & capacity inference (manifests consumed here)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StageState:
    """Inferred dataset state at one stage boundary.

    ``schema``/``capacity`` are ``None`` when an op without a manifest (or
    without a declared output schema) makes them unknown — downstream
    checks are then skipped and errors surface at trace/action time as
    before.  ``key_space`` is the declared key range of the current
    records' key leaf (set by key-emitting images like ``kmer-stats``),
    used to size and bounds-check keyed-reduce tables; ``producer`` labels
    the stage that produced the current schema, for error messages.
    """

    schema: Optional[Schema]
    capacity: Optional[int]
    num_shards: int = 1
    key_space: Optional[int] = None
    producer: str = "input dataset"

    def describe(self) -> str:
        s = self.schema.describe() if self.schema is not None else "?"
        c = self.capacity if self.capacity is not None else "?"
        return f"{s}#{c}"


def _infer_op(state: StageState, op: ContainerOp, stage_idx: int,
              reduce_shards: Optional[int] = None) -> StageState:
    """Push ``state`` through one ContainerOp's declared contract.

    ``reduce_shards`` is set when the op runs as a reduce combiner: a
    capacity-PRESERVE combiner is concat-like and its single surviving
    partition must hold every shard's records (tree_reduce's rule).
    """
    op_label = op.contract.label if op.contract is not None else op.name
    label = f"stage {stage_idx} ({op_label})"
    if op.input_mount is not None and state.schema is not None:
        try:
            op.input_mount.validate_schema(state.schema)
        except ValueError as e:
            raise PlanTypeError(f"{label}: input mount: {e}") from e
    contract = op.contract
    env: dict = dict(contract.params) if contract is not None else {}
    if (contract is not None and contract.input_schema is not None
            and state.schema is not None):
        try:
            env = contract.check_input(state.schema)
        except SchemaMismatch as e:
            raise PlanTypeError(
                f"{label}: input schema mismatch: {contract.label} "
                f"expects {contract.input_schema.describe()} but receives "
                f"{state.schema.describe()} from {state.producer}: {e}"
            ) from e
    if contract is not None:
        out_schema = contract.infer_output_schema(state.schema, env)
        try:
            out_cap = contract.infer_out_capacity(state.capacity, env)
        except ValueError as e:
            raise PlanTypeError(f"{label}: {e}") from e
        if reduce_shards is not None and out_cap is not None \
                and state.capacity is not None and out_cap >= state.capacity:
            # concat-like combiner: the surviving partition holds all shards
            out_cap = reduce_shards * state.capacity
        key_space = contract.infer_key_space(env)
    else:
        out_schema = None
        out_cap = op.out_capacity
        key_space = None
    if op.output_mount is not None and out_schema is not None:
        try:
            op.output_mount.validate_schema(out_schema)
        except ValueError as e:
            raise PlanTypeError(f"{label}: output mount: {e}") from e
    return StageState(schema=out_schema, capacity=out_cap,
                      num_shards=state.num_shards, key_space=key_space,
                      producer=label)


def _check_key_by(stage, state: StageState, stage_idx: int,
                  what: str) -> None:
    """Abstractly evaluate a keyBy against the inferred schema: it must
    map the record pytree to an int array of one key per record."""
    if state.schema is None or state.capacity is None \
            or not state.schema.concrete:
        return
    structs = state.schema.structs(state.capacity)
    try:
        spec = jax.eval_shape(stage.key_by, structs)
    except Exception as e:
        raise PlanTypeError(
            f"stage {stage_idx} ({what}): key_by failed against inferred "
            f"schema {state.schema.describe()} (from {state.producer}): "
            f"{e}") from e
    leaves = jax.tree.leaves(spec)
    ok = (len(leaves) == 1
          and np.issubdtype(np.dtype(leaves[0].dtype), np.integer)
          and tuple(leaves[0].shape) == (state.capacity,))
    if not ok:
        got = [(str(l.dtype), tuple(l.shape)) for l in leaves]
        raise PlanTypeError(
            f"stage {stage_idx} ({what}): key_by must return one int "
            f"array of shape [{state.capacity}] over schema "
            f"{state.schema.describe()}, got {got}")


def _key_by_is_passthrough(key_by, state: StageState) -> bool:
    """Whether ``key_by`` provably returns the KEY leaf unchanged.

    The declared ``key_space`` describes the record's key leaf — by
    convention the *first* leaf of a key-emitting image's output records
    (``kmer-stats``: ``(codes, ones)``).  An arbitrary ``key_by`` may
    remap keys into a smaller range, or key on a different column
    entirely, so the plan-time bounds check below is only sound when the
    key leaf reaches the table untransformed — detected conservatively
    from the jaxpr (no equations, output is the first input leaf).
    Anything else defers to the action-time overflow counter.
    """
    if state.schema is None or state.capacity is None \
            or not state.schema.concrete:
        return False
    try:
        closed = jax.make_jaxpr(key_by)(
            state.schema.structs(state.capacity))
    except Exception:
        return False
    jaxpr = closed.jaxpr
    return (not jaxpr.eqns and len(jaxpr.outvars) == 1
            and len(jaxpr.invars) > 0
            and jaxpr.outvars[0] is jaxpr.invars[0])


def _infer_keyed(state: StageState, stage: "KeyedReduceStage",
                 stage_idx: int) -> StageState:
    label = f"stage {stage_idx} ({stage.describe()})"
    if (state.key_space is not None and stage.num_keys < state.key_space
            and _key_by_is_passthrough(stage.key_by, state)):
        raise PlanTypeError(
            f"{label}: key table num_keys={stage.num_keys} is smaller "
            f"than the key space {state.key_space} declared by "
            f"{state.producer} — keys would overflow at action time; "
            f"raise num_keys (or omit it to infer {state.key_space})")
    _check_key_by(stage, state, stage_idx, stage.describe())
    out_schema = None
    if state.schema is not None and state.capacity is not None \
            and state.schema.concrete:
        structs = state.schema.structs(state.capacity)
        values = structs if stage.value_by is None else None
        if stage.value_by is not None:
            try:
                values = jax.eval_shape(stage.value_by, structs)
            except Exception as e:
                raise PlanTypeError(
                    f"{label}: value_by failed against inferred schema "
                    f"{state.schema.describe()}: {e}") from e
        value_fields = jax.tree.map(
            lambda l: Field(np.dtype(l.dtype).name,
                            tuple(int(d) for d in l.shape[1:])), values)
        out_schema = Schema((Field("int32"), value_fields, Field("int32")))
    return StageState(schema=out_schema, capacity=stage.num_keys,
                      num_shards=state.num_shards,
                      key_space=stage.num_keys, producer=label)


def infer_stage(stage: Stage, state: StageState, i: int) -> StageState:
    """Push an inferred state through one stage (see :func:`infer_states`)."""
    if isinstance(stage, MapStage):
        for op in stage.ops:
            state = _infer_op(state, op, i)
        return state
    if isinstance(stage, ShuffleStage):
        _check_key_by(stage, state, i, "repartition_by")
        # every source shard may contribute up to `capacity` records
        # (shuffle_partition: output capacity = axis_size * capacity)
        send_cap = stage.capacity or state.capacity
        out_cap = (state.num_shards * send_cap
                   if send_cap is not None else None)
        return dataclasses.replace(state, capacity=out_cap)
    if isinstance(stage, KeyedReduceStage):
        return _infer_keyed(state, stage, i)
    if isinstance(stage, ReduceStage):
        return _infer_op(state, stage.op, i, reduce_shards=state.num_shards)
    raise TypeError(  # pragma: no cover - defensive
        f"unknown stage type {type(stage).__name__}")


def infer_states(plan: Plan, initial: StageState) -> List[StageState]:
    """Type-check a plan against manifests; states after each stage.

    Runs at plan-*build* time (every ``MaRe.map/...`` call): declared
    image contracts, mount contracts, capacity transfers and keyBy
    signatures are checked stage by stage, raising :class:`PlanTypeError`
    with the stage index and both schemas — instead of a cryptic shape
    error from inside the fused ``shard_map`` trace.  Returns
    ``[initial, after_stage_0, ...]``.
    """
    with span("plan.typecheck", stages=len(plan.stages)):
        states = [initial]
        state = initial
        for i, stage in enumerate(plan.stages):
            state = infer_stage(stage, state, i)
            states.append(state)
        return states
