"""Lazy execution plan — the Spark-DAG/stage analogue, now a stage DAG.

MaRe inherits Spark's lazy evaluation: chained ``map`` calls generate a
single stage (one ``mapPartitions`` chain, no shuffle); ``reduce`` and
``repartitionBy`` are stage *boundaries* — but not execution boundaries.
A :class:`Plan` accumulates a linear DAG of :class:`MapStage` /
:class:`ShuffleStage` / :class:`ReduceStage` nodes; nothing runs until an
action.  :mod:`repro.core.planner` lowers the whole DAG into a **single**
``shard_map`` + ``jit`` program — map ops fused into their downstream
shuffle/reduce, one XLA module per pipeline shape, locality preserved by
construction (DESIGN.md §2) — and memoizes compiled programs so
interactive re-execution (paper Fig. 6) pays zero re-trace.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Hashable, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.container import ContainerOp, Partition, make_partition


class _IdKey:
    """Identity-based hashable wrapper for unhashable op params.

    Param values are baked into the traced program, so two pipelines may
    only share a compiled program when their params hold the same value —
    a repr() fallback could collide (e.g. numpy's truncated repr of large
    arrays) and silently reuse a program compiled with different
    constants.  Holding a strong reference keeps ``id`` from being
    recycled for as long as the cache key lives.  CAVEAT: identity keying
    means in-place mutation of the param object goes unseen (the cached
    program keeps the old baked-in value) — numpy arrays are therefore
    keyed by content digest in :func:`_freeze`; anything that falls
    through to ``_IdKey`` must be treated as immutable, matching
    ``jax.jit``'s own semantics for closed-over constants.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any) -> None:
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, _IdKey) and other.obj is self.obj

    def __repr__(self) -> str:
        return f"_IdKey({type(self.obj).__name__}@{id(self.obj):#x})"


def _freeze(value: Any) -> Hashable:
    """Hashable view of an op parameter.

    Hashable values key on themselves; numpy arrays key on a content
    digest (so in-place mutation correctly misses the cache); any other
    unhashable value keys on object identity and must not be mutated.
    """
    try:
        hash(value)
        return value
    except TypeError:
        pass
    if isinstance(value, np.ndarray):
        arr = np.ascontiguousarray(value)
        digest = hashlib.sha1(arr.tobytes()).hexdigest()
        return ("ndarray", arr.shape, str(arr.dtype), digest)
    return _IdKey(value)


def op_signature(op: ContainerOp) -> Tuple:
    """Hashable identity of a ContainerOp for plan/compile-cache keying.

    Two ops with the same registry function, command, params and mounts
    trace to the same jaxpr, so they may share a compiled program.
    """
    params = tuple(sorted((k, _freeze(v)) for k, v in op.params.items()))
    return (op.image, op.tag, op.command, op.fn, op.out_capacity,
            repr(op.input_mount), repr(op.output_mount), params)


@dataclasses.dataclass(frozen=True)
class MapStage:
    """A fused chain of per-partition ContainerOps (no collectives)."""

    ops: Tuple[ContainerOp, ...]

    def signature(self) -> Tuple:
        return ("map",) + tuple(op_signature(op) for op in self.ops)

    def describe(self) -> str:
        return "map[" + " | ".join(op.name for op in self.ops) + "]"


@dataclasses.dataclass(frozen=True)
class ShuffleStage:
    """Hash repartition by a vectorized keyBy (one ``all_to_all``)."""

    key_by: Callable[[Any], jax.Array]
    capacity: Optional[int] = None
    num_partitions: Optional[int] = None

    def signature(self) -> Tuple:
        # key_by keys on the callable object: two equal lambdas miss the
        # cache, and (as with jax.jit) values it closes over are baked in
        # at trace time — mutating them without a new callable goes unseen.
        return ("shuffle", self.key_by, self.capacity, self.num_partitions)

    def describe(self) -> str:
        extra = (f", parts={self.num_partitions}"
                 if self.num_partitions is not None else "")
        return f"shuffle(cap={self.capacity}{extra})"


@dataclasses.dataclass(frozen=True)
class ReduceStage:
    """K-level tree aggregation of all partitions down to one."""

    op: ContainerOp
    depth: int = 2

    def signature(self) -> Tuple:
        return ("reduce", op_signature(self.op), self.depth)

    def describe(self) -> str:
        return f"reduce[{self.op.name}, depth={self.depth}]"


#: Monoids a KeyedReduceStage can fold values with (segment-reduce table).
KEYED_MONOIDS = ("sum", "max", "min")


@dataclasses.dataclass(frozen=True)
class KeyedReduceStage:
    """Grouped aggregation: fold records with equal keys into one record.

    ``key_by(records) -> int array [capacity]`` (vectorized keyBy); keys
    must lie in ``[0, num_keys)`` — the bounded key table is the static-SPMD
    price of sort-free aggregation, and out-of-range keys are counted into
    the action-time error channel rather than silently dropped.
    ``value_by`` selects the value pytree to fold (default: the whole
    record).  With ``combiner=True`` each shard pre-aggregates its records
    per key *before* the exchange (the classic map-side combiner), so
    shuffle volume scales with distinct keys, not records.
    """

    key_by: Callable[[Any], jax.Array]
    op: str
    num_keys: int
    value_by: Optional[Callable[[Any], Any]] = None
    combiner: bool = True
    capacity: Optional[int] = None
    use_kernel: Optional[bool] = None

    def signature(self) -> Tuple:
        # key_by/value_by key on callable identity, like ShuffleStage.key_by
        return ("keyed_reduce", self.key_by, self.value_by, self.op,
                self.num_keys, self.combiner, self.capacity, self.use_kernel)

    def describe(self) -> str:
        comb = "on" if self.combiner else "off"
        return (f"reduce_by_key[{self.op}, keys={self.num_keys}, "
                f"combiner={comb}]")


Stage = Union[MapStage, ShuffleStage, ReduceStage, KeyedReduceStage]


#: Counter kinds that abort the action with RuntimeError when non-zero
#: (the rest are informational diagnostics, e.g. exchanged-record volume).
COUNTER_ERROR_KINDS = frozenset({"shuffle_dropped", "key_overflow"})


def stage_counter_kinds(stage: Stage) -> Tuple[str, ...]:
    """Diagnostic counters a stage contributes to the fused program's
    output vector (one int32 scalar per shard per kind, in this order)."""
    if isinstance(stage, ShuffleStage):
        return ("shuffle_dropped",)
    if isinstance(stage, KeyedReduceStage):
        return ("key_overflow", "shuffle_dropped", "exchanged_records")
    return ()


@dataclasses.dataclass
class Plan:
    """A pending linear DAG of stages (immutable builder)."""

    stages: Tuple[Stage, ...] = ()

    def then(self, op: ContainerOp) -> "Plan":
        """Append a map op, fusing into a trailing MapStage if present."""
        if self.stages and isinstance(self.stages[-1], MapStage):
            head, last = self.stages[:-1], self.stages[-1]
            return Plan(stages=head + (MapStage(last.ops + (op,)),))
        return Plan(stages=self.stages + (MapStage((op,)),))

    def then_shuffle(self, key_by: Callable[[Any], jax.Array],
                     capacity: Optional[int] = None,
                     num_partitions: Optional[int] = None) -> "Plan":
        return Plan(stages=self.stages + (
            ShuffleStage(key_by, capacity, num_partitions),))

    def then_reduce(self, op: ContainerOp, depth: int = 2) -> "Plan":
        return Plan(stages=self.stages + (ReduceStage(op, depth),))

    def then_keyed_reduce(self, key_by: Callable[[Any], jax.Array],
                          op: str, num_keys: int,
                          value_by: Optional[Callable[[Any], Any]] = None,
                          combiner: bool = True,
                          capacity: Optional[int] = None,
                          use_kernel: Optional[bool] = None) -> "Plan":
        return Plan(stages=self.stages + (KeyedReduceStage(
            key_by=key_by, op=op, num_keys=num_keys, value_by=value_by,
            combiner=combiner, capacity=capacity, use_kernel=use_kernel),))

    @property
    def empty(self) -> bool:
        return not self.stages

    @property
    def ops(self) -> Tuple[ContainerOp, ...]:
        """All pending map ops (legacy view of a map-only plan)."""
        return tuple(op for st in self.stages
                     if isinstance(st, MapStage) for op in st.ops)

    @property
    def num_shuffles(self) -> int:
        """ShuffleStage count (legacy view; keyed stages shuffle too — the
        program's counter-vector layout lives in :meth:`counter_specs`)."""
        return sum(isinstance(st, ShuffleStage) for st in self.stages)

    def counter_specs(self) -> Tuple[Tuple[int, str], ...]:
        """(stage_index, kind) for every diagnostic counter the fused
        program outputs, in program-output order."""
        return tuple((i, kind) for i, st in enumerate(self.stages)
                     for kind in stage_counter_kinds(st))

    def signature(self) -> Tuple:
        """Hashable pipeline shape — the compile-cache key component."""
        return tuple(st.signature() for st in self.stages)

    def describe(self) -> str:
        return " -> ".join(st.describe() for st in self.stages) \
            or "<identity>"


def _apply_chain(ops: Tuple[ContainerOp, ...], records: Any,
                 count: jax.Array) -> Partition:
    part = make_partition(records, count)
    for op in ops:
        if op.input_mount is not None:
            op.input_mount.validate(part.records)
        part = op(part)
        if op.output_mount is not None:
            op.output_mount.validate(part.records)
    return part
