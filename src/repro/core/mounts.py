"""Mount points: typed I/O contracts between partitions and ContainerOps.

Paper mapping (MaRe §1.2.1): ``TextFile(path, recordSeparator)`` mounts a
partition as one file whose records are separated by a configurable
separator; ``BinaryFiles(dir)`` mounts each record as a distinct file in a
directory.  On TPU there is no POSIX filesystem inside the compute unit, so
a mount becomes a *typed array contract*:

* ``RecordMount`` (== ``TextFile``): the partition is a single array pytree
  whose **leading dimension indexes records** (the "record separator" is the
  leading-dim boundary; custom separators map to custom record widths).
* ``FileSetMount`` (== ``BinaryFiles``): the partition is a **dict of named
  arrays** — each entry a distinct "file".

At the kernel level the same contract reappears as a Pallas ``BlockSpec``:
the VMEM tile of a record block is the TPU analogue of the paper's tmpfs
in-memory mount (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core.schema import Schema


@dataclasses.dataclass(frozen=True)
class Mount:
    """Base class for mount points.

    ``path`` is kept for provenance / paper fidelity (e.g. ``"/dna"``) and
    used in error messages; it has no filesystem meaning here.
    """

    path: str

    def validate(self, records: Any) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def validate_schema(self, schema: Schema) -> None:
        """Plan-time twin of :meth:`validate`: check the mount contract
        against an *inferred* record schema instead of live arrays."""
        raise NotImplementedError  # pragma: no cover - abstract


@dataclasses.dataclass(frozen=True)
class RecordMount(Mount):
    """A partition mounted as one array (pytree) of stacked records.

    Equivalent to the paper's ``TextFile``.  ``record_shape``/``dtype`` are
    optional contracts checked against the mounted arrays; ``separator`` is
    recorded for provenance only (leading-dim boundaries separate records).
    """

    dtype: Optional[Any] = None
    record_shape: Optional[Tuple[int, ...]] = None
    separator: Optional[str] = None

    def validate(self, records: Any) -> None:
        leaves = jax.tree.leaves(records)
        if not leaves:
            raise ValueError(f"mount {self.path}: empty record pytree")
        lead = {l.shape[0] for l in leaves if hasattr(l, "shape") and l.ndim}
        if len(lead) > 1:
            raise ValueError(
                f"mount {self.path}: inconsistent record counts {lead}")
        if self.dtype is not None:
            for l in leaves:
                if l.dtype != self.dtype:
                    raise ValueError(
                        f"mount {self.path}: dtype {l.dtype} != contract "
                        f"{self.dtype}")
        if self.record_shape is not None:
            for l in leaves:
                if tuple(l.shape[1:]) != tuple(self.record_shape):
                    raise ValueError(
                        f"mount {self.path}: record shape {l.shape[1:]} != "
                        f"contract {self.record_shape}")

    def validate_schema(self, schema: Schema) -> None:
        fields = jax.tree.leaves(schema.fields)
        if not fields:
            raise ValueError(f"mount {self.path}: empty record schema")
        if self.dtype is not None:
            want = np.dtype(self.dtype).name
            for f in fields:
                if f.dtype is not None and f.dtype != want:
                    raise ValueError(
                        f"mount {self.path}: dtype {f.dtype} != contract "
                        f"{want} (schema {schema.describe()})")
        if self.record_shape is not None:
            want_shape = tuple(self.record_shape)
            for f in fields:
                concrete = tuple(d for d in f.shape if isinstance(d, int))
                if len(concrete) == len(f.shape) and f.shape != want_shape:
                    raise ValueError(
                        f"mount {self.path}: record shape {f.shape} != "
                        f"contract {want_shape} (schema "
                        f"{schema.describe()})")


@dataclasses.dataclass(frozen=True)
class FileSetMount(Mount):
    """A partition mounted as a directory of named arrays.

    Equivalent to the paper's ``BinaryFiles``: each dict entry is one
    "file".  All entries must share the leading record dimension.
    """

    keys: Optional[Tuple[str, ...]] = None

    def validate(self, records: Any) -> None:
        if not isinstance(records, Mapping):
            raise ValueError(
                f"mount {self.path}: FileSetMount requires a dict of arrays, "
                f"got {type(records).__name__}")
        if self.keys is not None:
            missing = set(self.keys) - set(records)
            if missing:
                raise ValueError(f"mount {self.path}: missing files {missing}")

    def validate_schema(self, schema: Schema) -> None:
        if not isinstance(schema.fields, Mapping):
            raise ValueError(
                f"mount {self.path}: FileSetMount requires a dict of arrays, "
                f"got record schema {schema.describe()}")
        if self.keys is not None:
            missing = set(self.keys) - set(schema.fields)
            if missing:
                raise ValueError(
                    f"mount {self.path}: missing files {sorted(missing)} "
                    f"(schema {schema.describe()})")


# Paper-fidelity aliases -----------------------------------------------------

def TextFile(path: str, separator: Optional[str] = None,
             dtype: Optional[Any] = None,
             record_shape: Optional[Tuple[int, ...]] = None) -> RecordMount:
    """Alias matching MaRe Listing 1/2 spelling."""
    return RecordMount(path=path, dtype=dtype, record_shape=record_shape,
                       separator=separator)


def BinaryFiles(path: str, keys: Optional[Tuple[str, ...]] = None
                ) -> FileSetMount:
    """Alias matching MaRe Listing 3 spelling."""
    return FileSetMount(path=path, keys=keys)
