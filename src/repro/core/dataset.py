"""ShardedDataset: the RDD analogue — a partitioned dataset on a mesh axis.

A dataset is a pytree of *global* arrays whose leading dimension is the
total record capacity, sharded over one mesh axis (`NamedSharding`), plus a
per-shard valid-record count.  Shards play the role of RDD partitions;
`from_host` plays the role of `sc.parallelize`, `collect` of `RDD.collect`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardedDataset:
    records: Any          # pytree of global arrays; leading dim = n * cap
    counts: jax.Array     # [n_shards] int32, valid records per shard
    mesh: Mesh
    axis: str = "data"
    #: Lineage fingerprint (repro.runtime.lineage.Lineage) identifying how
    #: this dataset was produced — root source id + canonical stage
    #: signatures.  None = unknown provenance; the runtime executor
    #: assigns a fresh host root on first action, so forked handles over
    #: the same base dataset share a lineage prefix.
    lineage: Any = None

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def capacity(self) -> int:
        """Per-shard record capacity."""
        lead = jax.tree.leaves(self.records)[0].shape[0]
        return lead // self.num_shards

    def record_spec(self) -> Any:
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), self.records)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis))

    def with_records(self, records: Any, counts: Optional[jax.Array] = None
                     ) -> "ShardedDataset":
        # records changed by an unknown transformation -> provenance lost
        return dataclasses.replace(
            self, records=records, lineage=None,
            counts=self.counts if counts is None else counts)


def from_host(records: Any, mesh: Mesh, axis: str = "data",
              capacity: Optional[int] = None) -> ShardedDataset:
    """Distribute host records round-robin-block over the ``axis`` shards,
    padding each shard to a common capacity (static SPMD shapes)."""
    n = int(mesh.shape[axis])
    leaves = jax.tree.leaves(records)
    total = leaves[0].shape[0]
    cap = capacity or math.ceil(total / n)
    counts = np.full((n,), cap, np.int32)
    rem = n * cap - total
    for i in range(rem):
        counts[n - 1 - (i % n)] -= 1
    # Block layout: shard s holds records [sum(counts[:s]), +counts[s]) of
    # the input, padded to cap.
    offsets = np.concatenate([[0], np.cumsum(counts)])

    def place(leaf):
        leaf = np.asarray(leaf)
        out = np.zeros((n * cap,) + leaf.shape[1:], leaf.dtype)
        for s in range(n):
            c = counts[s]
            out[s * cap:s * cap + c] = leaf[offsets[s]:offsets[s] + c]
        return jax.device_put(out, NamedSharding(mesh, P(axis)))

    placed = jax.tree.map(place, records)
    counts_dev = jax.device_put(
        jnp.asarray(counts), NamedSharding(mesh, P(axis)))
    return ShardedDataset(records=placed, counts=counts_dev, mesh=mesh,
                          axis=axis)


def from_shard_arrays(shard_records: Any, shard_counts: Sequence[int],
                      mesh: Mesh, axis: str = "data") -> ShardedDataset:
    """Assemble a ShardedDataset from per-shard host pytrees.

    ``shard_records`` is an iterable of ``num_shards`` pytrees whose leaves
    are ``[cap, ...]`` arrays (identical cap/dtype/trailing shape across
    shards).  Each shard's leaves are ``jax.device_put`` to that shard's
    device(s) as they arrive — transfers are dispatched asynchronously, so
    when the iterable packs lazily (repro.io.ingest), the device transfer
    of shard *s* overlaps host packing of shard *s+1* (double buffering) —
    then stitched into global arrays without a host-side copy of the full
    dataset.
    """
    n = int(mesh.shape[axis])
    sharding = NamedSharding(mesh, P(axis))
    axis_idx = list(mesh.axis_names).index(axis)
    dev_grid = np.moveaxis(np.asarray(mesh.devices), axis_idx, 0
                           ).reshape(n, -1)

    treedef = None
    leaf_shards: List[List[Any]] = []
    count_shards: List[Any] = []
    num_seen = 0
    for s, rec in enumerate(shard_records):
        leaves, td = jax.tree.flatten(rec)
        if treedef is None:
            treedef = td
            leaf_shards = [[] for _ in leaves]
        for li, leaf in enumerate(leaves):
            leaf = np.asarray(leaf)
            for d in dev_grid[s]:
                leaf_shards[li].append(jax.device_put(leaf, d))
        cnt = np.asarray([shard_counts[s]], np.int32)
        for d in dev_grid[s]:
            count_shards.append(jax.device_put(cnt, d))
        num_seen += 1
    if num_seen != n:
        raise ValueError(f"got {num_seen} shard pytrees for {n} shards")

    def assemble(arrays, lead, tail):
        return jax.make_array_from_single_device_arrays(
            (lead,) + tuple(tail), sharding, arrays)

    out_leaves = []
    for li, arrays in enumerate(leaf_shards):
        cap_shape = arrays[0].shape
        out_leaves.append(assemble(arrays, n * cap_shape[0], cap_shape[1:]))
    records = jax.tree.unflatten(treedef, out_leaves)
    counts = assemble(count_shards, n, ())
    return ShardedDataset(records=records, counts=counts, mesh=mesh,
                          axis=axis)


def collect_shard(ds: ShardedDataset, shard: int = 0) -> Any:
    """One shard's valid records (``MaRe.collect(shard=...)``'s engine).

    Slices the shard's block on device and transfers only its valid rows
    to host — a replicated reduce result would otherwise ship every
    shard's full copy across just to keep one.
    """
    n = ds.num_shards
    if not 0 <= shard < n:
        raise ValueError(f"shard index {shard} out of range for "
                         f"{n}-shard dataset")
    rows = int(jax.device_get(ds.counts)[shard])

    def one(leaf):
        cap = leaf.shape[0] // n  # per-leaf shard block
        lo = shard * cap
        return jax.device_get(leaf[lo:lo + min(cap, rows)])

    return jax.tree.map(one, ds.records)


def collect_first_shard(ds: ShardedDataset) -> Any:
    """Shard 0's valid records (for reduced/replicated results)."""
    return collect_shard(ds, 0)


def collect(ds: ShardedDataset) -> Any:
    """Gather valid records to host (RDD.collect)."""
    counts = np.asarray(jax.device_get(ds.counts))
    cap = ds.capacity

    def gather(leaf):
        host = np.asarray(jax.device_get(leaf))
        segs: List[np.ndarray] = []
        for s in range(ds.num_shards):
            segs.append(host[s * cap:s * cap + counts[s]])
        return np.concatenate(segs, axis=0) if segs else host[:0]

    return jax.tree.map(gather, ds.records)
