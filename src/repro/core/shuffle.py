"""Key-based repartitioning — MaRe's ``repartitionBy`` primitive.

Paper semantics (§1.2.1/§1.2.2): a user ``keyBy`` function computes a key
per record; ``repartition`` + ``HashPartitioner`` then guarantees records
with equal keys land in the same partition.

TPU mapping: partitions are shards on a mesh axis of size ``n``.  Each shard
hashes its record keys, packs records into a ``[n, capacity, ...]`` send
buffer grouped by destination, and a single ``lax.all_to_all`` performs the
shuffle.  Fixed capacity is the SPMD price for static shapes — the same
capacity-factor discipline used by MoE dispatch (which *is* this primitive
with ``keyBy = router``; see models/moe.py).  Overflow is counted and
surfaced, never silently ignored.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.container import Partition, make_partition


def hash_keys(keys: jax.Array) -> jax.Array:
    """Deterministic 32-bit integer mix (splitmix32-style) — the
    HashPartitioner.  Accepts any integer dtype, returns uint32."""
    x = keys.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def keyed_bucket_capacities(num_keys: int, axis_size: int) -> np.ndarray:
    """Exact per-destination bucket sizes of the keyed hash exchange.

    The hash partitioner is deterministic and the key space is bounded, so
    how many of the ``num_keys`` possible keys each destination shard owns
    is computable statically on the host: entry ``d`` is
    ``|{k in [0, num_keys) : hash(k) % axis_size == d}|``.  A combiner-side
    shard sends at most one record per distinct key, so entry ``d`` bounds
    what *any* shard can send to ``d`` — the skew-aware capacity vector.
    Runs chunked so a 4**15-sized key space costs MiBs of host scratch,
    not GiBs.  (Host-side mirror of :func:`hash_keys`; keep in lockstep.)
    """
    mask = np.uint64(0xFFFFFFFF)
    buckets = np.zeros((axis_size,), np.int64)
    chunk = 1 << 22
    for start in range(0, num_keys, chunk):
        x = np.arange(start, min(start + chunk, num_keys), dtype=np.uint64)
        x = ((x ^ (x >> np.uint64(16))) * np.uint64(0x7FEB352D)) & mask
        x = ((x ^ (x >> np.uint64(15))) * np.uint64(0x846CA68B)) & mask
        x = x ^ (x >> np.uint64(16))
        dest = (x % np.uint64(axis_size)).astype(np.int64)
        buckets += np.bincount(dest, minlength=axis_size)
    return buckets


def keyed_bucket_capacity(num_keys: int, axis_size: int) -> int:
    """Exact-lossless *uniform* per-destination send capacity for a
    combined keyed exchange: ``max(keyed_bucket_capacities(...))``.

    Contract: a single ``lax.all_to_all`` under static SPMD must use ONE
    capacity for every (source, destination) pair — shapes are uniform
    across shards — so the exchange buffer is sized to the *largest* hash
    bucket even though most destinations own fewer keys.  Typically
    ~``num_keys / axis_size`` instead of the worst-case ``num_keys`` a
    dynamic bound would have to assume; the gap between this max and the
    mean of :func:`keyed_bucket_capacities` is the (mild) hash-imbalance
    cost, and is unrelated to *data* skew — a hot key inflates record
    counts, not distinct-key counts, which is why the combiner (or the
    salted two-hop path for ``combiner=False``; see
    ``planner._apply_keyed``) is the skew defense, not this bound.
    Overflow semantics: sends beyond capacity are counted into
    ``ShuffleResult.dropped`` and raise at action time; with this bound
    on a combined exchange the counter is provably always zero.
    """
    return max(1, int(keyed_bucket_capacities(num_keys, axis_size).max()))


def salted_dest(keys: jax.Array, axis_size: int, salt: int) -> jax.Array:
    """Hot-key-splitting destination map: spread each key's records over
    ``salt`` consecutive shards round-robin by record slot.

    ``dest = (hash(key) + (slot % salt)) % axis_size`` — a key's records
    land on a deterministic window of ``salt`` shards instead of one, so
    a 90%-hot key costs any single destination ~``n*0.9/salt`` slots
    rather than ``n*0.9``.  Equal keys no longer co-locate after ONE
    exchange; callers must follow with a per-key merge and a second,
    combiner-style exchange (the two-hop path in ``planner._apply_keyed``).
    """
    base = hash_keys(keys)
    slot = jnp.arange(keys.shape[0], dtype=jnp.uint32)
    return ((base + slot % jnp.uint32(salt))
            % jnp.uint32(axis_size)).astype(jnp.int32)


class ShuffleResult(NamedTuple):
    part: Partition         # received records, compacted to the front
    dropped: jax.Array      # int32 scalar: records lost to capacity overflow
    send_counts: jax.Array  # [n] records sent to each destination shard


class PackResult(NamedTuple):
    buffer: Any             # [num_dest, capacity, ...] pytree
    counts: jax.Array       # [num_dest] records packed per destination
    dropped: jax.Array      # overflow count
    dest: jax.Array         # [n] destination of each input record
    pos: jax.Array          # [n] slot of each input record at its dest
    in_cap: jax.Array       # [n] whether the record made it into the buffer


def _pack_by_dest(records: Any, dest: jax.Array, valid: jax.Array,
                  num_dest: int, capacity: int) -> PackResult:
    """Group records into a [num_dest, capacity, ...] send buffer.

    GATHER-ONLY construction: sort by destination, then each output slot
    (d, p) *gathers* sorted row ``start[d] + p``.  No scatter ops — XLA's
    scatter expander materializes full-buffer u32/f32 temporaries (a
    measured dominant memory cost; EXPERIMENTS.md §Perf kimi-2).  Stable
    order within a destination mirrors Spark's deterministic partitioning.
    The returned (dest, pos, in_cap) triple lets callers invert the pack
    with another pure gather.
    """
    cap_in = dest.shape[0]
    dest_m = jnp.where(valid, dest, num_dest)  # invalid -> sentinel bucket
    order = jnp.argsort(dest_m, stable=True)
    sorted_dest = dest_m[order]
    # start offset of each destination bucket in the sorted stream
    start = jnp.searchsorted(sorted_dest, jnp.arange(num_dest + 1))
    counts = start[1:] - start[:-1]           # true per-dest counts
    dropped = jnp.sum(jnp.maximum(counts - capacity, 0))
    counts_c = jnp.minimum(counts, capacity)
    # output slot (d, p) <- sorted row start[d] + p   (gather indices)
    src_pos = start[:num_dest, None] + jnp.arange(capacity)[None, :]
    slot_ok = jnp.arange(capacity)[None, :] < counts_c[:, None]
    src_pos = jnp.where(slot_ok, src_pos, cap_in)       # sentinel row

    def build(leaf):
        sorted_leaf = jnp.take(leaf, order, axis=0, mode="clip")
        ext = jnp.concatenate(
            [sorted_leaf,
             jnp.zeros((1,) + leaf.shape[1:], leaf.dtype)], axis=0)
        return jnp.take(ext, src_pos.reshape(-1), axis=0, mode="clip").reshape(
            (num_dest, capacity) + leaf.shape[1:])

    buffer = jax.tree.map(build, records)
    # per-record placement in original order (inverse permutation)
    pos_sorted = jnp.arange(cap_in) - start[
        jnp.clip(sorted_dest, 0, num_dest)]
    in_cap_sorted = (pos_sorted < capacity) & (sorted_dest < num_dest)
    inv = jnp.argsort(order)                  # order is a permutation
    pos = jnp.take(pos_sorted, inv, mode="clip")
    in_cap = jnp.take(in_cap_sorted, inv, mode="clip")
    return PackResult(buffer=buffer, counts=counts_c, dropped=dropped,
                      dest=jnp.where(valid, dest, num_dest), pos=pos,
                      in_cap=in_cap)


def unpack_gather(packed_flat: jax.Array, pack: PackResult,
                  capacity: int) -> jax.Array:
    """Inverse of _pack_by_dest for one leaf: returns, per input record,
    the row of ``packed_flat`` ([num_dest * capacity, ...], sentinel-safe)
    it was packed into (zeros for dropped records).  Pure gather."""
    n_slots = packed_flat.shape[0]
    ext = jnp.concatenate(
        [packed_flat,
         jnp.zeros((1,) + packed_flat.shape[1:], packed_flat.dtype)],
        axis=0)
    idx = jnp.where(pack.in_cap, pack.dest * capacity + pack.pos, n_slots)
    return jnp.take(ext, idx, axis=0, mode="clip")


def shuffle_partition(
    part: Partition,
    keys: jax.Array,
    axis_name: str,
    axis_size: int,
    capacity: Optional[int] = None,
    partitioner: Callable[[jax.Array], jax.Array] = hash_keys,
    dest: Optional[jax.Array] = None,
) -> ShuffleResult:
    """shard_map-interior repartitionBy over ``axis_name``.

    ``keys``: int array [capacity_in] (entries beyond ``part.count`` are
    ignored).  Output partition capacity is ``axis_size * capacity`` (every
    source may contribute up to ``capacity`` records).  With ``capacity ==
    part.capacity`` the shuffle is lossless (a single source can never
    overflow a destination).  ``dest`` (int32 [capacity_in], values in
    ``[0, axis_size)``) overrides the ``partitioner(keys) % axis_size``
    destination map entirely — the hook the salted skew path uses to
    spread a hot key over several shards (:func:`salted_dest`).
    """
    cap_in = part.capacity
    capacity = capacity or cap_in
    if dest is None:
        dest = (partitioner(keys) % jnp.uint32(axis_size)).astype(jnp.int32)
    valid = part.mask()
    pack = _pack_by_dest(part.records, dest, valid, axis_size, capacity)
    buf, send_counts, dropped = pack.buffer, pack.counts, pack.dropped
    recv = jax.tree.map(
        lambda l: jax.lax.all_to_all(
            l, axis_name, split_axis=0, concat_axis=0, tiled=False),
        buf)
    # recv leaf shape: [axis_size, capacity, ...] — row s = from source s.
    recv_counts = jax.lax.all_to_all(
        send_counts.reshape(axis_size, 1), axis_name,
        split_axis=0, concat_axis=0).reshape(axis_size)
    # Compact: valid slots are the first recv_counts[s] of each source row.
    slot_valid = (jnp.arange(capacity)[None, :] <
                  recv_counts[:, None]).reshape(-1)
    order = jnp.argsort(~slot_valid, stable=True)

    def compact(leaf):
        flat = leaf.reshape((axis_size * capacity,) + leaf.shape[2:])
        return jnp.take(flat, order, axis=0, mode="clip")

    out = make_partition(jax.tree.map(compact, recv),
                         jnp.sum(recv_counts).astype(jnp.int32))
    return ShuffleResult(part=out, dropped=dropped, send_counts=send_counts)


def grouped_all_to_all(
    x: jax.Array,
    group_ids: jax.Array,
    axis_name: str,
    axis_size: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array]:
    """Structured variant used by MoE dispatch: rows of ``x`` [tokens, d] are
    routed to shard ``group_ids[i] % axis_size`` keeping the [source, slot]
    structure (no compaction).  Returns (recv [axis_size, capacity, d],
    recv_counts [axis_size]).  This is repartitionBy with an identity
    partitioner — the chromosome-wise grouping of Listing 3, re-used as
    expert dispatch (DESIGN.md §3.2).
    """
    part = make_partition((x,), jnp.int32(x.shape[0]))
    dest = (group_ids % axis_size).astype(jnp.int32)
    pack = _pack_by_dest(part.records, dest, part.mask(), axis_size,
                         capacity)
    recv = jax.lax.all_to_all(pack.buffer[0], axis_name, split_axis=0,
                              concat_axis=0)
    recv_counts = jax.lax.all_to_all(
        pack.counts.reshape(axis_size, 1), axis_name,
        split_axis=0, concat_axis=0).reshape(axis_size)
    return recv, recv_counts
