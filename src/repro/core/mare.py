"""MaRe: the user-facing driver API (paper Listings 1-3, JAX edition).

.. code-block:: python

    result = (MaRe(dataset)
        .map(input_mount=TextFile("/dna"), output_mount=TextFile("/count"),
             image="posix", command="grep -c [GC]")
        .reduce(input_mount=TextFile("/counts"),
                output_mount=TextFile("/sum"),
                image="posix", command="awk-sum")
        .collect())

Semantics match the paper: ``map`` applies a container to each partition
(single stage, no shuffle); ``reduce`` aggregates all partitions down to one
via a depth-K tree (K shuffles, combiner must be associative+commutative;
default K=2); ``repartition_by`` co-locates records by key (hash shuffle).
Ops are pulled from the registry by image name; a ``command`` string is
passed to the image factory (images interpret their own command grammar,
like a container ENTRYPOINT).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core import dataset as ds_lib
from repro.core.container import (ContainerOp, Partition, Registry,
                                  DEFAULT_REGISTRY, make_partition)
from repro.core.dataset import ShardedDataset
from repro.core.mounts import Mount
from repro.core.plan import Plan, execute_map_stage, _apply_chain
from repro.core.shuffle import shuffle_partition
from repro.core.tree_reduce import tree_reduce_partition


def _resolve_op(image: Optional[str], op: Optional[ContainerOp],
                command: str, registry: Registry,
                input_mount: Optional[Mount],
                output_mount: Optional[Mount], **params: Any) -> ContainerOp:
    if op is None:
        if image is None:
            raise ValueError("either `image` or `op` must be given")
        op = registry.pull(image, command=command, **params)
    if input_mount is not None or output_mount is not None:
        op = op.with_mounts(input_mount, output_mount, command)
    return op


class MaRe:
    """Driver handle over a :class:`ShardedDataset` with a lazy map plan."""

    def __init__(self, data: Any, mesh: Optional[Mesh] = None,
                 axis: str = "data",
                 registry: Registry = DEFAULT_REGISTRY,
                 _plan: Optional[Plan] = None):
        if isinstance(data, ShardedDataset):
            self.dataset = data
        else:
            if mesh is None:
                mesh = compat.make_mesh((jax.device_count(),), (axis,))
            self.dataset = ds_lib.from_host(data, mesh, axis)
        self.registry = registry
        self.plan = _plan or Plan()

    @classmethod
    def from_source(cls, source: Any, mesh: Optional[Mesh] = None,
                    axis: str = "data", capacity: Optional[int] = None,
                    width: Optional[int] = None,
                    workers: Optional[int] = None,
                    registry: Registry = DEFAULT_REGISTRY) -> "MaRe":
        """Ingest a :class:`repro.io.DataSource` (storage backend + format
        + split plan) into a sharded dataset via the parallel fetch pool —
        the paper's heterogeneous-storage entry point (Fig. 5)."""
        from repro.io.ingest import ingest  # deferred: io depends on core
        if mesh is None:
            mesh = compat.make_mesh((jax.device_count(),), (axis,))
        ds = ingest(source, mesh, axis=axis, capacity=capacity,
                    width=width, workers=workers)
        return cls(ds, registry=registry)

    # -- primitives ---------------------------------------------------------

    def map(self, *, image: Optional[str] = None,
            op: Optional[ContainerOp] = None,
            command: str = "",
            inputMountPoint: Optional[Mount] = None,
            outputMountPoint: Optional[Mount] = None,
            input_mount: Optional[Mount] = None,
            output_mount: Optional[Mount] = None,
            **params: Any) -> "MaRe":
        """Apply a container to each partition (lazy; fused into one stage).

        Accepts both paper spelling (``inputMountPoint``) and snake_case.
        """
        op = _resolve_op(image, op, command, self.registry,
                         input_mount or inputMountPoint,
                         output_mount or outputMountPoint, **params)
        out = MaRe(self.dataset, registry=self.registry,
                   _plan=self.plan.then(op))
        return out

    def reduce(self, *, image: Optional[str] = None,
               op: Optional[ContainerOp] = None,
               command: str = "",
               inputMountPoint: Optional[Mount] = None,
               outputMountPoint: Optional[Mount] = None,
               input_mount: Optional[Mount] = None,
               output_mount: Optional[Mount] = None,
               depth: int = 2,
               **params: Any) -> "MaRe":
        """K-level tree aggregation of all partitions to one (paper K=2).

        Runs the pending map chain and the reduce tree in a single
        ``shard_map`` computation; the result is replicated on every shard
        (single-partition RDD')."""
        op = _resolve_op(image, op, command, self.registry,
                         input_mount or inputMountPoint,
                         output_mount or outputMountPoint, **params)
        if not op.associative_commutative:
            raise ValueError(
                f"reduce combiner {op.name} is not marked associative+"
                "commutative (paper: required for tree-reduce consistency)")
        ds = self.dataset
        mesh, axis = ds.mesh, ds.axis
        axis_size = ds.num_shards
        map_ops = self.plan.ops

        def stage(records, counts):
            part = _apply_chain(map_ops, records, counts[0])
            part = tree_reduce_partition(
                part, op, axis_name=axis, axis_size=axis_size, depth=depth)
            return part.records, part.count[None]

        fn = jax.jit(compat.shard_map(
            stage, mesh=mesh, in_specs=(P(axis), P(axis)),
            out_specs=(P(axis), P(axis))))
        out_records, out_counts = fn(ds.records, ds.counts)
        # Result is replicated; present it as a 1-logical-partition dataset.
        reduced = ShardedDataset(records=out_records, counts=out_counts,
                                 mesh=mesh, axis=axis)
        return MaRe(reduced, registry=self.registry)

    def repartition_by(self, key_by: Callable[[Any], jax.Array],
                       capacity: Optional[int] = None,
                       num_partitions: Optional[int] = None) -> "MaRe":
        """Hash-shuffle records so equal keys share a partition.

        ``key_by(records) -> int array [capacity]`` (vectorized keyBy over
        the record pytree).  ``num_partitions`` other than the axis size is
        emulated by keying into ``num_partitions`` buckets spread over the
        axis (paper sets it to #workers, which is the axis size here).
        """
        ds = self.dataset
        mesh, axis = ds.mesh, ds.axis
        axis_size = ds.num_shards
        map_ops = self.plan.ops

        def stage(records, counts):
            part = _apply_chain(map_ops, records, counts[0])
            keys = key_by(part.records)
            if num_partitions is not None and num_partitions != axis_size:
                keys = keys % num_partitions
            res = shuffle_partition(part, keys, axis_name=axis,
                                    axis_size=axis_size, capacity=capacity)
            return (res.part.records, res.part.count[None],
                    res.dropped[None])

        fn = jax.jit(compat.shard_map(
            stage, mesh=mesh, in_specs=(P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(axis))))
        out_records, out_counts, dropped = fn(ds.records, ds.counts)
        total_dropped = int(jax.device_get(dropped).sum())
        if total_dropped:
            raise RuntimeError(
                f"repartition_by overflow: {total_dropped} records dropped; "
                "raise `capacity` (paper analogue: partition exceeded tmpfs "
                "capacity — fall back to a larger staging area)")
        out = ShardedDataset(records=out_records, counts=out_counts,
                             mesh=mesh, axis=axis)
        return MaRe(out, registry=self.registry)

    # Paper spelling alias
    repartitionBy = repartition_by

    # -- actions ------------------------------------------------------------

    def cache(self) -> "MaRe":
        """Materialize the pending map chain (RDD.cache analogue)."""
        return MaRe(execute_map_stage(self.dataset, self.plan),
                    registry=self.registry)

    def collect(self) -> Any:
        """Run pending stages and gather valid records to host."""
        ds = execute_map_stage(self.dataset, self.plan)
        out = ds_lib.collect(ds)
        return out

    def collect_first_shard(self) -> Any:
        """For reduced (replicated) results: shard 0's valid records."""
        ds = execute_map_stage(self.dataset, self.plan)
        counts = jax.device_get(ds.counts)
        n = ds.num_shards

        def first(leaf):
            host = jax.device_get(leaf)
            cap = host.shape[0] // n  # per-leaf shard-0 block
            return host[:min(cap, int(counts[0]))]

        return jax.tree.map(first, ds.records)

    def num_partitions(self) -> int:
        return self.dataset.num_shards

    def describe(self) -> str:
        return (f"MaRe(shards={self.dataset.num_shards}, "
                f"cap={self.dataset.capacity}, stage=[{self.plan.describe()}])")
