"""MaRe: the user-facing driver API (paper Listings 1-3, JAX edition).

.. code-block:: python

    result = (MaRe(dataset)
        .map(input_mount=TextFile("/dna"), output_mount=TextFile("/count"),
             image="posix", command="grep -c [GC]")
        .reduce(input_mount=TextFile("/counts"),
                output_mount=TextFile("/sum"),
                image="posix", command="awk-sum")
        .collect())

Semantics match the paper: ``map`` applies a container to each partition
(single stage, no shuffle); ``reduce`` aggregates all partitions down to one
via a depth-K tree (K shuffles, combiner must be associative+commutative;
default K=2); ``repartition_by`` co-locates records by key (hash shuffle).
Ops are pulled from the registry by image name; a ``command`` string is
passed to the image factory (images interpret their own command grammar,
like a container ENTRYPOINT).

All primitives are **lazy**: they append stages to a logical plan.  MaRe
itself is a thin facade — an action (``collect`` / ``persist`` /
``dataset``) hands the chain to the runtime layer
(:mod:`repro.runtime`): the planner lowers it into a single memoized
``shard_map`` program, and the executor dispatches it, reusing any plan
*prefix* previously materialized with :meth:`MaRe.persist`
(lineage-keyed cache), syncing stage counters once, and appending an
:class:`~repro.runtime.reports.ActionReport` to the shared per-chain
history (:meth:`MaRe.report` / :meth:`MaRe.reports`).

There is ONE action signature: ``collect(shard=..., asynchronous=...,
label=...)``.  The former variants (``collect_async``,
``collect_first_shard``, ``collect_first_shard_async``) and the
``last_diagnostics`` dict survive as deprecated shims, as do the
paper-spelling camelCase aliases (``repartitionBy``, ``reduceByKey``,
``inputMountPoint=`` / ``outputMountPoint=``) — all centralized in
:data:`PAPER_METHOD_ALIASES` / :data:`PAPER_KWARG_ALIASES` and applied
by the :func:`paper_aliases` class decorator, each warning once per
process (:mod:`repro.deprecations`).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, TYPE_CHECKING

import jax
from jax.sharding import Mesh

from repro import compat
from repro.core import dataset as ds_lib
from repro.core import planner as planner_lib
from repro.core.container import (ContainerOp, Registry, DEFAULT_REGISTRY)
from repro.core.dataset import ShardedDataset
from repro.core.mounts import Mount
from repro.core.plan import (KEYED_MONOIDS, Plan, StageState, infer_stage,
                             infer_states)
from repro.core.schema import schema_of_records
from repro.deprecations import warn_once

if TYPE_CHECKING:  # runtime imported lazily: core must not require
    from repro.runtime.executor import ActionHandle, Executor  # noqa: F401
    from repro.runtime.reports import ActionReport, ReportLog  # noqa: F401


#: Deprecated camelCase method -> canonical snake_case method, applied to
#: MaRe by :func:`paper_aliases` (the ONE place paper spellings live).
PAPER_METHOD_ALIASES: Dict[str, str] = {
    "repartitionBy": "repartition_by",
    "reduceByKey": "reduce_by_key",
}

#: Deprecated camelCase kwarg -> canonical kwarg, translated on the
#: methods listed in :data:`PAPER_KWARG_METHODS`.
PAPER_KWARG_ALIASES: Dict[str, str] = {
    "inputMountPoint": "input_mount",
    "outputMountPoint": "output_mount",
}

#: Methods whose kwargs go through the alias table.
PAPER_KWARG_METHODS = ("map", "reduce")


def _alias_method(camel: str, snake: str) -> Callable:
    def shim(self, *args: Any, **kwargs: Any):
        warn_once(("method", camel),
                  f"MaRe.{camel}() is deprecated; use MaRe.{snake}() "
                  f"(paper-spelling alias, forwarded unchanged)")
        return getattr(self, snake)(*args, **kwargs)

    shim.__name__ = camel
    shim.__qualname__ = f"MaRe.{camel}"
    shim.__doc__ = (f"Deprecated paper spelling of :meth:`{snake}` "
                    f"(warns once, forwards everything).")
    return shim


def _translate_kwargs(fn: Callable) -> Callable:
    @functools.wraps(fn)
    def wrapper(self, *args: Any, **kwargs: Any):
        for camel, snake in PAPER_KWARG_ALIASES.items():
            if camel in kwargs:
                if kwargs.get(snake) is not None:
                    raise TypeError(
                        f"{fn.__name__}() got both {snake!r} and its "
                        f"deprecated alias {camel!r}")
                warn_once(("kwarg", camel),
                          f"{camel}= is deprecated; use {snake}= "
                          f"(paper-spelling kwarg alias)")
                kwargs[snake] = kwargs.pop(camel)
        return fn(self, *args, **kwargs)

    return wrapper


def paper_aliases(cls):
    """Class decorator installing every paper-spelling alias from the
    tables above — ad-hoc per-method aliasing is not allowed; add new
    spellings to the tables instead."""
    for camel, snake in PAPER_METHOD_ALIASES.items():
        setattr(cls, camel, _alias_method(camel, snake))
    for name in PAPER_KWARG_METHODS:
        setattr(cls, name, _translate_kwargs(getattr(cls, name)))
    return cls


#: Per-shard finalizer cache: ``collect(shard=i)`` must hand the runtime
#: the SAME callable object for a given ``i`` every time — finalize
#: identity is part of the cross-session batch key, so two sessions
#: asking for shard 0 of the same lineage coalesce into one dispatch.
_SHARD_FINALIZERS: Dict[int, Callable] = {}


def _finalizer(shard: Optional[int]) -> Callable:
    """The dataset->host callable for ``collect(shard=...)``: whole-dataset
    gather when ``shard`` is None, else a cached per-shard slicer."""
    if shard is None:
        return ds_lib.collect
    fn = _SHARD_FINALIZERS.get(shard)
    if fn is None:
        fn = _SHARD_FINALIZERS[shard] = functools.partial(
            ds_lib.collect_shard, shard=shard)
    return fn


def _resolve_monoid(image: str, command: str, registry: Registry) -> str:
    """Keyed-reduce combiner via the paper's container spelling: the image
    is pulled and its *manifest* must declare a monoid (``toolbox/sum``
    and the posix ``awk-sum`` command declare ``monoid="sum"``)."""
    op = registry.pull(image, command=command)
    monoid = op.contract.monoid if op.contract is not None else None
    if monoid is None:
        raise ValueError(
            f"image {image!r} (command {command!r}) is not a known "
            f"keyed-reduce monoid: its manifest declares no `monoid`; "
            f"use op= directly ({KEYED_MONOIDS}) or an image whose "
            f"manifest declares one (e.g. 'toolbox/sum', or 'ubuntu' "
            f"with command 'awk-sum')")
    return monoid


def _resolve_op(image: Optional[str], op: Optional[ContainerOp],
                command: str, registry: Registry,
                input_mount: Optional[Mount],
                output_mount: Optional[Mount], **params: Any) -> ContainerOp:
    if op is None:
        if image is None:
            raise ValueError("either `image` or `op` must be given")
        op = registry.pull(image, command=command, **params)
    if input_mount is not None or output_mount is not None:
        op = op.with_mounts(input_mount, output_mount, command)
    return op


@paper_aliases
class MaRe:
    """Driver handle over a :class:`ShardedDataset` with a lazy stage plan.

    ``plan_cache`` overrides the process-wide compile cache (mostly for
    tests/benchmarks); ``fuse=False`` forces stage-at-a-time execution
    (each stage its own program — the pre-planner schedule); ``executor``
    overrides the process-wide runtime engine (its materialization cache
    is what ``persist()`` feeds).
    """

    def __init__(self, data: Any, mesh: Optional[Mesh] = None,
                 axis: str = "data",
                 registry: Registry = DEFAULT_REGISTRY,
                 _plan: Optional[Plan] = None,
                 plan_cache: Optional["planner_lib.PlanCache"] = None,
                 fuse: bool = True,
                 executor: Optional[Executor] = None,
                 _reports: Optional[ReportLog] = None):
        # deferred: repro.runtime depends on core submodules, so importing
        # it at core-module import time would be circular either way round
        from repro.runtime.executor import DEFAULT_EXECUTOR
        from repro.runtime.reports import ReportLog
        if isinstance(data, ShardedDataset):
            self._dataset = data
        else:
            if mesh is None:
                mesh = compat.make_mesh((jax.device_count(),), (axis,))
            self._dataset = ds_lib.from_host(data, mesh, axis)
        self.registry = registry
        self.plan = _plan or Plan()
        self.plan_cache = plan_cache
        self.fuse = fuse
        self.executor = executor if executor is not None else DEFAULT_EXECUTOR
        # Per-chain action history (shared across handles forked from this
        # one): every action appends an ActionReport here AND to the
        # executor's global history.  Surfaced via report()/reports().
        self._report_log = _reports if _reports is not None else ReportLog()
        #: Inferred StageState per stage boundary (build-time type check);
        #: computed in _chain, reset when the plan materializes.
        self._states: Optional[list] = None

    @classmethod
    def from_source(cls, source: Any, mesh: Optional[Mesh] = None,
                    axis: str = "data", capacity: Optional[int] = None,
                    width: Optional[int] = None,
                    workers: Optional[int] = None,
                    registry: Registry = DEFAULT_REGISTRY,
                    executor: Optional[Executor] = None,
                    parser: str = "vectorized") -> "MaRe":
        """Ingest a :class:`repro.io.DataSource` (storage backend + format
        + split plan) into a sharded dataset via the parallel fetch pool —
        the paper's heterogeneous-storage entry point (Fig. 5).
        ``parser`` selects the framing path: ``"vectorized"`` columnar
        :class:`~repro.io.formats.RecordBatch` (default) or the
        ``"legacy"`` per-line oracle it is property-tested against."""
        from repro.io.ingest import ingest  # deferred: io depends on core
        if mesh is None:
            mesh = compat.make_mesh((jax.device_count(),), (axis,))
        ds = ingest(source, mesh, axis=axis, capacity=capacity,
                    width=width, workers=workers, parser=parser)
        return cls(ds, registry=registry, executor=executor)

    # -- reports -------------------------------------------------------------

    def report(self) -> Optional["ActionReport"]:
        """The NEWEST :class:`~repro.runtime.reports.ActionReport` on this
        chain (None before the first action).  ``report().diagnostics``
        is the per-stage counter dict; ``report().phases`` the wall
        breakdown."""
        return self._report_log.latest

    def reports(self) -> "ReportLog":
        """The chain's full action history (shared across forked handles):
        a :class:`~repro.runtime.reports.ReportLog` — iterate, index,
        ``total(counter)``, ``summary()``."""
        return self._report_log

    @property
    def last_diagnostics(self) -> dict:
        """Deprecated: counter totals of the newest action.  Use
        ``report().diagnostics`` (and ``reports()`` for history)."""
        warn_once(("property", "last_diagnostics"),
                  "MaRe.last_diagnostics is deprecated; use "
                  "MaRe.report().diagnostics (reports() for history)")
        latest = self.report()
        return latest.diagnostics if latest is not None else {}

    def _initial_state(self) -> StageState:
        ds = self._dataset
        return StageState(schema=schema_of_records(ds.records),
                          capacity=ds.capacity, num_shards=ds.num_shards)

    def _stage_states(self) -> list:
        """Inferred [initial, after-stage-0, ...] states for the pending
        plan — the build-time type check (raises PlanTypeError)."""
        if self._states is None:
            self._states = infer_states(self.plan, self._initial_state())
        return self._states

    def _chain(self, plan: Plan) -> "MaRe":
        m = MaRe(self._dataset, registry=self.registry, _plan=plan,
                 plan_cache=self.plan_cache, fuse=self.fuse,
                 executor=self.executor, _reports=self._report_log)
        # type-check at BUILD time, incrementally: every primitive either
        # appends one stage or extends the trailing MapStage, so the
        # parent's inferred states are a valid prefix up to the new plan's
        # last stage — only that stage is (re-)inferred here, keeping
        # chain construction O(1) per call instead of O(stages).
        prefix = self._stage_states()[:len(plan.stages)]
        last = len(plan.stages) - 1
        m._states = prefix + [infer_stage(plan.stages[last], prefix[-1],
                                          last)]
        return m

    def _materialize(self, label: Optional[str] = None) -> ShardedDataset:
        """Run all pending stages through the runtime executor: one fused
        program for the suffix not already materialized in the lineage
        cache, one counter sync, one appended ActionReport."""
        if not self.plan.empty:
            self._dataset, _ = self.executor.run(
                self._dataset, self.plan, fuse=self.fuse,
                plan_cache=self.plan_cache, reports=self._report_log,
                label=label)
            self.plan = Plan()
            self._states = None
        else:
            self.executor.ensure_lineage(self._dataset)
        return self._dataset

    @property
    def dataset(self) -> ShardedDataset:
        """The materialized dataset (triggers execution of pending stages)."""
        return self._materialize()

    # -- primitives ---------------------------------------------------------

    def map(self, *, image: Optional[str] = None,
            op: Optional[ContainerOp] = None,
            command: str = "",
            input_mount: Optional[Mount] = None,
            output_mount: Optional[Mount] = None,
            **params: Any) -> "MaRe":
        """Apply a container to each partition (lazy; fused into one stage).

        The paper spelling (``inputMountPoint=`` / ``outputMountPoint=``)
        is accepted as a deprecated alias via :func:`paper_aliases`.
        """
        op = _resolve_op(image, op, command, self.registry,
                         input_mount, output_mount, **params)
        return self._chain(self.plan.then(op))

    def reduce(self, *, image: Optional[str] = None,
               op: Optional[ContainerOp] = None,
               command: str = "",
               input_mount: Optional[Mount] = None,
               output_mount: Optional[Mount] = None,
               depth: int = 2,
               **params: Any) -> "MaRe":
        """K-level tree aggregation of all partitions to one (paper K=2).

        Lazy: appends a reduce stage; the pending map chain, the reduce
        tree and any upstream shuffles run in a single ``shard_map``
        program at action time.  The result is replicated on every shard
        (single-partition RDD')."""
        op = _resolve_op(image, op, command, self.registry,
                         input_mount, output_mount, **params)
        if not op.associative_commutative:
            raise ValueError(
                f"reduce combiner {op.name} is not marked associative+"
                "commutative (paper: required for tree-reduce consistency)")
        return self._chain(self.plan.then_reduce(op, depth))

    def repartition_by(self, key_by: Callable[[Any], jax.Array],
                       capacity: Optional[int] = None,
                       num_partitions: Optional[int] = None) -> "MaRe":
        """Hash-shuffle records so equal keys share a partition (lazy).

        ``key_by(records) -> int array [capacity]`` (vectorized keyBy over
        the record pytree).  ``num_partitions`` other than the axis size is
        emulated by keying into ``num_partitions`` buckets spread over the
        axis (paper sets it to #workers, which is the axis size here).

        Capacity overflow (dropped records) raises ``RuntimeError`` at
        action time: the fused program returns per-shuffle drop counters
        as outputs, so a chain with K shuffles pays one host sync total
        instead of K.
        """
        return self._chain(self.plan.then_shuffle(
            key_by, capacity=capacity, num_partitions=num_partitions))

    def reduce_by_key(self, key_by: Callable[[Any], jax.Array], *,
                      num_keys: Optional[int] = None,
                      op: str = "sum",
                      value_by: Optional[Callable[[Any], Any]] = None,
                      image: Optional[str] = None,
                      command: str = "",
                      combiner: bool = True,
                      capacity: Optional[int] = None,
                      use_kernel: Optional[bool] = None,
                      salt: int = 1) -> "MaRe":
        """Grouped aggregation: fold records with equal keys (lazy).

        ``key_by(records) -> int array [capacity]`` computes a key per
        record; keys must lie in ``[0, num_keys)`` (the bounded key table —
        out-of-range keys raise ``RuntimeError`` at action time through
        the same one-sync error channel as shuffle overflow).  When the
        upstream image's manifest declares a ``key_space`` (e.g.
        ``kmer-stats``: ``4**k``), ``num_keys`` may be omitted and is
        inferred at plan time — and an explicit ``num_keys`` smaller than
        the declared key space fails at *build* time.  ``value_by``
        selects the value pytree to fold (default: the whole record
        pytree); ``op`` is the merge monoid (``sum`` / ``max`` / ``min``,
        associative+commutative by construction), or pass a container
        spelling (``image="toolbox/sum"``, or ``image="ubuntu",
        command="awk-sum"``) — the pulled image's *manifest* must declare
        the monoid, as in the paper's combiner listings.

        Execution fuses into the single program like every other stage:
        with ``combiner=True`` (default) each shard pre-aggregates per key
        **before** the hash exchange — the classic map-side combiner — so
        shuffle volume scales with distinct keys, not records, and the
        per-destination send capacity is the statically-known largest hash
        bucket.  The result partition on each shard holds the keys hashing
        to it as records ``(key, folded_values, record_count)``, compacted
        to the front.  The segment-reduce hot path autotunes between the
        tiled Pallas kernel and the fused/sorted/scatter jnp strategies
        per shape (``use_kernel=True/False`` forces the kernel/the plain
        scatter; ``REPRO_SEGMENT_KERNEL`` overrides the default; see
        docs/kernels.md).

        Skew: with ``combiner=False`` a hot key inflates every shard's
        statically-sized exchange buffer.  ``salt=S`` (S > 1) spreads
        each key's records over S consecutive shards and re-exchanges
        per-key partials in a second hop, shrinking buffers by ~S/2 on
        hot-key data (docs/architecture.md §keyed exchange).  After any
        action, ``report().diagnostics['stage<i>.max_send_count']`` is the
        tightest lossless ``capacity=`` observed — the feedback knob if
        the salted heuristic capacity ever overflows.  ``salt`` with
        ``combiner=True`` is rejected: the combiner already bounds the
        exchange by distinct keys, so salting could only add a hop.
        """
        if image is not None:
            op = _resolve_monoid(image, command, self.registry)
        if op not in KEYED_MONOIDS:
            raise ValueError(f"unknown reduce_by_key op {op!r}; expected "
                             f"one of {KEYED_MONOIDS}")
        if salt < 1:
            raise ValueError(f"salt must be >= 1, got {salt}")
        if salt > 1 and combiner:
            raise ValueError(
                "salt > 1 requires combiner=False: the map-side combiner "
                "already caps the exchange at one record per distinct key, "
                "so hot-key splitting has nothing to spread")
        if num_keys is None:
            num_keys = self._stage_states()[-1].key_space
            if num_keys is None:
                raise ValueError(
                    "num_keys not given and no upstream image manifest "
                    "declares a key_space to infer it from")
        if num_keys < 1:
            raise ValueError(f"num_keys must be >= 1, got {num_keys}")
        return self._chain(self.plan.then_keyed_reduce(
            key_by, op=op, num_keys=num_keys, value_by=value_by,
            combiner=combiner, capacity=capacity, use_kernel=use_kernel,
            salt=salt))

    # -- actions ------------------------------------------------------------

    def persist(self, tier: str = "device") -> "MaRe":
        """Materialize the pending plan and register the result in the
        runtime's lineage-keyed materialization cache (Spark
        ``RDD.persist`` analogue).

        ``tier="device"`` keeps the sharded arrays live on the mesh;
        ``tier="host"`` stores a host copy that is re-placed on a hit.
        The cache is budgeted LRU per tier (device evictions spill to
        host, host evictions drop — recomputable from lineage).  After
        ``persist()``, ANY handle whose plan prefix reaches this lineage
        node — including forks of an ancestor handle rebuilding the same
        stages — starts from the cached dataset and executes only the
        suffix.
        """
        ds = self._materialize()
        self.executor.persist(ds, tier=tier)
        return MaRe(ds, registry=self.registry, plan_cache=self.plan_cache,
                    fuse=self.fuse, executor=self.executor,
                    _reports=self._report_log)

    def cache(self) -> "MaRe":
        """Sugar for :meth:`persist` (``tier="device"``).

        Pre-runtime, ``cache()`` was an eager materialize on one handle
        only; it now also registers the result under its lineage, so
        sibling handles sharing the prefix reuse it.
        """
        return self.persist(tier="device")

    def collect(self, *, shard: Optional[int] = None,
                asynchronous: bool = False,
                label: Optional[str] = None) -> Any:
        """THE action: run pending stages and gather valid records to host.

        ``shard=None`` gathers every shard's valid records
        (``RDD.collect``); ``shard=i`` slices one shard's block on device
        and ships only its valid rows — the right call for reduced
        (replicated) results, where ``shard=0`` replaces the old
        ``collect_first_shard``.

        ``asynchronous=False`` (default) blocks and returns host arrays.
        ``asynchronous=True`` dispatches on the executor's action thread
        behind its bounded queue and returns an
        :class:`~repro.runtime.executor.ActionHandle` (``.result()``
        blocks, ``.report`` carries the ActionReport).  Snapshot
        semantics: the handle's pending plan is captured at call time and
        this handle is left lazy (a later sync action on it re-resolves
        against the materialization cache — persist first if the prefix
        should be shared).

        ``label`` tags the action's report either way (e.g. ``"wave 3"``
        on the wave path, query names in interactive sessions).
        """
        if shard is not None and not (0 <= shard
                                      < self._dataset.num_shards):
            raise ValueError(
                f"shard index {shard} out of range for "
                f"{self._dataset.num_shards}-shard dataset")
        finalize = _finalizer(shard)
        if not asynchronous:
            return finalize(self._materialize(label=label))
        return self.executor.submit_action(
            self._dataset, self.plan, finalize=finalize,
            fuse=self.fuse, plan_cache=self.plan_cache,
            reports=self._report_log, label=label)

    # -- deprecated action shims (one collect() signature replaces them) -----

    def collect_async(self, label: Optional[str] = None) -> ActionHandle:
        """Deprecated: use ``collect(asynchronous=True)``."""
        warn_once(("method", "collect_async"),
                  "MaRe.collect_async(label=...) is deprecated; use "
                  "MaRe.collect(asynchronous=True, label=...)")
        return self.collect(asynchronous=True, label=label)

    def collect_first_shard(self) -> Any:
        """Deprecated: use ``collect(shard=0)``."""
        warn_once(("method", "collect_first_shard"),
                  "MaRe.collect_first_shard() is deprecated; use "
                  "MaRe.collect(shard=0)")
        return self.collect(shard=0)

    def collect_first_shard_async(self, label: Optional[str] = None
                                  ) -> ActionHandle:
        """Deprecated: use ``collect(shard=0, asynchronous=True)``."""
        warn_once(("method", "collect_first_shard_async"),
                  "MaRe.collect_first_shard_async(label=...) is "
                  "deprecated; use MaRe.collect(shard=0, "
                  "asynchronous=True, label=...)")
        return self.collect(shard=0, asynchronous=True, label=label)

    def num_partitions(self) -> int:
        return self._dataset.num_shards

    # -- observability -------------------------------------------------------

    def trace_to(self, path: str) -> str:
        """Export everything the process-wide tracer has recorded as
        Chrome-trace JSON (load at https://ui.perfetto.dev) and return
        ``path``.  Recording must be on — wrap the session (or the
        interesting actions) in ``repro.obs.tracing()`` or call
        ``repro.obs.TRACER.start()`` first; the instrumentation itself
        is always present and costs one branch per site while off."""
        from repro.obs import TRACER
        return TRACER.export(path)

    def metrics(self) -> dict:
        """Snapshot of the process-wide metrics registry: cache hits and
        evictions per tier, compile-cache hits/misses, exchanged-record
        volume, dispatch-queue depth, per-phase wall histograms."""
        from repro.obs import METRICS
        return METRICS.snapshot()

    def describe(self) -> str:
        """Human-readable view of the pending stage DAG (no execution),
        annotated with the inferred record schema at every stage boundary
        (``{schema}#capacity``; ``?`` where an op without a manifest makes
        it unknown).  Stages whose lineage node is materialized in the
        runtime cache — i.e. the prefix an action would NOT re-execute —
        are marked ``[cached]``.  A ``counters=[...]`` section lists
        every diagnostic counter the fused program will emit (stage
        index + kind), i.e. what an action's report will contain before
        anything runs."""
        states = self._stage_states()
        cached, _ = self.executor.cached_prefix(self._dataset, self.plan)
        if self.plan.empty:
            chain = "<identity>"
        else:
            chain = " -> ".join(
                f"{st.describe()} : {state.describe()}"
                + (" [cached]" if i < cached else "")
                for i, (st, state) in enumerate(zip(self.plan.stages,
                                                    states[1:])))
        specs = self.plan.counter_specs()
        counters = (", counters=[" + ", ".join(
            f"stage{i}.{kind}" for i, kind in specs) + "]") if specs else ""
        return (f"MaRe(shards={self._dataset.num_shards}, "
                f"cap={self._dataset.capacity}, "
                f"schema={states[0].describe()}, "
                f"plan=[{chain}]{counters})")
