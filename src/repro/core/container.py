"""ContainerOps: the TPU analogue of MaRe's Docker-image transformations.

A Docker image in MaRe is a *named, versioned, self-contained tool* with
declared input/output mount points.  Here a :class:`ContainerOp` is a named,
versioned, self-contained **jittable transformation** over one partition,
with the same declared mounts.  The registry plays the role of the Docker
registry: ops are ``register``-ed under ``image:tag`` names and ``pull``-ed
by the driver (DESIGN.md §2 — delivery contract retained, kernel-namespace
isolation dropped: it has no TPU analogue).

A partition is a :class:`Partition` — a fixed-capacity pytree of record
arrays plus a dynamic valid-record count (SPMD requires static shapes, so
partitions are padded; `count` tracks validity, mirroring how MaRe staged a
variable number of records into a fixed tmpfs mount).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.manifests import Contract, ImageManifest
from repro.core.mounts import Mount


class Partition(NamedTuple):
    """One shard-local partition: padded records + valid count."""

    records: Any        # pytree of arrays, leading dim = capacity
    count: jax.Array    # int32 scalar, number of valid records

    @property
    def capacity(self) -> int:
        leaves = jax.tree.leaves(self.records)
        return int(leaves[0].shape[0]) if leaves else 0

    def mask(self) -> jax.Array:
        """Boolean [capacity] validity mask."""
        return jnp.arange(self.capacity) < self.count


def make_partition(records: Any, count: Optional[Any] = None) -> Partition:
    leaves = jax.tree.leaves(records)
    cap = leaves[0].shape[0] if leaves else 0
    if count is None:
        count = jnp.int32(cap)
    return Partition(records=records, count=jnp.asarray(count, jnp.int32))


@dataclasses.dataclass(frozen=True)
class ContainerOp:
    """A named transformation over one partition.

    ``fn(partition, **params) -> partition``.  ``image``/``tag`` give the
    registry identity; ``command`` records the originating command string
    (provenance — mirrors the paper's shell command field).  ``out_capacity``
    declares the static record capacity of the output partition (needed for
    SPMD shape inference; reducers must shrink, per the paper's requirement
    that reduce commands "always reduce the size of the partition").
    ``associative_commutative`` marks combiners that are safe for the
    K-level reduce tree (paper §1.2.2).

    ``manifest`` is the image's declarative contract (schemas, capacity
    transfer, monoid, command grammar); ``contract`` is that manifest
    resolved against this op's command + params at pull time — the record
    the planner type-checks at plan-build time.  Ops constructed directly
    (no registry) carry neither: the planner treats their output schema
    as unknown and falls back to execution-time checks only.
    """

    image: str
    fn: Callable[..., Partition]
    input_mount: Optional[Mount] = None
    output_mount: Optional[Mount] = None
    command: str = ""
    tag: str = "latest"
    out_capacity: Optional[int] = None
    associative_commutative: bool = False
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    manifest: Optional[ImageManifest] = None
    contract: Optional[Contract] = None

    @property
    def name(self) -> str:
        return f"{self.image}:{self.tag}"

    def __call__(self, part: Partition) -> Partition:
        out = self.fn(part, **self.params)
        if not isinstance(out, Partition):
            raise TypeError(
                f"container {self.name} must return a Partition, got "
                f"{type(out).__name__}")
        return out

    def with_mounts(self, input_mount: Mount, output_mount: Mount,
                    command: str = "") -> "ContainerOp":
        return dataclasses.replace(
            self, input_mount=input_mount, output_mount=output_mount,
            command=command or self.command)


class Registry:
    """Name -> ContainerOp factory (the "Docker registry")."""

    def __init__(self) -> None:
        self._images: Dict[str, Callable[..., ContainerOp]] = {}

    def register(self, image: str, tag: str = "latest"
                 ) -> Callable[[Callable[..., ContainerOp]],
                               Callable[..., ContainerOp]]:
        key = f"{image}:{tag}"

        def deco(factory: Callable[..., ContainerOp]):
            if key in self._images:
                raise ValueError(f"image {key} already registered")
            self._images[key] = factory
            return factory

        return deco

    def pull(self, image: str, **build_args: Any) -> ContainerOp:
        key = image if ":" in image else f"{image}:latest"
        if key not in self._images:
            raise KeyError(
                f"image {key!r} not found in registry; available: "
                f"{sorted(self._images)}")
        return self._images[key](**build_args)

    def images(self):
        return sorted(self._images)


#: Global default registry (like the Docker Hub default).
DEFAULT_REGISTRY = Registry()
register = DEFAULT_REGISTRY.register
pull = DEFAULT_REGISTRY.pull


def container_op(image: str, *, tag: str = "latest",
                 out_capacity: Optional[int] = None,
                 associative_commutative: bool = False,
                 manifest: Optional[ImageManifest] = None,
                 registry: Registry = DEFAULT_REGISTRY,
                 **default_params: Any):
    """Decorator: register ``fn(partition, **params) -> Partition``.

    The decorated function becomes an image factory: ``pull(image,
    **params)`` binds params and returns a :class:`ContainerOp`.

    When a ``manifest`` is given, the pull-time ``command`` string is
    parsed through its typed grammar (one central ``shlex``, typed args,
    pull-time errors for unknown commands / bad arguments) instead of
    reaching the implementation raw; a :class:`CommandSpec` may dispatch
    to its own implementation fn.  Without a manifest the legacy behavior
    holds: a non-empty command is passed to ``fn`` as the ``command``
    keyword, to be interpreted by the image itself.
    """

    def deco(fn: Callable[..., Partition]) -> Callable[..., ContainerOp]:
        def factory(**params: Any) -> ContainerOp:
            command = params.pop("command", "") or ""
            merged = dict(default_params)
            impl = fn
            assoc = associative_commutative
            contract = None
            if manifest is not None:
                spec, parsed = manifest.parse_command(command, image=image)
                merged.update(params)
                merged.update(parsed)   # the command IS the interface:
                #                         its argv wins over python kwargs
                if spec is not None:
                    if spec.fn is not None:
                        impl = spec.fn
                    if spec.associative_commutative is not None:
                        assoc = spec.associative_commutative
                elif command:
                    # manifest without a grammar: the command string is
                    # passed through for the image to interpret, exactly
                    # as for manifest-less images
                    merged["command"] = command
                contract = manifest.resolve(spec, merged, image=image,
                                            command=command)
            else:
                merged.update(params)
                if command:
                    merged["command"] = command
            return ContainerOp(
                image=image, tag=tag, fn=impl, command=command,
                out_capacity=merged.pop("out_capacity", out_capacity),
                associative_commutative=assoc,
                params=merged, manifest=manifest, contract=contract)

        registry.register(image, tag)(factory)
        factory.__name__ = fn.__name__
        factory.op = factory  # convenience alias
        return factory

    return deco
