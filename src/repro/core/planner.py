"""Whole-pipeline lowering: a stage DAG compiled to ONE shard_map program.

MaRe's headline advantage over workflow engines is locality and
interactive processing: a ``map -> repartitionBy -> map -> reduce`` chain
should execute as one locality-preserving job, not as a sequence of
independently launched stages (the DAG-vs-Hadoop lesson of the MapReduce
survey literature).  The planner delivers that on JAX:

* :func:`lower` turns a :class:`~repro.core.plan.Plan` into a single
  shard-interior function — map chains feed straight into their downstream
  shuffle/reduce with no intermediate ``ShardedDataset`` materialization.
* Shuffle overflow counters become **outputs of the same program** (one
  ``[num_shuffles]`` vector per shard) instead of a host sync per shuffle;
  the driver checks them once, after the single dispatch.
* Compiled programs are memoized in a :class:`PlanCache` keyed on
  (stage structure, record shapes/dtypes, mesh, axis), so re-running an
  identical pipeline — the paper's Fig. 6 interactive workflow, or every
  wave of an out-of-core run — pays zero re-trace and zero re-compile.

This module is *lowering only*: actually dispatching a program, syncing
its counters and recording diagnostics is the runtime layer's job
(:mod:`repro.runtime.executor`, which also reuses materialized plan
prefixes via the lineage cache).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.obs import METRICS, TRACER, timed
from repro.core.container import Partition, make_partition
from repro.core.dataset import ShardedDataset
from repro.core.plan import (KeyedReduceStage, MapStage, Plan, ReduceStage,
                             ShuffleStage, _apply_chain)
from repro.core.shuffle import (keyed_bucket_capacity, salted_dest,
                                shuffle_partition)
from repro.core.tree_reduce import (keyed_combine_partition,
                                    keyed_merge_partition,
                                    tree_reduce_partition)


@dataclasses.dataclass
class CompiledProgram:
    """A jitted whole-pipeline shard_map program plus its plan metadata."""

    fn: Callable[..., Tuple]      # (records, counts) -> outputs
    counters: Tuple[Tuple[int, str], ...]  # trailing counter-vector layout
    key: Hashable                 # cache key it was compiled under
    #: FLOP/byte estimate of the compiled HLO (launch/hlo_cost), filled
    #: by :meth:`ensure_compiled` when tracing is enabled.
    cost: Optional[Dict[str, float]] = None
    _aot: Optional[Callable[..., Tuple]] = None   # jax.stages.Compiled
    _aot_failed: bool = False

    def __call__(self, records: Any, counts: jax.Array) -> Tuple:
        if self._aot is not None:
            try:
                return self._aot(records, counts)
            except Exception:
                # e.g. an argument placed differently than the arrays the
                # program was AOT-compiled against; programs are pure, so
                # falling back to the lazy jit path re-runs safely
                self._aot = None
                self._aot_failed = True
        return self.fn(records, counts)

    @property
    def num_counters(self) -> int:
        return len(self.counters)

    def ensure_compiled(self, records: Any, counts: jax.Array,
                        phases: Optional[Dict[str, float]] = None) -> None:
        """AOT trace+compile against concrete arguments, once, so the
        executor can attribute lowering vs XLA-compile time as separate
        phases/spans instead of folding both into the first dispatch.

        The compiled executable is reused for every later dispatch (the
        plan cache keys on shapes/dtypes/mesh, so one signature per
        program).  Any AOT failure — e.g. an API gap on an old JAX —
        falls back permanently to the lazy ``jax.jit`` path, whose
        compile time then lands in the ``dispatch`` phase.
        """
        if self._aot is not None or self._aot_failed:
            return
        try:
            with timed("plan.lower", phases):
                lowered = self.fn.lower(records, counts)
            with timed("plan.compile", phases) as sp:
                compiled = lowered.compile()
                if TRACER.enabled:
                    # annotate the compile span with what the compiled
                    # program *does* per dispatch, not just how long the
                    # compile took
                    self.cost = _estimate_cost(compiled)
                    if self.cost:
                        sp.set(**self.cost)
        except Exception:
            self._aot_failed = True
            return
        self._aot = compiled


def _estimate_cost(compiled) -> Optional[Dict[str, float]]:
    """FLOP/byte estimate of a compiled program via the trip-count-aware
    HLO walker (launch/hlo_cost) — annotates compile spans so a trace
    shows not just how long a compile took but how much work the
    resulting program does per dispatch."""
    try:
        from repro.launch.hlo_cost import analyze
        a = analyze(compiled.as_text())
        return {"flops": float(a["flops"]), "bytes": float(a["bytes"]),
                "wire_bytes": float(a["wire_bytes"])}
    except Exception:
        return None


class PlanCache:
    """Compile cache: pipeline shape -> :class:`CompiledProgram` (LRU).

    ``misses`` counts programs traced+compiled; ``hits`` counts reuses.
    The jitted callable is reused by object identity, so JAX's own jit
    cache is hit too — a cache hit implies zero re-trace.  ``maxsize``
    bounds retained programs (keys pin jitted executables and, for
    shuffle stages, the ``key_by`` callable — unbounded growth would be
    a leak in long interactive sessions with churning pipeline shapes).
    """

    def __init__(self, maxsize: int = 128) -> None:
        self._programs: "OrderedDict[Hashable, CompiledProgram]" = \
            OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._programs)

    def stats(self) -> Dict[str, int]:
        return {"programs": len(self._programs), "hits": self.hits,
                "misses": self.misses}

    def clear(self) -> None:
        self._programs.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_compile(self, key: Hashable,
                       build: Callable[[], CompiledProgram],
                       phases: Optional[Dict[str, float]] = None
                       ) -> CompiledProgram:
        prog = self._programs.get(key)
        if prog is not None:
            self.hits += 1
            METRICS.counter("compile_cache.hits").inc()
            self._programs.move_to_end(key)
            return prog
        self.misses += 1
        METRICS.counter("compile_cache.misses").inc()
        with timed("plan.build", phases):
            prog = build()
        self._programs[key] = prog
        while len(self._programs) > self.maxsize:
            self._programs.popitem(last=False)
            self.evictions += 1
            METRICS.counter("compile_cache.evictions").inc()
        return prog


#: Process-wide default cache (MaRe actions and WaveRunner waves share it,
#: so a wave pipeline compiles once and amortizes across all waves).
DEFAULT_CACHE = PlanCache()


def program_key(plan: Plan, ds: ShardedDataset) -> Hashable:
    """Cache key: stage structure x input shapes/dtypes x mesh geometry."""
    leaves, treedef = jax.tree.flatten(ds.records)
    shapes = tuple((leaf.shape, str(leaf.dtype)) for leaf in leaves)
    return (plan.signature(), treedef, shapes,
            (tuple(ds.counts.shape), str(ds.counts.dtype)),
            ds.mesh, ds.axis)


def _apply_keyed(stage: KeyedReduceStage, part: Partition, axis: str,
                 axis_size: int) -> Tuple[Partition, List[jax.Array]]:
    """Shard-interior keyed aggregation: (combine) -> exchange -> merge.

    With the map-side combiner on, each shard first folds its records into
    at most ``num_keys`` per-key partials, so the exchange moves distinct
    keys, not records, and the per-destination send capacity is the
    statically known largest hash bucket (exact-lossless).  Combiner off
    ships raw ``(key, value, 1)`` records with the input capacity — the
    shuffle-volume baseline benchmarks compare against.

    Skew (``combiner=False, salt > 1``): a hot key makes the single-hop
    exchange degenerate — static SPMD forces ONE capacity for every
    (source, dest) pair, and a 90%-hot key forces it towards the full
    input capacity.  The salted path exchanges twice: hop 1 spreads each
    key's records over ``salt`` consecutive shards (``salted_dest``) at
    capacity ``~2 * cap_in / spread`` where ``spread = min(salt,
    axis_size)`` (a key cannot land on more destinations than exist),
    every shard merges what it received into per-key partials, and hop 2
    re-exchanges those partials combiner-style at the exact-lossless
    bucket capacity.  Buffer volume drops from ``axis_size * cap_in`` to
    ``axis_size * (2 * cap_in / spread + bucket_cap)`` rows per shard.  Hop 1's capacity is heuristic (2x
    headroom over the perfectly-spread hot key); adversarial key mixes
    can still overflow, which raises at action time with the
    ``max_send_count`` diagnostic as the tight retry capacity.

    Counters (order = ``stage_counter_kinds``): key_overflow,
    shuffle_dropped, exchanged_records, max_send_count (max per-dest send
    this shard; max-reduced across shards by the executor),
    exchange_buffer_rows (static per-shard buffer allocation).
    """
    keys = jnp.asarray(stage.key_by(part.records)).astype(jnp.int32)
    values = (stage.value_by(part.records) if stage.value_by is not None
              else part.records)
    valid = part.mask()
    num_keys = stage.num_keys
    salt = 1 if stage.combiner else max(1, int(stage.salt))
    if stage.combiner:
        send, overflow = keyed_combine_partition(
            keys, values, valid, num_keys, op=stage.op,
            use_kernel=stage.use_kernel)
        default_cap = keyed_bucket_capacity(num_keys, axis_size)
    else:
        in_range = (keys >= 0) & (keys < num_keys)
        ok = valid & in_range
        overflow = jnp.sum(valid & ~in_range).astype(jnp.int32)
        # compact surviving records to the front (count semantics)
        order = jnp.argsort(~ok, stable=True)
        recs = (jnp.take(keys, order, mode="clip"),
                jax.tree.map(lambda l: jnp.take(l, order, axis=0,
                                                mode="clip"), values),
                jnp.take(ok.astype(jnp.int32), order, mode="clip"))
        send = make_partition(recs, jnp.sum(ok).astype(jnp.int32))
        if salt > 1:
            # perfectly-spread hot key needs cap_in/spread; 2x headroom
            # for overlapping salt windows of distinct keys. A key can
            # never spread over more destinations than exist, so the
            # spread factor is capped at axis_size (salt > axis_size on
            # a small mesh must not shrink the buffer below what one
            # destination can receive).
            spread = min(salt, axis_size)
            default_cap = min(part.capacity,
                              2 * ((part.capacity + spread - 1) // spread))
        else:
            default_cap = part.capacity  # any shard may ship every record
    cap = stage.capacity or default_cap
    dest = (salted_dest(send.records[0], axis_size, salt)
            if salt > 1 else None)
    res = shuffle_partition(send, send.records[0], axis_name=axis,
                            axis_size=axis_size, capacity=cap, dest=dest)
    exchanged = jnp.sum(res.send_counts).astype(jnp.int32)
    max_send = jnp.max(res.send_counts).astype(jnp.int32)
    buffer_rows = axis_size * cap
    out, merge_overflow = keyed_merge_partition(
        res.part, num_keys, op=stage.op, use_kernel=stage.use_kernel)
    dropped = res.dropped
    if salt > 1:
        # hop 2: per-key partials back to their hash owner (combiner-style,
        # exact-lossless capacity) + final merge
        cap2 = keyed_bucket_capacity(num_keys, axis_size)
        res2 = shuffle_partition(out, out.records[0], axis_name=axis,
                                 axis_size=axis_size, capacity=cap2)
        exchanged = exchanged + jnp.sum(res2.send_counts).astype(jnp.int32)
        max_send = jnp.maximum(max_send,
                               jnp.max(res2.send_counts).astype(jnp.int32))
        buffer_rows += axis_size * cap2
        dropped = dropped + res2.dropped
        out, merge2_overflow = keyed_merge_partition(
            res2.part, num_keys, op=stage.op, use_kernel=stage.use_kernel)
        merge_overflow = merge_overflow + merge2_overflow
    return out, [(overflow + merge_overflow).astype(jnp.int32),
                 dropped.astype(jnp.int32), exchanged, max_send,
                 jnp.full((), buffer_rows, jnp.int32)]


def _validate_mount(mount, records, stage_idx: int, op_name: str,
                    which: str) -> None:
    """Execution-time mount validation with stage/image context (fires
    when plan-time inference couldn't check — unknown upstream schema)."""
    if mount is None:
        return
    try:
        mount.validate(records)
    except ValueError as e:
        raise ValueError(
            f"stage {stage_idx} (reduce[{op_name}]): {which} mount "
            f"validation failed: {e}") from e


def _apply_stage(stage, part: Partition, axis: str, axis_size: int,
                 stage_idx: int = 0
                 ) -> Tuple[Partition, List[jax.Array]]:
    """Shard-interior application of one stage; returns ``(part,
    counters)`` with counters matching ``stage_counter_kinds(stage)``."""
    if isinstance(stage, MapStage):
        return _apply_chain(stage.ops, part.records, part.count,
                            stage_idx), []
    if isinstance(stage, ShuffleStage):
        keys = stage.key_by(part.records)
        if (stage.num_partitions is not None
                and stage.num_partitions != axis_size):
            keys = keys % stage.num_partitions
        res = shuffle_partition(part, keys, axis_name=axis,
                                axis_size=axis_size,
                                capacity=stage.capacity)
        return res.part, [res.dropped.astype(jnp.int32)]
    if isinstance(stage, KeyedReduceStage):
        return _apply_keyed(stage, part, axis, axis_size)
    if isinstance(stage, ReduceStage):
        _validate_mount(stage.op.input_mount, part.records, stage_idx,
                        stage.op.name, "input")
        part = tree_reduce_partition(
            part, stage.op, axis_name=axis, axis_size=axis_size,
            depth=stage.depth)
        _validate_mount(stage.op.output_mount, part.records, stage_idx,
                        stage.op.name, "output")
        return part, []
    raise TypeError(f"unknown stage type {type(stage).__name__}")


def lower(plan: Plan, axis: str, axis_size: int):
    """Build the shard-interior function for a whole plan.

    Returns ``interior(records, counts) -> (records, counts[, counters])``
    where ``counters`` is an int32 vector laid out per
    ``plan.counter_specs()`` (omitted when the plan has none): shuffle
    drop counts, keyed-reduce key-table overflow, exchanged-record volume.
    """

    def interior(records, counts):
        part = make_partition(records, counts[0])
        counters: List[jax.Array] = []
        for i, stage in enumerate(plan.stages):
            part, cs = _apply_stage(stage, part, axis, axis_size, i)
            counters.extend(cs)
        outs = (part.records, part.count[None])
        if counters:
            outs = outs + (jnp.stack(counters).astype(jnp.int32),)
        return outs

    return interior


def _plan_uses_pallas(plan: Plan) -> bool:
    """Whether any keyed stage COULD resolve to the Pallas segment-reduce
    kernel (shard_map has no replication rule for pallas_call, so such a
    program must be built with the replication check off).  Conservative:
    with ``use_kernel=None`` the autotuner decides at trace time, so this
    answers "is tiled in the candidate set" (TPU backend, env force, or
    ``REPRO_SEGMENT_TUNE_PALLAS=1``), not "will tiled win"."""
    import os

    from repro.kernels.segment_reduce.ops import resolve_use_kernel
    tuner_may_pick = (jax.default_backend() == "tpu"
                      or os.environ.get("REPRO_SEGMENT_TUNE_PALLAS") == "1")
    return any(isinstance(st, KeyedReduceStage)
               and (resolve_use_kernel(st.use_kernel, st.op)
                    or (st.use_kernel is None and st.op == "sum"
                        and tuner_may_pick))
               for st in plan.stages)


def compile_plan(plan: Plan, ds: ShardedDataset,
                 cache: Optional[PlanCache] = None,
                 phases: Optional[Dict[str, float]] = None
                 ) -> CompiledProgram:
    """Memoized lowering of ``plan`` against ``ds``'s shapes and mesh.
    ``phases`` (when given) accumulates build time under ``plan.build``."""
    cache = cache if cache is not None else DEFAULT_CACHE
    mesh, axis = ds.mesh, ds.axis
    key = program_key(plan, ds)

    def build() -> CompiledProgram:
        counters = plan.counter_specs()
        interior = lower(plan, axis, int(mesh.shape[axis]))
        out_specs = (P(axis), P(axis)) + ((P(axis),) if counters else ())
        check_vma = False if _plan_uses_pallas(plan) else None
        fn = jax.jit(compat.shard_map(
            interior, mesh=mesh, in_specs=(P(axis), P(axis)),
            out_specs=out_specs, check_vma=check_vma))
        return CompiledProgram(fn=fn, counters=counters, key=key)

    return cache.get_or_compile(key, build, phases=phases)


# NOTE: action execution (dispatch, counter sync, prefix-cache reuse,
# per-action reports) lives in repro.runtime.executor — this module stops
# at lowering + program memoization.  ``repro.runtime.execute`` is the
# bare dispatch engine; ``repro.runtime.Executor`` the full one.
