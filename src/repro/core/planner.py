"""Whole-pipeline lowering: a stage DAG compiled to ONE shard_map program.

MaRe's headline advantage over workflow engines is locality and
interactive processing: a ``map -> repartitionBy -> map -> reduce`` chain
should execute as one locality-preserving job, not as a sequence of
independently launched stages (the DAG-vs-Hadoop lesson of the MapReduce
survey literature).  The planner delivers that on JAX:

* :func:`lower` turns a :class:`~repro.core.plan.Plan` into a single
  shard-interior function — map chains feed straight into their downstream
  shuffle/reduce with no intermediate ``ShardedDataset`` materialization.
* Shuffle overflow counters become **outputs of the same program** (one
  ``[num_shuffles]`` vector per shard) instead of a host sync per shuffle;
  the driver checks them once, after the single dispatch.
* Compiled programs are memoized in a :class:`PlanCache` keyed on
  (stage structure, record shapes/dtypes, mesh, axis), so re-running an
  identical pipeline — the paper's Fig. 6 interactive workflow, or every
  wave of an out-of-core run — pays zero re-trace and zero re-compile.

``execute(..., fuse=False)`` preserves the old stage-at-a-time schedule
(each stage its own program, overflow synced mid-pipeline) for debugging
and as the benchmark baseline (benchmarks/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.container import Partition, make_partition
from repro.core.dataset import ShardedDataset
from repro.core.plan import (MapStage, Plan, ReduceStage, ShuffleStage,
                             _apply_chain)
from repro.core.shuffle import shuffle_partition
from repro.core.tree_reduce import tree_reduce_partition


@dataclasses.dataclass
class CompiledProgram:
    """A jitted whole-pipeline shard_map program plus its plan metadata."""

    fn: Callable[..., Tuple]      # (records, counts) -> outputs
    num_shuffles: int             # trailing overflow-vector arity
    key: Hashable                 # cache key it was compiled under

    def __call__(self, records: Any, counts: jax.Array) -> Tuple:
        return self.fn(records, counts)


class PlanCache:
    """Compile cache: pipeline shape -> :class:`CompiledProgram` (LRU).

    ``misses`` counts programs traced+compiled; ``hits`` counts reuses.
    The jitted callable is reused by object identity, so JAX's own jit
    cache is hit too — a cache hit implies zero re-trace.  ``maxsize``
    bounds retained programs (keys pin jitted executables and, for
    shuffle stages, the ``key_by`` callable — unbounded growth would be
    a leak in long interactive sessions with churning pipeline shapes).
    """

    def __init__(self, maxsize: int = 128) -> None:
        self._programs: "OrderedDict[Hashable, CompiledProgram]" = \
            OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._programs)

    def stats(self) -> Dict[str, int]:
        return {"programs": len(self._programs), "hits": self.hits,
                "misses": self.misses}

    def clear(self) -> None:
        self._programs.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_or_compile(self, key: Hashable,
                       build: Callable[[], CompiledProgram]
                       ) -> CompiledProgram:
        prog = self._programs.get(key)
        if prog is not None:
            self.hits += 1
            self._programs.move_to_end(key)
            return prog
        self.misses += 1
        prog = build()
        self._programs[key] = prog
        while len(self._programs) > self.maxsize:
            self._programs.popitem(last=False)
            self.evictions += 1
        return prog


#: Process-wide default cache (MaRe actions and WaveRunner waves share it,
#: so a wave pipeline compiles once and amortizes across all waves).
DEFAULT_CACHE = PlanCache()


def program_key(plan: Plan, ds: ShardedDataset) -> Hashable:
    """Cache key: stage structure x input shapes/dtypes x mesh geometry."""
    leaves, treedef = jax.tree.flatten(ds.records)
    shapes = tuple((leaf.shape, str(leaf.dtype)) for leaf in leaves)
    return (plan.signature(), treedef, shapes,
            (tuple(ds.counts.shape), str(ds.counts.dtype)),
            ds.mesh, ds.axis)


def _apply_stage(stage, part: Partition, axis: str, axis_size: int
                 ) -> Tuple[Partition, Optional[jax.Array]]:
    """Shard-interior application of one stage; returns (part, dropped?)."""
    if isinstance(stage, MapStage):
        return _apply_chain(stage.ops, part.records, part.count), None
    if isinstance(stage, ShuffleStage):
        keys = stage.key_by(part.records)
        if (stage.num_partitions is not None
                and stage.num_partitions != axis_size):
            keys = keys % stage.num_partitions
        res = shuffle_partition(part, keys, axis_name=axis,
                                axis_size=axis_size,
                                capacity=stage.capacity)
        return res.part, res.dropped
    if isinstance(stage, ReduceStage):
        part = tree_reduce_partition(
            part, stage.op, axis_name=axis, axis_size=axis_size,
            depth=stage.depth)
        return part, None
    raise TypeError(f"unknown stage type {type(stage).__name__}")


def lower(plan: Plan, axis: str, axis_size: int):
    """Build the shard-interior function for a whole plan.

    Returns ``interior(records, counts) -> (records, counts[, dropped])``
    where ``dropped`` is a ``[num_shuffles]`` int32 vector (omitted when
    the plan has no shuffle stage).
    """

    def interior(records, counts):
        part = make_partition(records, counts[0])
        dropped: List[jax.Array] = []
        for stage in plan.stages:
            part, d = _apply_stage(stage, part, axis, axis_size)
            if d is not None:
                dropped.append(d)
        outs = (part.records, part.count[None])
        if dropped:
            outs = outs + (jnp.stack(dropped).astype(jnp.int32),)
        return outs

    return interior


def compile_plan(plan: Plan, ds: ShardedDataset,
                 cache: Optional[PlanCache] = None) -> CompiledProgram:
    """Memoized lowering of ``plan`` against ``ds``'s shapes and mesh."""
    cache = cache if cache is not None else DEFAULT_CACHE
    mesh, axis = ds.mesh, ds.axis
    key = program_key(plan, ds)

    def build() -> CompiledProgram:
        num_shuffles = plan.num_shuffles
        interior = lower(plan, axis, int(mesh.shape[axis]))
        out_specs = (P(axis), P(axis)) + ((P(axis),) if num_shuffles else ())
        fn = jax.jit(compat.shard_map(
            interior, mesh=mesh, in_specs=(P(axis), P(axis)),
            out_specs=out_specs))
        return CompiledProgram(fn=fn, num_shuffles=num_shuffles, key=key)

    return cache.get_or_compile(key, build)


def _check_overflow(dropped: jax.Array, num_shuffles: int,
                    num_shards: int) -> None:
    """One host sync for ALL shuffle stages, after the single dispatch."""
    per_stage = np.asarray(jax.device_get(dropped)).reshape(
        num_shards, num_shuffles).sum(axis=0)
    total = int(per_stage.sum())
    if total:
        worst = int(per_stage.argmax())
        raise RuntimeError(
            f"repartition_by overflow: {total} records dropped "
            f"(per shuffle stage: {per_stage.tolist()}, worst stage "
            f"#{worst}); raise `capacity` (paper analogue: partition "
            "exceeded tmpfs capacity — fall back to a larger staging area)")


def execute(ds: ShardedDataset, plan: Plan, *,
            cache: Optional[PlanCache] = None,
            fuse: bool = True) -> ShardedDataset:
    """Run a whole plan against a dataset.

    ``fuse=True`` (default): one compiled program for the entire DAG;
    shuffle-overflow counters come back as outputs of that program and
    are checked once.  ``fuse=False``: stage-at-a-time execution (each
    stage its own program, overflow synced after each shuffle) — the
    pre-planner schedule, kept for debugging and benchmarking.
    """
    if plan.empty:
        return ds
    if not fuse:
        for stage in plan.stages:
            ds = execute(ds, Plan(stages=(stage,)), cache=cache, fuse=True)
        return ds
    prog = compile_plan(plan, ds, cache)
    outs = prog(ds.records, ds.counts)
    if prog.num_shuffles:
        out_records, out_counts, dropped = outs
        _check_overflow(dropped, prog.num_shuffles, ds.num_shards)
    else:
        out_records, out_counts = outs
    return ShardedDataset(records=out_records, counts=out_counts,
                          mesh=ds.mesh, axis=ds.axis)
