"""Typed image manifests: the declarative contract of a container image.

The paper's container interface (§1.2.1, Listings 1-3) is a *convention*:
an image declares input/output mount points and a command string it knows
how to interpret.  An :class:`ImageManifest` makes that contract a machine-
checked record attached at registration:

* **record schemas** — declared input/output :class:`~repro.core.schema.
  Schema` pytrees (dtype + per-record shape, symbolic dims allowed);
* **capacity transfer** — ``out_capacity = f(in_capacity, env)`` where
  ``env`` is the op's params plus the dims bound by input-schema
  unification (``grep-count -> 1``, ``kmer-stats -> cap * (W - k + 1)``);
* **monoid** — reduce/merge algebra the image implements (``"sum"`` /
  ``"max"`` / ``"min"``), consumed by ``reduce_by_key``'s container
  spelling instead of hard-coded image tables;
* **key space** — for key-emitting images, the declared size of the key
  range their output records' key leaf (by convention the FIRST record
  leaf) covers (``kmer-stats: 4**k``), so downstream key tables can be
  sized — and bounds-checked — at plan time;
* **command grammar** — declared commands with typed args, replacing
  per-image ``shlex`` micro-parsers; each :class:`CommandSpec` may carry
  its own implementation fn and contract overrides (the `posix` image is
  really three tools behind one ENTRYPOINT).

The planner consumes resolved :class:`Contract` objects to type-check a
whole stage DAG at plan-build time (see ``repro.core.plan.infer_states``).
"""
from __future__ import annotations

import dataclasses
import shlex
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro.core.schema import (Schema, SchemaMismatch, substitute, unify)


class PlanTypeError(TypeError):
    """A pipeline violates a declared image contract at plan-build time.

    Raised while *building* a chain (``MaRe.map(...)`` etc.), with the
    stage index and both schemas in the message — instead of a shape error
    from inside the fused ``shard_map`` trace at action time.
    """


#: ``out_capacity`` marker: the op keeps its input partition capacity
#: (for a reduce combiner this means concat-like growth — see plan.py).
PRESERVE = "preserve"


def SAME(schema: Optional[Schema], env: Mapping[str, Any]
         ) -> Optional[Schema]:
    """``output_schema`` transfer: records pass through unchanged."""
    return schema


_REQUIRED = object()


@dataclasses.dataclass(frozen=True)
class ArgSpec:
    """One positional argument of a command grammar.

    ``type`` coerces the token (``int`` / ``float`` / ``str``);
    ``variadic`` consumes all remaining tokens into a tuple; optional args
    (``required=False``) emit nothing when absent, deferring to the
    image's registered parameter defaults.
    """

    name: str
    type: Callable[[str], Any] = str
    required: bool = True
    variadic: bool = False


#: Sentinel: a CommandSpec field inherits the image-level manifest value.
_INHERIT = None


@dataclasses.dataclass(frozen=True)
class CommandSpec:
    """One command of an image's typed grammar (+ contract overrides).

    ``fn`` optionally overrides the image's registered implementation
    (command dispatch); contract fields left ``None`` inherit the
    image-level manifest defaults.
    """

    name: str
    args: Tuple[ArgSpec, ...] = ()
    fn: Optional[Callable[..., Any]] = None
    input_schema: Optional[Schema] = _INHERIT
    output_schema: Any = _INHERIT            # Schema | callable | None
    out_capacity: Any = _INHERIT             # int | callable | PRESERVE
    monoid: Optional[str] = _INHERIT
    key_space: Any = _INHERIT                # int | callable(env) -> int
    associative_commutative: Optional[bool] = None

    def parse(self, argv: List[str], image: str) -> Dict[str, Any]:
        """Coerce ``argv`` (tokens after the command name) to typed params."""
        params: Dict[str, Any] = {}
        rest = list(argv)
        for spec in self.args:
            if spec.variadic:
                if not rest:
                    if spec.required:
                        raise ValueError(
                            f"image {image!r} command {self.name!r}: "
                            f"missing required argument {spec.name!r}")
                    continue   # optional + absent: defer to defaults
                try:
                    params[spec.name] = tuple(spec.type(a) for a in rest)
                except ValueError as e:
                    raise ValueError(
                        f"image {image!r} command {self.name!r}: bad "
                        f"argument for {spec.name!r}: {e}") from e
                rest = []
            elif rest:
                tok = rest.pop(0)
                try:
                    params[spec.name] = spec.type(tok)
                except ValueError as e:
                    raise ValueError(
                        f"image {image!r} command {self.name!r}: argument "
                        f"{spec.name!r} expects {spec.type.__name__}, got "
                        f"{tok!r}") from e
            elif spec.required:
                raise ValueError(
                    f"image {image!r} command {self.name!r}: missing "
                    f"required argument {spec.name!r}")
        if rest:
            raise ValueError(
                f"image {image!r} command {self.name!r}: unexpected "
                f"arguments {rest}")
        return params


@dataclasses.dataclass(frozen=True)
class Contract:
    """A manifest resolved against one op's command + params.

    This is what the planner consumes: the command-level overrides are
    already merged over the image-level defaults, and ``params`` holds the
    fully-merged op parameters feeding the transfer functions' ``env``.
    """

    label: str                               # e.g. "ubuntu[grep-chars]"
    input_schema: Optional[Schema] = None
    output_schema: Any = None
    out_capacity: Any = PRESERVE
    monoid: Optional[str] = None
    key_space: Any = None
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def check_input(self, actual: Schema) -> Dict[str, Any]:
        """Unify the incoming schema against the declared input contract;
        returns the transfer-function ``env`` (params + bound dims)."""
        env: Dict[str, Any] = dict(self.params)
        if self.input_schema is None:
            return env
        bound = unify(self.input_schema, actual,
                      {k: v for k, v in env.items() if isinstance(v, int)})
        env.update(bound)
        return env

    def infer_output_schema(self, in_schema: Optional[Schema],
                            env: Mapping[str, Any]) -> Optional[Schema]:
        if self.output_schema is None:
            return None
        if callable(self.output_schema):
            return self.output_schema(in_schema, env)
        dims = {k: v for k, v in env.items() if isinstance(v, int)}
        return substitute(self.output_schema, dims)

    def infer_out_capacity(self, in_capacity: Optional[int],
                           env: Mapping[str, Any]) -> Optional[int]:
        oc = self.out_capacity
        if oc == PRESERVE:
            return in_capacity
        if callable(oc):
            if in_capacity is None:
                return None
            try:
                cap = int(oc(in_capacity, env))
            except KeyError:
                return None      # transfer needs a dim the schema didn't bind
            if cap < 1:
                raise ValueError(
                    f"capacity transfer of {self.label} yields {cap} "
                    f"(in_capacity={in_capacity}, env={dict(env)})")
            return cap
        return None if oc is None else int(oc)

    def infer_key_space(self, env: Mapping[str, Any]) -> Optional[int]:
        ks = self.key_space
        if callable(ks):
            try:
                return int(ks(env))
            except KeyError:
                return None
        return None if ks is None else int(ks)


@dataclasses.dataclass(frozen=True)
class ImageManifest:
    """Declarative contract attached to a registered image.

    Image-level fields are the defaults; entries in ``commands`` are the
    typed grammar and may override any contract field per command.
    ``default_command`` names the command used when an op is pulled with
    an empty command string; with a non-empty grammar and no default, an
    empty command is a pull-time error (the ENTRYPOINT needs an argv).
    """

    input_schema: Optional[Schema] = None
    output_schema: Any = None                # Schema | callable | None
    out_capacity: Any = PRESERVE             # int | callable | PRESERVE
    monoid: Optional[str] = None
    key_space: Any = None                    # int | callable(env) -> int
    commands: Tuple[CommandSpec, ...] = ()
    default_command: Optional[str] = None

    def command_names(self) -> Tuple[str, ...]:
        return tuple(sorted(c.name for c in self.commands))

    def find_command(self, name: str) -> Optional[CommandSpec]:
        for c in self.commands:
            if c.name == name:
                return c
        return None

    def parse_command(self, command: str, image: str
                      ) -> Tuple[Optional[CommandSpec], Dict[str, Any]]:
        """Parse a command string through the typed grammar.

        Returns ``(spec, typed params)``; ``(None, {})`` when the image
        has no grammar (the command string, if any, is passed through to
        the implementation untyped, as before manifests).
        """
        if not self.commands:
            return None, {}
        argv = shlex.split(command)
        if not argv:
            if self.default_command is None:
                raise ValueError(
                    f"image {image!r} requires a command; grammar: "
                    f"{', '.join(self.command_names())}")
            spec = self.find_command(self.default_command)
            assert spec is not None, (image, self.default_command)
            return spec, spec.parse([], image)
        spec = self.find_command(argv[0])
        if spec is None:
            raise ValueError(
                f"image {image!r}: unknown command {argv[0]!r}; grammar: "
                f"{', '.join(self.command_names())}")
        return spec, spec.parse(argv[1:], image)

    def resolve(self, spec: Optional[CommandSpec],
                params: Mapping[str, Any], *, image: str,
                command: str = "") -> Contract:
        """Merge command-level overrides over image defaults."""

        def pick(field_name: str) -> Any:
            if spec is not None:
                val = getattr(spec, field_name)
                if val is not _INHERIT:
                    return val
            return getattr(self, field_name)

        label = (f"{image}[{spec.name}]"
                 if spec is not None and spec.name != image else image)
        return Contract(
            label=label,
            input_schema=pick("input_schema"),
            output_schema=pick("output_schema"),
            out_capacity=pick("out_capacity"),
            monoid=pick("monoid"),
            key_space=pick("key_space"),
            params=dict(params))


__all__ = [
    "ArgSpec", "CommandSpec", "Contract", "ImageManifest", "PlanTypeError",
    "PRESERVE", "SAME", "SchemaMismatch",
]
