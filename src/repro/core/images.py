"""Standard container images (the "Docker Hub" of this repo).

Each image is a registered ContainerOp factory whose ``command`` string is
interpreted by the image itself — the ENTRYPOINT analogue.  The ``posix``
image implements a micro-grammar covering the paper's Listing 1 commands
(grep-count / awk-sum), plus generic combiners used by the evaluation
pipelines (top-k filtering = sdsorter, concat = vcf-concat).
"""
from __future__ import annotations

import shlex
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.container import (ContainerOp, Partition, container_op,
                                  make_partition)


# ---------------------------------------------------------------------------
# posix: grep-count / awk-sum over integer token records (Listing 1)
# ---------------------------------------------------------------------------

def _posix_fn(part: Partition, command: str = "", **kw: Any) -> Partition:
    argv = shlex.split(command)
    if not argv:
        raise ValueError("posix image requires a command")
    prog = argv[0]
    if prog == "grep-count":
        # grep -o '<chars>' | wc -l : count records whose value is in a set.
        # Records are int32 token codes; command: grep-count 2 3  (codes)
        codes = jnp.asarray([int(a) for a in argv[1:]], jnp.int32)
        (tokens,) = jax.tree.leaves(part.records)
        valid = part.mask()
        hit = jnp.isin(tokens, codes) & valid
        total = jnp.sum(hit).astype(jnp.int32)
        return make_partition((total[None],), jnp.int32(1))
    if prog == "grep-chars":
        # grep -o '[<chars>]' | wc -l over BYTE records: count occurrences
        # of any of the given characters inside each record's valid length.
        # Records: {"data": [cap, width] uint8, "len": [cap] int32}.
        if len(argv) < 2:
            raise ValueError("grep-chars needs a character-class argument")
        codes = jnp.asarray([ord(c) for c in argv[1]], jnp.uint8)
        data = part.records["data"]
        lens = part.records["len"]
        in_len = jnp.arange(data.shape[1])[None, :] < lens[:, None]
        valid = part.mask()[:, None]
        hit = jnp.isin(data, codes) & in_len & valid
        total = jnp.sum(hit).astype(jnp.int32)
        return make_partition((total[None],), jnp.int32(1))
    if prog == "awk-sum":
        # awk '{s+=$1} END {print s}' : sum records to a single record.
        (vals,) = jax.tree.leaves(part.records)
        valid = part.mask()
        s = jnp.sum(jnp.where(valid, vals, 0), axis=0)
        return make_partition((s[None],), jnp.int32(1))
    raise ValueError(f"posix image: unknown command {prog!r}")


@container_op("ubuntu", associative_commutative=True)
def posix_ubuntu(part: Partition, command: str = "", **kw: Any) -> Partition:
    """The paper's `ubuntu` image: POSIX text tools micro-grammar."""
    return _posix_fn(part, command=command, **kw)


@container_op("posix", associative_commutative=True)
def posix(part: Partition, command: str = "", **kw: Any) -> Partition:
    return _posix_fn(part, command=command, **kw)


# ---------------------------------------------------------------------------
# kmer-stats: FASTA byte records -> packed k-mer keys/counts (arXiv:1807.01566
# workload: reduce_by_key over the 4^k k-mer key space)
# ---------------------------------------------------------------------------

_BASE_CODES = {65: 0, 67: 1, 71: 2, 84: 3}   # A C G T -> 2-bit codes


@container_op("kmer-stats")
def kmer_stats(part: Partition, command: str = "", k: int = 8,
               **kw: Any) -> Partition:
    """Emit one ``(packed k-mer key, 1)`` record per k-mer occurrence.

    Input: byte records ``{"data": uint8 [cap, W], "len": int32 [cap]}``
    (the repro.io FASTA contract — each record is one sequence line, so
    k-mers never span records).  Output records: ``(codes int32, ones
    int32)`` with the 2-bit packing ``A=0 C=1 G=2 T=3`` (case-insensitive);
    windows containing any other base (N, gaps) are skipped.  ``k`` comes
    from the param or the command string (``kmer-stats 8``); ``k <= 15``
    keeps codes within int32, and ``num_keys = 4**k`` downstream.
    """
    argv = shlex.split(command)
    if len(argv) >= 2 and argv[0] == "kmer-stats":
        k = int(argv[1])
    elif len(argv) == 1 and argv[0].isdigit():
        k = int(argv[0])
    if not 1 <= k <= 15:
        raise ValueError(f"kmer-stats needs 1 <= k <= 15, got {k}")
    data = part.records["data"]
    lens = part.records["len"]
    cap, width = data.shape
    if k > width:
        raise ValueError(f"k={k} exceeds record width {width}")
    nw = width - k + 1
    upper = jnp.where((data >= 97) & (data <= 122), data - 32, data)
    code = jnp.zeros_like(upper, dtype=jnp.int32)
    base_ok = jnp.zeros(data.shape, bool)
    for byte, c in _BASE_CODES.items():
        hit = upper == byte
        code = jnp.where(hit, c, code)
        base_ok = base_ok | hit
    acc = jnp.zeros((cap, nw), jnp.int32)
    window_ok = jnp.ones((cap, nw), bool)
    for j in range(k):
        acc = acc * 4 + code[:, j:j + nw]
        window_ok = window_ok & base_ok[:, j:j + nw]
    in_len = jnp.arange(nw)[None, :] + k <= lens[:, None]
    ok = (window_ok & in_len & part.mask()[:, None]).reshape(-1)
    # compact valid k-mers to the front (partition count semantics)
    order = jnp.argsort(~ok, stable=True)
    codes = jnp.take(acc.reshape(-1), order, mode="clip")
    total = jnp.sum(ok).astype(jnp.int32)
    ones = (jnp.arange(cap * nw) < total).astype(jnp.int32)
    return make_partition((codes, ones), total)


# ---------------------------------------------------------------------------
# Generic combinators (used by evaluation pipelines and tests)
# ---------------------------------------------------------------------------

def fn_image(name: str, fn: Callable[..., Partition], *,
             associative_commutative: bool = False,
             registry=None, **defaults: Any) -> Callable[..., ContainerOp]:
    """Build + register an image from a python function at runtime
    (the `docker build` analogue for ad-hoc tools)."""
    from repro.core import container as c
    reg = registry or c.DEFAULT_REGISTRY

    @container_op(name, associative_commutative=associative_commutative,
                  registry=reg, **defaults)
    def _op(part: Partition, command: str = "", **kw: Any) -> Partition:
        return fn(part, **kw)

    return _op


@container_op("toolbox/topk", associative_commutative=True)
def topk_image(part: Partition, command: str = "", k: int = 30,
               score_field: int = 0, **kw: Any) -> Partition:
    """sdsorter analogue: keep the k best-scoring records.

    Records: tuple whose first leaf is [cap, ...]; scores are taken from
    ``records[score_field]`` (a [cap] float array).  Associative +
    commutative (paper notes sdsorter top-k is reduce-safe).
    """
    leaves = jax.tree.leaves(part.records)
    scores = leaves[score_field]
    if scores.ndim > 1:
        scores = scores.reshape(scores.shape[0], -1)[:, 0]
    valid = part.mask()
    neg_inf = jnp.asarray(-jnp.inf, scores.dtype)
    masked = jnp.where(valid, scores, neg_inf)
    k_eff = min(k, part.capacity)
    _, idx = jax.lax.top_k(masked, k_eff)
    out = jax.tree.map(lambda l: jnp.take(l, idx, axis=0), part.records)
    cnt = jnp.minimum(part.count, k_eff).astype(jnp.int32)
    return make_partition(out, cnt)


@container_op("toolbox/concat", associative_commutative=True)
def concat_image(part: Partition, command: str = "", **kw: Any) -> Partition:
    """vcf-concat analogue: identity on records (concatenation is implicit
    in the tree gather); compacts valid records to the front."""
    return part


@container_op("toolbox/sum", associative_commutative=True)
def sum_image(part: Partition, command: str = "", **kw: Any) -> Partition:
    """Elementwise sum of records into a single record."""
    valid = part.mask()

    def s(leaf):
        m = valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(jnp.where(m, leaf, 0), axis=0)[None]

    return make_partition(jax.tree.map(s, part.records), jnp.int32(1))
