"""Standard container images (the "Docker Hub" of this repo).

Every image registers with an :class:`~repro.core.manifests.ImageManifest`:
a declarative contract carrying record schemas, a capacity transfer
function, reduce-monoid properties, and a typed command grammar.  The
``posix`` image's grammar covers the paper's Listing 1 commands
(``grep-count`` / ``awk-sum``) plus ``grep-chars`` for byte records; each
command dispatches to its own implementation — the central grammar
replaces the per-image ``shlex`` micro-parsers, so an unknown command or a
mistyped argument fails at *pull* time with the image's grammar in the
message, and the planner can type-check whole pipelines before tracing.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.container import (ContainerOp, Partition, container_op,
                                  make_partition)
from repro.core.manifests import (ArgSpec, CommandSpec, ImageManifest,
                                  PRESERVE, SAME)
from repro.core.schema import Schema, bytes_record_schema, field


# ---------------------------------------------------------------------------
# posix: grep-count / grep-chars / awk-sum (Listing 1 micro-tools)
# ---------------------------------------------------------------------------

#: Single-leaf tuple of scalar records (any dtype) — the token stream the
#: Listing 1 integer pipeline flows through.
_SCALAR_RECORDS = Schema((field(None),))
#: One int32 count record — what the grep counters emit.
_COUNT_RECORDS = Schema((field(jnp.int32),))


def _grep_count(part: Partition, codes: Any = (), **kw: Any) -> Partition:
    """``grep -o '<codes>' | wc -l``: count records whose value is in a
    set of int token codes."""
    code_arr = jnp.asarray(list(codes), jnp.int32)
    (tokens,) = jax.tree.leaves(part.records)
    valid = part.mask()
    hit = jnp.isin(tokens, code_arr) & valid
    total = jnp.sum(hit).astype(jnp.int32)
    return make_partition((total[None],), jnp.int32(1))


def _grep_chars(part: Partition, chars: str = "", **kw: Any) -> Partition:
    """``grep -o '[<chars>]' | wc -l`` over byte records: count occurrences
    of any of the given characters inside each record's valid length."""
    codes = jnp.asarray([ord(c) for c in chars], jnp.uint8)
    data = part.records["data"]
    lens = part.records["len"]
    in_len = jnp.arange(data.shape[1])[None, :] < lens[:, None]
    valid = part.mask()[:, None]
    hit = jnp.isin(data, codes) & in_len & valid
    total = jnp.sum(hit).astype(jnp.int32)
    return make_partition((total[None],), jnp.int32(1))


def _awk_sum(part: Partition, **kw: Any) -> Partition:
    """``awk '{s+=$1} END {print s}'``: sum records to a single record."""
    (vals,) = jax.tree.leaves(part.records)
    valid = part.mask()
    s = jnp.sum(jnp.where(valid, vals, 0), axis=0)
    return make_partition((s[None],), jnp.int32(1))


POSIX_MANIFEST = ImageManifest(
    commands=(
        CommandSpec(
            "grep-count",
            args=(ArgSpec("codes", type=int, required=False, variadic=True),),
            fn=_grep_count,
            input_schema=_SCALAR_RECORDS,
            output_schema=_COUNT_RECORDS,
            out_capacity=1),
        CommandSpec(
            "grep-chars",
            args=(ArgSpec("chars", type=str),),
            fn=_grep_chars,
            input_schema=bytes_record_schema(),
            output_schema=_COUNT_RECORDS,
            out_capacity=1),
        CommandSpec(
            "awk-sum",
            fn=_awk_sum,
            output_schema=SAME,
            out_capacity=1,
            monoid="sum",
            associative_commutative=True),
    ))


def _posix_entry(part: Partition, **kw: Any) -> Partition:
    raise ValueError("posix image requires a command")  # pragma: no cover


#: The paper's `ubuntu` image: POSIX text tools behind a typed grammar.
posix_ubuntu = container_op("ubuntu", manifest=POSIX_MANIFEST)(_posix_entry)
posix = container_op("posix", manifest=POSIX_MANIFEST)(_posix_entry)


# ---------------------------------------------------------------------------
# kmer-stats: FASTA byte records -> packed k-mer keys/counts (arXiv:1807.01566
# workload: reduce_by_key over the 4^k k-mer key space)
# ---------------------------------------------------------------------------

_BASE_CODES = {65: 0, 67: 1, 71: 2, 84: 3}   # A C G T -> 2-bit codes

KMER_MANIFEST = ImageManifest(
    input_schema=bytes_record_schema(),
    output_schema=Schema((field(jnp.int32), field(jnp.int32))),
    # every record yields at most W - k + 1 windows
    out_capacity=lambda cap, env: cap * (env["W"] - env["k"] + 1),
    # packed 2-bit keys cover [0, 4**k) — downstream key tables can be
    # sized (and bounds-checked) at plan time, FastKmer-style
    key_space=lambda env: 4 ** env["k"],
    commands=(CommandSpec(
        "kmer-stats", args=(ArgSpec("k", type=int, required=False),)),),
    default_command="kmer-stats")


@container_op("kmer-stats", manifest=KMER_MANIFEST, k=8)
def kmer_stats(part: Partition, k: int = 8, **kw: Any) -> Partition:
    """Emit one ``(packed k-mer key, 1)`` record per k-mer occurrence.

    Input: byte records ``{"data": uint8 [cap, W], "len": int32 [cap]}``
    (the repro.io FASTA contract — each record is one sequence line, so
    k-mers never span records).  Output records: ``(codes int32, ones
    int32)`` with the 2-bit packing ``A=0 C=1 G=2 T=3`` (case-insensitive);
    windows containing any other base (N, gaps) are skipped.  ``k`` comes
    from the param or the command grammar (``kmer-stats 8``); ``k <= 15``
    keeps codes within int32, and ``num_keys = 4**k`` downstream (declared
    as the manifest's ``key_space``, so ``reduce_by_key`` can infer it).
    """
    if not 1 <= k <= 15:
        raise ValueError(f"kmer-stats needs 1 <= k <= 15, got {k}")
    data = part.records["data"]
    lens = part.records["len"]
    cap, width = data.shape
    if k > width:
        raise ValueError(f"k={k} exceeds record width {width}")
    nw = width - k + 1
    upper = jnp.where((data >= 97) & (data <= 122), data - 32, data)
    code = jnp.zeros_like(upper, dtype=jnp.int32)
    base_ok = jnp.zeros(data.shape, bool)
    for byte, c in _BASE_CODES.items():
        hit = upper == byte
        code = jnp.where(hit, c, code)
        base_ok = base_ok | hit
    acc = jnp.zeros((cap, nw), jnp.int32)
    window_ok = jnp.ones((cap, nw), bool)
    for j in range(k):
        acc = acc * 4 + code[:, j:j + nw]
        window_ok = window_ok & base_ok[:, j:j + nw]
    in_len = jnp.arange(nw)[None, :] + k <= lens[:, None]
    ok = (window_ok & in_len & part.mask()[:, None]).reshape(-1)
    # compact valid k-mers to the front (partition count semantics)
    order = jnp.argsort(~ok, stable=True)
    codes = jnp.take(acc.reshape(-1), order, mode="clip")
    total = jnp.sum(ok).astype(jnp.int32)
    ones = (jnp.arange(cap * nw) < total).astype(jnp.int32)
    return make_partition((codes, ones), total)


# ---------------------------------------------------------------------------
# Generic combinators (used by evaluation pipelines and tests)
# ---------------------------------------------------------------------------

def _accepts_command(fn: Callable[..., Any]) -> bool:
    """Whether ``fn`` can receive the ``command`` keyword (named param or
    **kwargs)."""
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):  # builtins / C callables
        return False
    for p in sig.parameters.values():
        if p.kind is inspect.Parameter.VAR_KEYWORD:
            return True
        if p.name == "command" and p.kind in (
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
                inspect.Parameter.KEYWORD_ONLY):
            return True
    return False


def fn_image(name: str, fn: Callable[..., Partition], *,
             associative_commutative: bool = False,
             manifest: Optional[ImageManifest] = None,
             registry=None, **defaults: Any) -> Callable[..., ContainerOp]:
    """Build + register an image from a python function at runtime
    (the `docker build` analogue for ad-hoc tools).

    The wrapped fn receives the pull-time ``command`` string whenever its
    signature can accept it (a ``command`` parameter or ``**kwargs``) —
    runtime-built images interpret their command like registered ones do.
    """
    from repro.core import container as c
    reg = registry or c.DEFAULT_REGISTRY
    forward_command = _accepts_command(fn)

    @container_op(name, associative_commutative=associative_commutative,
                  manifest=manifest, registry=reg, **defaults)
    def _op(part: Partition, command: str = "", **kw: Any) -> Partition:
        if forward_command:
            return fn(part, command=command, **kw)
        return fn(part, **kw)

    return _op


TOPK_MANIFEST = ImageManifest(
    output_schema=SAME,
    out_capacity=lambda cap, env: min(int(env["k"]), cap))


@container_op("toolbox/topk", associative_commutative=True,
              manifest=TOPK_MANIFEST, k=30)
def topk_image(part: Partition, k: int = 30,
               score_field: int = 0, **kw: Any) -> Partition:
    """sdsorter analogue: keep the k best-scoring records.

    Records: tuple whose first leaf is [cap, ...]; scores are taken from
    ``records[score_field]`` (a [cap] float array).  Associative +
    commutative (paper notes sdsorter top-k is reduce-safe).
    """
    leaves = jax.tree.leaves(part.records)
    scores = leaves[score_field]
    if scores.ndim > 1:
        scores = scores.reshape(scores.shape[0], -1)[:, 0]
    valid = part.mask()
    if jnp.issubdtype(scores.dtype, jnp.floating):
        lowest = jnp.asarray(-jnp.inf, scores.dtype)
    else:
        lowest = jnp.asarray(jnp.iinfo(scores.dtype).min, scores.dtype)
    masked = jnp.where(valid, scores, lowest)
    k_eff = min(k, part.capacity)
    _, idx = jax.lax.top_k(masked, k_eff)
    out = jax.tree.map(lambda l: jnp.take(l, idx, axis=0), part.records)
    cnt = jnp.minimum(part.count, k_eff).astype(jnp.int32)
    return make_partition(out, cnt)


CONCAT_MANIFEST = ImageManifest(output_schema=SAME, out_capacity=PRESERVE)


@container_op("toolbox/concat", associative_commutative=True,
              manifest=CONCAT_MANIFEST)
def concat_image(part: Partition, **kw: Any) -> Partition:
    """vcf-concat analogue: identity on records (concatenation is implicit
    in the tree gather); compacts valid records to the front."""
    return part


SUM_MANIFEST = ImageManifest(output_schema=SAME, out_capacity=1,
                             monoid="sum")


@container_op("toolbox/sum", associative_commutative=True,
              manifest=SUM_MANIFEST)
def sum_image(part: Partition, **kw: Any) -> Partition:
    """Elementwise sum of records into a single record."""
    valid = part.mask()

    def s(leaf):
        m = valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(jnp.where(m, leaf, 0), axis=0)[None]

    return make_partition(jax.tree.map(s, part.records), jnp.int32(1))
