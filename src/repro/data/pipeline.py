"""Data pipeline: sources, packing (as MaRe map stages), host prefetch with
straggler mitigation.

The paper's ingestion story (HDFS / Swift / S3, Fig. 5) maps to pluggable
``Source`` iterators behind one contract; its locality story maps to the
tokenize/pack stage running as a ``MaRe.map`` ContainerOp (partition-local,
zero shuffle).  Host-side prefetch wraps generation in a worker pool with a
deadline: tasks that exceed it are speculatively re-dispatched — the Spark
speculative-execution analogue that SPMD lost (DESIGN.md §2.3).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import METRICS, instant


# ---------------------------------------------------------------------------
# Sources (the heterogeneous-storage abstraction)
# ---------------------------------------------------------------------------

class Source:
    """Iterator of raw record arrays.  Subclasses emulate storage backends
    with different latency profiles (benchmarks/ingestion.py)."""

    name = "base"

    def __iter__(self) -> Iterator[np.ndarray]:  # pragma: no cover
        raise NotImplementedError


class SyntheticText(Source):
    """Zipf-distributed token documents (deterministic per seed)."""

    name = "synthetic"

    def __init__(self, vocab_size: int, doc_len: int = 1024,
                 num_docs: int = 1 << 30, seed: int = 0,
                 latency_s: float = 0.0, jitter_s: float = 0.0):
        self.vocab_size = vocab_size
        self.doc_len = doc_len
        self.num_docs = num_docs
        self.seed = seed
        self.latency_s = latency_s
        self.jitter_s = jitter_s

    def __iter__(self):
        for i in range(self.num_docs):
            rng = np.random.default_rng(self.seed + i)
            if self.latency_s or self.jitter_s:
                time.sleep(self.latency_s +
                           rng.exponential(self.jitter_s))
            ranks = rng.zipf(1.3, size=self.doc_len)
            yield (ranks % self.vocab_size).astype(np.int32)


# ---------------------------------------------------------------------------
# Batch builder
# ---------------------------------------------------------------------------

def lm_batches(source: Source, batch: int, seq: int,
               vocab_size: int, extra: Optional[Dict[str, Callable]] = None
               ) -> Iterator[Dict[str, np.ndarray]]:
    """Pack documents into [batch, seq+1] windows -> tokens/labels."""
    it = iter(source)
    buf = np.zeros((0,), np.int32)
    while True:
        need = batch * (seq + 1)
        while buf.shape[0] < need:
            buf = np.concatenate([buf, next(it)])
        window = buf[:need].reshape(batch, seq + 1)
        buf = buf[need:]
        out = {"tokens": window[:, :-1].copy(),
               "labels": window[:, 1:].copy()}
        if extra:
            for k, fn in extra.items():
                out[k] = fn(batch, seq)
        yield out


# ---------------------------------------------------------------------------
# Prefetcher with straggler re-dispatch
# ---------------------------------------------------------------------------

class Prefetcher:
    """Background batch production with speculative re-execution.

    A producer thread fills a bounded queue.  If producing one batch takes
    longer than ``deadline_s``, a backup producer is dispatched for the
    same batch index and the first result wins (both are deterministic, so
    duplicates are identical — Spark speculative-execution semantics)."""

    def __init__(self, make_iter: Callable[[], Iterator],
                 capacity: int = 4, deadline_s: Optional[float] = None):
        self.make_iter = make_iter
        self.q: "queue.Queue" = queue.Queue(maxsize=capacity)
        self.deadline_s = deadline_s
        self.stats = {"produced": 0, "respawned": 0}
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        it = iter(self.make_iter())
        idx = 0
        while not self._stop.is_set():
            result: Dict[str, Any] = {}
            lock = threading.Lock()
            done = threading.Event()

            def produce(slot_it):
                try:
                    batch = next(slot_it)
                    with lock:
                        if not result:
                            result["batch"] = batch
                            result["it"] = slot_it
                except StopIteration:
                    with lock:
                        if not result:   # a winner's batch beats a loser's
                            result["stop"] = True      # exhaustion
                finally:
                    done.set()

            worker = threading.Thread(target=produce, args=(it,), daemon=True)
            worker.start()
            timeout = self.deadline_s
            finished = done.wait(timeout) if timeout else done.wait()
            if not finished:
                # straggler: speculatively re-dispatch on a FRESH iterator
                # fast-forwarded to idx (deterministic source); first result
                # wins, and the winning iterator becomes the active one (the
                # loser is mis-positioned and abandoned).
                self.stats["respawned"] += 1
                instant("prefetch.speculative_redispatch", batch=idx,
                        deadline_s=self.deadline_s)
                METRICS.counter("prefetch.respawned").inc()
                backup_it = iter(self.make_iter())
                try:
                    for _ in range(idx):
                        next(backup_it)
                except StopIteration:
                    backup_it = None   # replay shorter than idx: no backup
                if backup_it is not None:
                    threading.Thread(target=produce, args=(backup_it,),
                                     daemon=True).start()
                done.wait()

            if result.get("stop"):
                break
            it = result["it"]
            self.q.put(result["batch"])
            self.stats["produced"] += 1
            idx += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()


# ---------------------------------------------------------------------------
# MaRe-stage tokenizer (the paper-faithful pre-processing path)
# ---------------------------------------------------------------------------

def register_tokenizer_image():
    """A 'tokenizer' container image: maps raw byte records to token ids
    partition-locally (MaRe.map — single stage, no shuffle)."""
    from repro.core.container import (DEFAULT_REGISTRY, Partition,
                                      container_op, make_partition)
    if "tools/tokenizer:latest" in DEFAULT_REGISTRY.images():
        return

    @container_op("tools/tokenizer", registry=DEFAULT_REGISTRY)
    def tokenizer(part: Partition, command: str = "", vocab_size: int = 256,
                  **kw) -> Partition:
        (raw,) = jax.tree.leaves(part.records)
        toks = (raw.astype(jnp.uint32) * jnp.uint32(2654435761)
                % jnp.uint32(vocab_size)).astype(jnp.int32)
        return make_partition((toks,), part.count)

    return tokenizer
