from repro.data.pipeline import (Prefetcher, Source, SyntheticText,
                                 lm_batches, register_tokenizer_image)

__all__ = ["Prefetcher", "Source", "SyntheticText", "lm_batches",
           "register_tokenizer_image"]
