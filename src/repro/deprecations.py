"""Deprecation machinery for the facade's renamed surface.

Every deprecated spelling (the four ``collect_*`` action variants, the
``last_diagnostics`` dict property, the paper-style camelCase aliases)
funnels through :func:`warn_once`, which emits ONE
:class:`MaReDeprecationWarning` per spelling per process — interactive
sessions see the pointer to the new name exactly once instead of on
every call of a hot loop.

The repo's own tests and benchmarks run with this category turned into
an error (``pytest.ini`` / an explicit ``warnings.filterwarnings`` in
each benchmark), so internal code can never quietly regress onto a
deprecated spelling; the shim tests opt back in with a
``filterwarnings`` mark and :func:`reset` between cases.
"""
from __future__ import annotations

import threading
import warnings
from typing import Hashable, Set


class MaReDeprecationWarning(DeprecationWarning):
    """Category for every deprecated repro.* spelling (filterable apart
    from third-party DeprecationWarnings)."""


_WARNED: Set[Hashable] = set()
_LOCK = threading.Lock()


def warn_once(key: Hashable, message: str, *, stacklevel: int = 3) -> bool:
    """Emit ``message`` as a :class:`MaReDeprecationWarning` the FIRST
    time ``key`` is seen (per process); return whether it warned."""
    with _LOCK:
        if key in _WARNED:
            return False
        _WARNED.add(key)
    warnings.warn(message, MaReDeprecationWarning, stacklevel=stacklevel)
    return True


def reset() -> None:
    """Forget which keys have warned (tests asserting warn-once)."""
    with _LOCK:
        _WARNED.clear()
