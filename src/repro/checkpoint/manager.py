"""Sharded checkpointing with atomic manifests + async flush.

Fault-tolerance model (DESIGN.md §4): synchronous device->host gather,
asynchronous file write (training continues during flush), atomic
directory rename so a crash mid-write never corrupts the latest
checkpoint, keep-last-K retention, and restore that re-shards onto
whatever mesh the restarted job has (elastic rescale lives in
``elastic.py`` but the mechanism — device_put with the new sharding — is
here in ``restore``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"[{k.idx}]"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = False) -> str:
        """Gather to host synchronously, write asynchronously."""
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
                if hasattr(v, "shape")}
        meta = {"step": int(step),
                "keys": {k: [list(v.shape), str(v.dtype)]
                         for k, v in host.items()},
                "time": time.time()}
        self.wait()
        if self.async_write and not blocking:
            self._pending = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._pending.start()
        else:
            self._write(step, host, meta)
        return self._step_dir(step)

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): v for k, v in host.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic publish
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- restore ------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of ``state_like``.

        ``shardings``: optional matching pytree of NamedShardings — this is
        where elastic re-sharding happens: the checkpoint is mesh-agnostic
        (host arrays), so restoring onto a different mesh is just a
        device_put with the new sharding."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._step_dir(step)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat_like = _flatten(state_like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out = {}
        for k, leaf in flat_like.items():
            key = k.replace("/", "__")
            if key not in data.files:
                raise KeyError(f"checkpoint {path} missing {k}")
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            sh = flat_shard.get(k)
            out[k] = (jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
        # rebuild tree
        paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        leaves = []
        for path_k, _ in paths:
            key = "/".join(_key_str(p) for p in path_k)
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _gc(self):
        steps = sorted(s for s in (self.latest_steps()))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def latest_steps(self):
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    yield int(d.split("_")[1])
                except ValueError:
                    pass
