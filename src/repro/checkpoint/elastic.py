"""Elastic rescale: resume a run on a different mesh (N -> M data shards).

Checkpoints are mesh-agnostic host arrays (manager.py), so rescaling =
rebuilding shardings for the new mesh and device_put-ing.  This module
adds the *policy*: recompute batch sharding, validate divisibility, and
split/merge optimizer state that is itself sharded.  It is the TPU
analogue of Spark's dynamic executor scaling, at checkpoint granularity
(DESIGN.md §2: per-task elasticity does not survive the SPMD narrowing).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.sharding import Rules


def shardings_for(tree_axes: Any, mesh: Mesh, rules: Rules) -> Any:
    """Map a logical-axes pytree (tuples of names) to NamedShardings."""
    def one(axes, leaf_shape=None):
        return NamedSharding(mesh, rules.spec_for(axes, dims=leaf_shape))

    return jax.tree.map(
        lambda axes: one(tuple(axes)),
        tree_axes, is_leaf=lambda t: isinstance(t, tuple))


def shardings_for_params(params: Any, logical_axes: Any, mesh: Mesh,
                         rules: Rules) -> Any:
    """Divisibility-aware: consults actual leaf shapes."""
    def one(leaf, axes):
        return NamedSharding(mesh, rules.spec_for(tuple(axes),
                                                  dims=leaf.shape))

    return jax.tree.map(one, params, logical_axes,
                        is_leaf=lambda t: hasattr(t, "shape"))


def rescale(manager: CheckpointManager, state_like: Any,
            new_mesh: Mesh, rules: Rules,
            logical_axes: Optional[Any] = None,
            step: Optional[int] = None) -> Any:
    """Restore the latest checkpoint onto ``new_mesh``.

    With ``logical_axes`` given for params, parameters get proper
    FSDP/TP shardings; otherwise everything restores replicated."""
    shardings = None
    if logical_axes is not None:
        shardings = jax.tree.map(
            lambda leaf: NamedSharding(new_mesh, P()), state_like)
        # params subtree gets real shardings
        params_sh = shardings_for_params(
            state_like.params, logical_axes, new_mesh, rules)
        shardings = shardings._replace(params=params_sh)
    return manager.restore(state_like, step=step, shardings=shardings)
