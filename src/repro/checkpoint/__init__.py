from repro.checkpoint.elastic import (rescale, shardings_for_params)
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "rescale", "shardings_for_params"]
