"""Process-wide metrics registry: counters, gauges, histograms.

Unlike spans (sampled timelines, off by default), metrics are *always*
maintained — they are a handful of integer adds per **action**, never
per record: materialization-cache hits/misses/spills/drops per tier,
compile-cache hits/misses, exchanged-record volume, dispatch-queue
depth, and per-phase wall histograms.  ``snapshot()`` returns a plain
dict (JSON-friendly, what ``MaRe.metrics()`` surfaces); ``render()``
a fixed-width text dump for interactive sessions.

Histograms use power-of-two bucketing over seconds (1 µs .. ~1 ks) —
coarse, allocation-free, and good enough to tell a 2 ms dispatch from a
200 ms compile at a glance.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Number:
        return self._value


class Gauge:
    """Last-set value (e.g. current dispatch-queue depth)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value: Number = 0
        self._lock = threading.Lock()

    def set(self, v: Number) -> None:
        self._value = v

    def add(self, delta: Number) -> Number:
        """Atomic increment/decrement (per-tenant queue depths are
        maintained by +1 on enqueue / -1 on dequeue from different
        threads); returns the new value."""
        with self._lock:
            self._value += delta
            return self._value

    @property
    def value(self) -> Number:
        return self._value


#: Histogram bucket upper bounds (seconds): 1 µs .. 2^30 µs (~18 min),
#: one power of two per bucket, plus a +inf overflow bucket.
_BUCKET_EDGES = tuple(1e-6 * (1 << i) for i in range(31))


class Histogram:
    """Power-of-two-bucketed distribution of observed values (seconds)."""

    __slots__ = ("name", "count", "total", "min", "max", "buckets", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets = [0] * (len(_BUCKET_EDGES) + 1)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        idx = len(_BUCKET_EDGES)
        for i, edge in enumerate(_BUCKET_EDGES):
            if v <= edge:
                idx = i
                break
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self.buckets[idx] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Bucket-resolution percentile estimate (``q`` in [0, 100]):
        the upper edge of the bucket holding the q-th observation.
        Power-of-two buckets make this a factor-of-2 estimate — good
        enough for the serving loop's live p50/p99 display; exact
        percentiles come from the benchmark's raw sample lists."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(self.count * min(max(q, 0.0), 100.0)
                                / 100.0))
        with self._lock:
            seen = 0
            for i, n in enumerate(self.buckets):
                seen += n
                if seen >= rank:
                    return (_BUCKET_EDGES[i] if i < len(_BUCKET_EDGES)
                            else self.max)
        return self.max

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "total": self.total,
                "mean": self.mean,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0}


class MetricsRegistry:
    """Name -> metric store; metrics are created on first touch so call
    sites never need registration boilerplate."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            m = self._counters.get(name)
            if m is None:
                m = self._counters[name] = Counter(name)
            return m

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            m = self._gauges.get(name)
            if m is None:
                m = self._gauges[name] = Gauge(name)
            return m

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            m = self._histograms.get(name)
            if m is None:
                m = self._histograms[name] = Histogram(name)
            return m

    def reset(self) -> None:
        """Drop every metric (tests/benchmarks isolating a measurement)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()

    def snapshot(self) -> Dict[str, object]:
        """Plain-dict view: counters/gauges to their value, histograms to
        their ``summary()`` dict — ``MaRe.metrics()``'s return value."""
        with self._lock:
            out: Dict[str, object] = {}
            for name, c in sorted(self._counters.items()):
                out[name] = c.value
            for name, g in sorted(self._gauges.items()):
                out[name] = g.value
            for name, h in sorted(self._histograms.items()):
                out[name] = h.summary()
            return out

    def render(self, prefix: Optional[str] = None) -> str:
        """Fixed-width text dump (optionally filtered to names starting
        with ``prefix``) for interactive inspection."""
        lines: List[str] = []
        for name, value in self.snapshot().items():
            if prefix is not None and not name.startswith(prefix):
                continue
            if isinstance(value, dict):                    # histogram
                lines.append(
                    f"{name:<44} count={value['count']:<8} "
                    f"mean={value['mean'] * 1e3:.3f}ms "
                    f"min={value['min'] * 1e3:.3f}ms "
                    f"max={value['max'] * 1e3:.3f}ms "
                    f"total={value['total']:.3f}s")
            else:
                lines.append(f"{name:<44} {value}")
        return "\n".join(lines)


#: Process-wide registry every instrumented layer reports into.
METRICS = MetricsRegistry()
