"""Observability: span tracing + process-wide metrics for the runtime.

Two independent, dependency-free primitives (stdlib only — importable
from any layer without cycles):

* :mod:`repro.obs.trace` — a bounded-ring span recorder with a
  Chrome-trace/Perfetto JSON exporter.  Disabled by default; the
  instrumentation threaded through ingest, planner, executor, cache and
  wave layers costs one branch per call site until
  :func:`~repro.obs.trace.tracing` (or ``TRACER.start()``) attaches the
  ring.
* :mod:`repro.obs.metrics` — always-on counters/gauges/histograms
  (cache hits per tier, compile-cache hits, exchanged records, queue
  depth, per-phase walls), snapshotted by ``MaRe.metrics()``.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, METRICS)
from repro.obs.trace import (TRACER, Tracer, instant, span,  # noqa: F401
                             timed, tracing)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "METRICS",
    "TRACER", "Tracer", "instant", "span", "timed", "tracing",
]
