"""Thread-safe span tracing with a Chrome-trace/Perfetto JSON exporter.

The runtime layers (ingest pool, planner, executor, materialization
cache, wave loop) are permanently instrumented with :func:`span` /
:func:`instant` calls against the process-wide :data:`TRACER`.  The
tracer is **disabled by default**: until a ring sink is attached with
:meth:`Tracer.start` (or the :func:`tracing` context manager), ``span``
returns a shared null context manager and ``instant`` returns
immediately — one attribute load and a branch, cheap enough to leave in
every hot path (asserted < 5% of a small fused action in
``tests/test_obs.py``).

When enabled, completed spans land in a bounded in-memory ring (oldest
events drop first; ``events_dropped`` counts the loss) as Chrome-trace
"complete" (``ph="X"``) events: wall-clock microseconds since the
tracer's epoch, the recording thread's id as ``tid``, and arbitrary
JSON-serializable ``args``.  Nesting is by containment on a thread —
Perfetto and ``chrome://tracing`` both render stacked slices without
explicit parent links.  Export with :meth:`Tracer.export` (or
``MaRe.trace_to``) and load the file straight into https://ui.perfetto.dev.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Deque, Dict, Iterator, List, Optional


class _NullSpan:
    """Shared no-op span: the disabled-tracing fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **args: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records [enter, exit) and appends to the ring."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.monotonic()
        self._tracer._record(self.name, self._t0, t1, self.args)

    def set(self, **args: Any) -> None:
        """Attach/override args after the span opened (e.g. an action id
        only known once the work completes)."""
        if self.args is None:
            self.args = {}
        self.args.update(args)


class Tracer:
    """Bounded-ring span recorder with a Chrome-trace JSON exporter.

    ``capacity`` bounds retained events (FIFO drop; ``events_dropped``
    counts evictions).  All methods are thread-safe: spans record their
    own thread id, and the ring append happens under a lock only at span
    *exit*, never per instruction inside the span.
    """

    def __init__(self, capacity: int = 1 << 16) -> None:
        self.capacity = capacity
        self._events: Deque[Dict[str, Any]] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._enabled = False
        self._epoch = time.monotonic()
        self.events_total = 0

    # -- control -------------------------------------------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def start(self, clear: bool = True) -> "Tracer":
        """Attach the ring sink: spans/instants record from now on."""
        with self._lock:
            if clear:
                self._events.clear()
                self.events_total = 0
                self._epoch = time.monotonic()
            self._enabled = True
        return self

    def stop(self) -> "Tracer":
        """Detach the sink: span()/instant() return to the no-op path
        (already-recorded events stay in the ring for export)."""
        self._enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.events_total = 0

    @property
    def events_dropped(self) -> int:
        return max(0, self.events_total - len(self._events))

    # -- recording -----------------------------------------------------------

    def span(self, name: str, **args: Any) -> Any:
        """Context manager timing one named region.  Disabled: returns a
        shared null object (no allocation, no clock reads)."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args: Any) -> None:
        """Zero-duration marker event (e.g. a speculative re-dispatch)."""
        if not self._enabled:
            return
        ev = {"name": name, "ph": "i", "s": "t",
              "ts": (time.monotonic() - self._epoch) * 1e6,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)
            self.events_total += 1

    def _record(self, name: str, t0: float, t1: float,
                args: Optional[Dict[str, Any]]) -> None:
        ev = {"name": name, "ph": "X",
              "ts": (t0 - self._epoch) * 1e6,
              "dur": (t1 - t0) * 1e6,
              "pid": os.getpid(), "tid": threading.get_ident()}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)
            self.events_total += 1

    # -- export --------------------------------------------------------------

    def events(self) -> List[Dict[str, Any]]:
        """Snapshot of recorded events (ring order = time order per
        thread; cross-thread order is by ``ts``)."""
        with self._lock:
            return list(self._events)

    def export(self, path: str) -> str:
        """Write the ring as Chrome-trace JSON (``traceEvents`` object
        format — loadable by Perfetto / chrome://tracing) and return
        ``path``."""
        payload = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"events_total": self.events_total,
                          "events_dropped": self.events_dropped},
        }
        with open(path, "w") as f:
            json.dump(payload, f)
        return path


#: Process-wide tracer every instrumented layer records against.
TRACER = Tracer()


def span(name: str, **args: Any):
    """``TRACER.span`` shorthand (the instrumentation call sites)."""
    if not TRACER._enabled:
        return _NULL_SPAN
    return _Span(TRACER, name, args or None)


def instant(name: str, **args: Any) -> None:
    """``TRACER.instant`` shorthand."""
    if TRACER._enabled:
        TRACER.instant(name, **args)


@contextmanager
def tracing(tracer: Optional[Tracer] = None,
            clear: bool = True) -> Iterator[Tracer]:
    """Enable the (default) tracer for a block, restoring the previous
    enabled state on exit — the test/benchmark spelling:

    .. code-block:: python

        with obs.tracing() as t:
            m.collect()
        t.export("trace.json")
    """
    t = tracer if tracer is not None else TRACER
    was = t._enabled
    t.start(clear=clear)
    try:
        yield t
    finally:
        t._enabled = was


@contextmanager
def timed(name: str, phases: Optional[Dict[str, float]] = None,
          **args: Any) -> Iterator[Any]:
    """Span + phase accumulator in one: times the block, emits a span
    when tracing is enabled, adds the elapsed seconds into
    ``phases[name]`` (the ``ActionReport.phases`` breakdown) when a dict
    is given, and yields the span (null when disabled) so the block can
    ``set()`` late-known args.  The phase accumulation always runs — two
    clock reads — so per-phase attribution survives with tracing off."""
    t0 = time.monotonic()
    s = span(name, **args)
    s.__enter__()
    try:
        yield s
    finally:
        s.__exit__(None, None, None)
        if phases is not None:
            phases[name] = phases.get(name, 0.0) + (time.monotonic() - t0)
