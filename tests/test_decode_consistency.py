"""Teacher-forced decode == full forward for every family (exactness of
KV caches, ring buffers, SSM/xLSTM recurrent states)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s, n_gen = 2, 12, 4
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s + n_gen)),
                       jnp.int32)
    batch = {"tokens": toks}
    pre = {"tokens": toks[:, :s]}
    if cfg.family == "audio":
        fr = jnp.asarray(RNG.normal(size=(b, cfg.encoder_seq,
                                          cfg.d_model)), jnp.float32)
        batch["frames"] = fr
        pre["frames"] = fr
    pe = 0
    if cfg.family == "vlm" and cfg.num_patches:
        p_emb = jnp.asarray(RNG.normal(size=(b, cfg.num_patches,
                                             cfg.d_model)), jnp.float32)
        batch["patch_embeds"] = p_emb
        pre["patch_embeds"] = p_emb
        pe = cfg.num_patches
    full = model.forward(params, batch)
    logits_p, caches = model.prefill(params, pre, s + n_gen + pe)
    err = [float(jnp.max(jnp.abs(full[:, :logits_p.shape[1]] - logits_p)))]
    for t in range(n_gen):
        lg, caches = model.decode_step(params, caches, toks[:, s + t])
        err.append(float(jnp.max(jnp.abs(full[:, pe + s + t] - lg))))
    assert max(err) < 2e-3, (arch, err)
