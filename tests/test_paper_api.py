"""Paper-spelling API surface, end to end: ``inputMountPoint=`` /
``outputMountPoint=``, ``repartitionBy``, ``reduceByKey``, and the
``TextFile`` / ``BinaryFiles`` mount aliases — each through a full
action (the listings must keep working verbatim over the manifest API).

Every paper spelling is now a deprecated shim over the snake_case API
(one alias table in ``repro.core.mare``); this module opts out of the
repo-wide error filter because exercising those shims is its job."""
import numpy as np
import pytest

from repro.core import (BinaryFiles, MaRe, PlanCache, TextFile)
from repro.io.formats import pack_records

pytestmark = pytest.mark.filterwarnings(
    "always::repro.deprecations.MaReDeprecationWarning")


def _key_mod3(recs):
    return recs[0] % 3


def test_listing1_textfile_mount_points_full_chain():
    """Listing 1 spelling: camelCase mount kwargs through map+reduce."""
    rng = np.random.default_rng(11)
    dna = rng.integers(0, 4, size=123).astype(np.int32)
    out = (MaRe((dna,), plan_cache=PlanCache())
           .map(inputMountPoint=TextFile("/dna", dtype=np.int32),
                outputMountPoint=TextFile("/count"),
                image="ubuntu", command="grep-count 2 3")
           .reduce(inputMountPoint=TextFile("/counts"),
                   outputMountPoint=TextFile("/sum"),
                   image="ubuntu", command="awk-sum"))
    got = int(out.collect_first_shard()[0][0])
    assert got == int(np.sum((dna == 2) | (dna == 3)))


def test_listing3_binaryfiles_mount_over_byte_records():
    """BinaryFiles (paper Listing 3): dict-of-named-arrays records flow
    through a byte-oriented container with the mount keys checked."""
    records = [b"GCGCAA", b"TTTT", b"CCG"]
    packed = pack_records(records, capacity=8)
    expected = sum(r.count(b"G") + r.count(b"C") for r in records)
    out = (MaRe(packed, plan_cache=PlanCache())
           .map(inputMountPoint=BinaryFiles("/dna", keys=("data", "len")),
                outputMountPoint=TextFile("/count"),
                image="ubuntu", command="grep-chars GC")
           .reduce(image="ubuntu", command="awk-sum"))
    assert int(out.collect_first_shard()[0][0]) == expected


def test_repartitionBy_alias_full_collect():
    data = np.arange(24, dtype=np.int32)
    m = MaRe((data,), plan_cache=PlanCache()).repartitionBy(_key_mod3)
    got = m.collect()
    assert sorted(got[0].tolist()) == data.tolist()


def test_reduceByKey_alias_full_collect():
    rng = np.random.default_rng(3)
    keys = rng.integers(0, 5, size=40).astype(np.int32)
    vals = rng.normal(size=40).astype(np.float32)
    m = MaRe((keys, vals), plan_cache=PlanCache()).reduceByKey(
        lambda r: r[0], value_by=lambda r: (r[1],), op="sum", num_keys=5)
    out_keys, (out_sum,), out_cnt = m.collect()
    for k, s, c in zip(out_keys, out_sum, out_cnt):
        sel = keys == int(k)
        assert int(c) == int(sel.sum())
        assert abs(float(s) - float(vals[sel].sum())) < 1e-4


def test_snake_case_and_camel_case_mounts_are_interchangeable():
    dna = np.arange(16, dtype=np.int32) % 4
    a = (MaRe((dna,), plan_cache=PlanCache())
         .map(input_mount=TextFile("/dna"), output_mount=TextFile("/c"),
              image="ubuntu", command="grep-count 1"))
    b = (MaRe((dna,), plan_cache=PlanCache())
         .map(inputMountPoint=TextFile("/dna"),
              outputMountPoint=TextFile("/c"),
              image="ubuntu", command="grep-count 1"))
    np.testing.assert_array_equal(a.collect()[0], b.collect()[0])


def test_binaryfiles_missing_key_fails_at_build():
    from repro.core import PlanTypeError
    packed = pack_records([b"ACGT"], capacity=4)
    with pytest.raises(PlanTypeError, match="missing files"):
        MaRe(packed, plan_cache=PlanCache()).map(
            inputMountPoint=BinaryFiles("/dna", keys=("data", "quality")),
            image="ubuntu", command="grep-chars GC")
