"""Fused RMSNorm kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import rmsnorm, rmsnorm_ref

RNG = np.random.default_rng(2)


@pytest.mark.parametrize("shape", [(128, 256), (33, 100), (4, 8, 64),
                                   (1, 512), (256, 128)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6),
                                       (jnp.bfloat16, 2e-2)])
def test_rmsnorm_vs_ref(shape, dtype, tol):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    w = jnp.asarray(RNG.normal(size=shape[-1]), dtype)
    out = rmsnorm(x, w, block_rows=32, interpret=True)
    ref = rmsnorm_ref(x, w)
    err = float(jnp.max(jnp.abs((out - ref).astype(jnp.float32))))
    assert err < tol, (shape, dtype, err)
