"""Trainer: convergence, failure injection + restart, replay determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.optim.schedule import constant
from repro.train import (FailureInjector, StepConfig, Trainer,
                         TrainerConfig, init_train_state, make_train_step)

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", remat=False)


def _setup(tmp_path, fail_at=None, total=30):
    model = build_model(CFG)
    opt = adamw()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, constant(1e-3),
                                   StepConfig()))

    fixed = []
    for i in range(4):        # small cycling set -> memorizable signal
        r = np.random.default_rng(i)
        t = r.integers(0, 64, (4, 16)).astype(np.int32)
        fixed.append({"tokens": jnp.asarray(t),
                      "labels": jnp.asarray(np.roll(t, -1, 1))})

    def batch_fn(i):
        return fixed[i % len(fixed)]

    mgr = CheckpointManager(str(tmp_path), keep=2)
    tr = Trainer(step, state, None, mgr,
                 TrainerConfig(total_steps=total, checkpoint_every=10,
                               log_every=5),
                 injector=FailureInjector(fail_at=fail_at),
                 batch_fn=batch_fn)
    return tr


def test_loss_decreases(tmp_path):
    tr = _setup(tmp_path)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]


def test_restart_after_failure_reaches_total(tmp_path):
    tr = _setup(tmp_path, fail_at=[15, 25])
    final = tr.run()
    assert int(final.step) == 30
    assert tr.restarts == 2


def test_restart_resumes_from_checkpoint_not_zero(tmp_path):
    tr = _setup(tmp_path, fail_at=[15])
    final = tr.run()
    # checkpoint at 10 -> failure at 15 -> restart trains 10..30
    assert int(final.step) == 30
    assert tr.restarts == 1
