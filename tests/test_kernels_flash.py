"""Flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention_ref, flash_attention

RNG = np.random.default_rng(0)

CASES = [
    # (b, hq, hkv, sq, sk, d, causal, window)
    (2, 4, 2, 64, 64, 32, True, None),
    (1, 8, 2, 40, 40, 64, False, None),
    (1, 4, 4, 96, 96, 32, True, 32),
    (1, 2, 1, 16, 128, 32, True, None),    # cross lengths (right-aligned)
    (1, 3, 1, 33, 77, 16, True, None),     # unaligned everything
    (2, 2, 2, 128, 128, 128, True, None),  # MXU-aligned
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_flash_vs_ref(case, dtype, tol):
    b, hq, hkv, sq, sk, d, causal, window = case
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, sk, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs((out - ref).astype(jnp.float32))))
    assert err < tol, (case, dtype, err)


def test_block_sizes():
    q = jnp.asarray(RNG.normal(size=(1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 64, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, 64, 32)), jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(8, 8), (16, 64), (64, 16), (128, 128)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        assert float(jnp.max(jnp.abs(out - ref))) < 2e-5, (bq, bk)
