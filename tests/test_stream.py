"""repro.stream: continuous sources, incremental keyed aggregation,
windows, live queries — and the exactness contract: the incrementally
maintained aggregate is bit-identical to a one-shot reduce_by_key over
the union of all epochs, for ANY partition of the input into epochs."""
import os
import threading

import numpy as np
import pytest

import jax
from repro import compat
from repro.core import MaRe, PlanCache
from repro.io import text_source
from repro.runtime import Executor, MaterializationCache
from repro.serve import QueryService, ServiceConfig
from repro.stream import (ContinuousSource, IncrementalQuery, LiveQuery,
                          WindowedQuery)

NUM_KEYS = 7


def _mesh():
    return compat.make_mesh((jax.device_count(),), ("data",))


def _drop(root, name, lines):
    path = os.path.join(root, name)
    with open(path + ".tmp", "w") as f:
        f.write("\n".join(lines) + "\n")
    os.rename(path + ".tmp", path)   # atomic arrival, the object-store way


def _lines(rng, n):
    return ["".join(rng.choice(list("ACGT"),
                               size=int(rng.integers(4, 30))))
            for _ in range(n)]


# module-level keyBy/valueBy: plan + lineage signatures key on callable
# identity, so the suffix must reuse the SAME objects every epoch
def _key7(recs):
    return (recs["data"][:, 0].astype(np.int32) % NUM_KEYS)


def _len_val(recs):
    return (recs["len"].astype(np.int32),)


def _oob_key(recs):
    return recs["len"].astype(np.int32) + 100    # far outside NUM_KEYS


def _build_for(op):
    def build(m):
        return m.reduce_by_key(_key7, value_by=_len_val, op=op,
                               num_keys=NUM_KEYS)
    return build


def _sorted_table(keys, vals, counts):
    order = np.argsort(keys)
    return keys[order], vals[order], counts[order]


def _query(root, build, **kw):
    kw.setdefault("plan_cache", PlanCache())
    kw.setdefault("executor", Executor(mat_cache=MaterializationCache()))
    cont = ContinuousSource(text_source(root), _mesh(), capacity=256)
    return IncrementalQuery(cont, build, **kw)


# -- exactness: any epoch partition == one-shot over the union ----------------

@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_incremental_equals_oneshot_for_any_epoch_partition(
        tmp_path, op, seed):
    rng = np.random.default_rng(seed)
    build = _build_for(op)
    q = _query(str(tmp_path), build)
    total = 0
    for epoch in range(int(rng.integers(2, 6))):
        _drop(str(tmp_path), f"part{epoch:03d}.txt",
              _lines(rng, int(rng.integers(2, 14))))
        update = q.update()
        assert update is not None and update.epoch == epoch
        total += update.new_splits
    keys, (vals,), counts = q.collect()
    one = build(MaRe.from_source(text_source(str(tmp_path)), _mesh(),
                                 capacity=1024))
    okeys, (ovals,), ocounts = one.collect()
    got = _sorted_table(np.asarray(keys), np.asarray(vals),
                        np.asarray(counts))
    want = _sorted_table(np.asarray(okeys), np.asarray(ovals),
                         np.asarray(ocounts))
    for g, w in zip(got, want):
        assert g.dtype == w.dtype           # same dtype,
        assert np.array_equal(g, w)         # same values, exactly
    assert q.epoch == q.source.watermark


def test_incremental_zero_recompiles_after_first_epoch(tmp_path):
    rng = np.random.default_rng(7)
    pc = PlanCache()
    q = _query(str(tmp_path), _build_for("sum"), plan_cache=pc)
    epochs = 5
    for epoch in range(epochs):
        _drop(str(tmp_path), f"e{epoch}.txt", _lines(rng, 6))
        q.update()
    stats = pc.stats()
    # ONE delta program compiled at epoch 0, hit every epoch after;
    # ONE fold program compiled at epoch 1 (first two-table fold)
    assert stats["programs"] == 1
    assert stats["hits"] == epochs - 1
    assert q.fold_engine.compiles == 1
    assert q.fold_engine.folds == epochs - 1


def test_key_overflow_raises_like_oneshot(tmp_path):
    rng = np.random.default_rng(3)
    _drop(str(tmp_path), "bad.txt", _lines(rng, 5))

    def build(m):
        return m.reduce_by_key(_oob_key, value_by=_len_val, op="sum",
                               num_keys=NUM_KEYS)
    q = _query(str(tmp_path), build)
    with pytest.raises(RuntimeError, match="overflow"):
        q.update()
    one = build(MaRe.from_source(text_source(str(tmp_path)), _mesh(),
                                 capacity=256))
    with pytest.raises(RuntimeError, match="overflow"):
        one.collect()


# -- continuous source --------------------------------------------------------

def test_poll_is_monotone_and_consumes_no_empty_epochs(tmp_path):
    rng = np.random.default_rng(0)
    cont = ContinuousSource(text_source(str(tmp_path)), _mesh(),
                            capacity=64)
    assert cont.poll() is None and cont.watermark == -1
    _drop(str(tmp_path), "a.txt", _lines(rng, 3))
    batch = cont.poll()
    assert batch.epoch == 0 and batch.num_splits == 1
    assert cont.poll() is None           # same files -> nothing new
    _drop(str(tmp_path), "b.txt", _lines(rng, 3))
    _drop(str(tmp_path), "c.txt", _lines(rng, 3))
    batch = cont.poll()
    assert batch.epoch == 1 and batch.num_splits == 2   # one epoch, both
    assert len(cont.seen_splits()) == 3


def test_incremental_report_carries_stream_counters(tmp_path):
    rng = np.random.default_rng(1)
    q = _query(str(tmp_path), _build_for("sum"))
    _drop(str(tmp_path), "a.txt", _lines(rng, 4))
    q.update()
    _drop(str(tmp_path), "b.txt", _lines(rng, 4))
    update = q.update()
    rep = update.report
    assert rep is not None
    assert rep.counters["stream.epoch"] == 1
    assert rep.counters["stream.watermark"] == 1
    assert rep.counters["stream.new_splits"] == 1
    assert "stream.fold" in rep.phases
    assert "[incremental @ epoch 1]" in q.describe()


def test_generations_are_distinct_and_old_ones_dropped(tmp_path):
    rng = np.random.default_rng(2)
    executor = Executor(mat_cache=MaterializationCache())
    q = _query(str(tmp_path), _build_for("sum"), executor=executor)
    seen = set()
    epochs = 4
    for epoch in range(epochs):
        _drop(str(tmp_path), f"e{epoch}.txt", _lines(rng, 3))
        q.update()
        lineage = q.state.lineage
        assert lineage not in seen       # (base, watermark) per generation
        seen.add(lineage)
    stats = executor.mat_cache.stats()
    # every superseded generation was explicitly invalidated
    assert stats["invalidations"] == epochs - 1
    assert executor.mat_cache.get(q.state.lineage) is not None


# -- plan-suffix validation ---------------------------------------------------

def test_plan_must_end_in_reduce_by_key(tmp_path):
    rng = np.random.default_rng(4)
    _drop(str(tmp_path), "a.txt", _lines(rng, 3))
    q = _query(str(tmp_path), lambda m: m)       # identity plan
    with pytest.raises(ValueError, match="reduce_by_key"):
        q.update()


def test_build_must_produce_the_same_plan_every_epoch(tmp_path):
    rng = np.random.default_rng(5)
    builds = [_build_for("sum"), _build_for("max")]

    def unstable(m):
        return builds.pop(0)(m)
    q = _query(str(tmp_path), unstable)
    _drop(str(tmp_path), "a.txt", _lines(rng, 3))
    q.update()
    _drop(str(tmp_path), "b.txt", _lines(rng, 3))
    with pytest.raises(ValueError, match="SAME suffix"):
        q.update()


# -- windows ------------------------------------------------------------------

def _window_oneshot(tmp_path, build, names):
    root = str(tmp_path / "window-ref")
    os.makedirs(root, exist_ok=True)
    for name in names:
        data = open(os.path.join(str(tmp_path), name)).read()
        with open(os.path.join(root, name), "w") as f:
            f.write(data)
    one = build(MaRe.from_source(text_source(root), _mesh(),
                                 capacity=1024))
    return one.collect()


@pytest.mark.parametrize("size,slide", [(2, 1), (2, 2), (3, 3)])
def test_window_aggregate_covers_exactly_the_ring(tmp_path, size, slide):
    rng = np.random.default_rng(6)
    build = _build_for("sum")
    cont = ContinuousSource(text_source(str(tmp_path)), _mesh(),
                            capacity=256)
    w = WindowedQuery(cont, build, size=size, slide=slide,
                      plan_cache=PlanCache(),
                      executor=Executor(mat_cache=MaterializationCache()))
    epochs = 6
    names = []
    for epoch in range(epochs):
        name = f"e{epoch}.txt"
        names.append(name)
        _drop(str(tmp_path), name, _lines(rng, 5))
        w.update()
    # the last emission happened at the newest slide boundary; its window
    # is the `size` epochs ending there
    last_emit = (epochs // slide) * slide - 1
    covered = names[max(0, last_emit - size + 1):last_emit + 1]
    keys, (vals,), counts = w.collect()
    okeys, (ovals,), ocounts = _window_oneshot(tmp_path, build, covered)
    got = _sorted_table(np.asarray(keys), np.asarray(vals),
                        np.asarray(counts))
    want = _sorted_table(np.asarray(okeys), np.asarray(ovals),
                         np.asarray(ocounts))
    for g, x in zip(got, want):
        assert np.array_equal(g, x)
    assert w.window_epochs == tuple(
        range(max(0, epochs - size), epochs))
    assert w.evicted == epochs - size


def test_window_eviction_invalidates_cache_entries(tmp_path):
    rng = np.random.default_rng(8)
    executor = Executor(mat_cache=MaterializationCache())
    cont = ContinuousSource(text_source(str(tmp_path)), _mesh(),
                            capacity=128)
    w = WindowedQuery(cont, _build_for("sum"), size=2, slide=1,
                      plan_cache=PlanCache(), executor=executor)
    for epoch in range(4):
        _drop(str(tmp_path), f"e{epoch}.txt", _lines(rng, 3))
        w.update()
    # 2 expired per-epoch partials + superseded window generations
    assert executor.mat_cache.stats()["invalidations"] >= 2
    assert w.evicted == 2


def test_window_validates_size_and_slide(tmp_path):
    cont = ContinuousSource(text_source(str(tmp_path)), _mesh())
    with pytest.raises(ValueError, match="size"):
        WindowedQuery(cont, _build_for("sum"), size=0)
    with pytest.raises(ValueError, match="slide"):
        WindowedQuery(cont, _build_for("sum"), size=2, slide=3)
    t = WindowedQuery.tumbling(cont, _build_for("sum"), size=3)
    assert t.slide == t.size == 3


# -- sessions + live queries --------------------------------------------------

def _service():
    return QueryService(
        executor=Executor(plan_cache=PlanCache(),
                          mat_cache=MaterializationCache()),
        config=ServiceConfig(batch_window_s=0.0))


def test_session_stream_routes_reports_through_session(tmp_path):
    rng = np.random.default_rng(9)
    with _service() as svc:
        sess = svc.session("alice")
        cont = ContinuousSource(text_source(str(tmp_path)), _mesh(),
                                capacity=128)
        q = sess.stream(cont, _build_for("sum"))
        _drop(str(tmp_path), "a.txt", _lines(rng, 4))
        update = q.update()
        assert update is not None
        assert sess.reports.appended == 1
        rep = sess.report()
        assert rep.tenant == "alice"
        assert rep.counters["stream.epoch"] == 0
        assert rep.label.startswith("alice/stream")
        with pytest.raises(TypeError, match="reports"):
            sess.stream(cont, _build_for("sum"), reports=sess.reports)


def test_live_query_drives_follow_loop(tmp_path):
    rng = np.random.default_rng(10)
    with _service() as svc:
        sess = svc.session("alice")
        cont = ContinuousSource(text_source(str(tmp_path)), _mesh(),
                                capacity=128)
        q = sess.stream(cont, _build_for("sum"))
        refreshed = threading.Event()
        with LiveQuery(q, interval_s=0.05,
                       on_refresh=lambda _u: refreshed.set()) as live:
            _drop(str(tmp_path), "a.txt", _lines(rng, 4))
            reports = sess.follow(0, timeout=30.0)   # wakes per refresh
            assert reports and reports[0].tenant == "alice"
            assert refreshed.wait(timeout=30.0)
            assert live.running
        assert not live.running
        assert live.refreshes >= 1
        assert live.latest is not None and live.latest.epoch == 0


def test_live_query_surfaces_refresh_errors_on_stop(tmp_path):
    rng = np.random.default_rng(11)
    _drop(str(tmp_path), "bad.txt", _lines(rng, 3))

    def build(m):
        return m.reduce_by_key(_oob_key, value_by=_len_val, op="sum",
                               num_keys=NUM_KEYS)
    q = _query(str(tmp_path), build)
    live = LiveQuery(q, interval_s=0.05).start()
    deadline = 30.0
    while live.error is None and deadline > 0:
        threading.Event().wait(0.05)
        deadline -= 0.05
    with pytest.raises(RuntimeError, match="overflow"):
        live.stop()
