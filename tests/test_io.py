"""repro.io: backends, split planning, record formats, parallel ingest."""
import os
import tempfile

import numpy as np
import pytest

from repro import compat
from repro.core import MaRe, collect
from repro.io import (BACKEND_PROFILES, EmulatedObjectStore, FastaFormat,
                      LineFormat, LocalFS, SmilesFormat, assign_splits,
                      fasta_source, ingest, make_backend, pack_records,
                      plan_splits, text_source, unpack_records)


@pytest.fixture
def text_file(tmp_path):
    p = tmp_path / "data.txt"
    lines = [f"record-{i:04d}-{'x' * (i % 17)}" for i in range(200)]
    p.write_text("\n".join(lines) + "\n")
    return str(p), lines


# -- backends ----------------------------------------------------------------

def test_localfs_list_size_read_range(text_file):
    path, lines = text_file
    be = LocalFS(path)
    assert be.list() == [path]
    raw = open(path, "rb").read()
    assert be.size(path) == len(raw)
    assert be.read_range(path, 5, 25) == raw[5:25]
    assert be.read_range(path, len(raw) - 3, len(raw) + 50) == raw[-3:]


def test_localfs_lists_directory_recursively(tmp_path):
    (tmp_path / "sub").mkdir()
    (tmp_path / "a.txt").write_text("aaa\n")
    (tmp_path / "sub" / "b.txt").write_text("bbb\n")
    names = [os.path.basename(p) for p in LocalFS(str(tmp_path)).list()]
    assert names == ["a.txt", "b.txt"]


def test_emulated_backends_return_identical_bytes(text_file):
    path, _ = text_file
    raw = open(path, "rb").read()
    for kind in ("hdfs", "swift", "s3"):
        be = make_backend(kind, path)
        assert be.name == kind
        assert be.read_range(path, 0, len(raw)) == raw
        assert be.stats["requests"] >= 1
    assert set(BACKEND_PROFILES) == {"hdfs", "swift", "s3"}


def test_emulated_backend_latency_is_paid(text_file):
    import time
    path, _ = text_file
    be = EmulatedObjectStore(LocalFS(path), latency_s=0.02)
    t0 = time.monotonic()
    be.read_range(path, 0, 10)
    assert time.monotonic() - t0 >= 0.02


# -- split planning ----------------------------------------------------------

def test_plan_splits_cover_file_exactly(text_file):
    path, _ = text_file
    be = LocalFS(path)
    size = be.size(path)
    splits = plan_splits(be, split_bytes=100)
    assert splits[0].start == 0 and splits[-1].stop == size
    for a, b in zip(splits, splits[1:]):
        assert a.stop == b.start          # contiguous, no gaps/overlap
    assert sum(s.length for s in splits) == size


def test_plan_splits_num_splits_override(text_file):
    path, _ = text_file
    splits = plan_splits(LocalFS(path), num_splits=7)
    assert 6 <= len(splits) <= 8


def test_assign_splits_balances_and_preserves_order(text_file):
    path, _ = text_file
    splits = plan_splits(LocalFS(path), split_bytes=64)
    bins = assign_splits(splits, 4)
    assert sum(len(b) for b in bins) == len(splits)
    loads = [sum(s.length for s in b) for b in bins]
    assert max(loads) - min(loads) <= 2 * 64
    for b in bins:   # plan order within a shard
        starts = [(s.path, s.start) for s in b]
        assert starts == sorted(starts)


# -- formats -----------------------------------------------------------------

def test_line_format_exactly_once_across_any_split_size(text_file):
    """The InputFormat ownership rule: every record is read exactly once
    no matter how the file is carved."""
    path, lines = text_file
    be = LocalFS(path)
    fmt = LineFormat()
    expected = [ln.encode() for ln in lines]
    for split_bytes in (17, 64, 100, 999, 10 ** 9):
        splits = plan_splits(be, split_bytes=split_bytes)
        got = [r for sp in splits for r in fmt.read_split(be, sp)]
        assert got == expected, f"split_bytes={split_bytes}"


def test_fasta_format_drops_headers(tmp_path):
    p = tmp_path / "g.fa"
    p.write_text(">chr1 desc\nATGC\nGGCC\n>chr2\nTTAA\n")
    be = LocalFS(str(p))
    (sp,) = plan_splits(be)
    assert FastaFormat().read_split(be, sp) == [b"ATGC", b"GGCC", b"TTAA"]


def test_smiles_format_first_token(tmp_path):
    p = tmp_path / "m.smi"
    p.write_text("CCO ethanol 42\nc1ccccc1 benzene\n\nO water\n")
    be = LocalFS(str(p))
    (sp,) = plan_splits(be)
    assert SmilesFormat().read_split(be, sp) == [b"CCO", b"c1ccccc1", b"O"]


def test_pack_unpack_roundtrip():
    recs = [b"a", b"bb", b"", b"dddd"]
    packed = pack_records(recs, capacity=8, width=16)
    assert packed["data"].shape == (8, 16)
    assert packed["data"].dtype == np.uint8
    assert list(packed["len"][:4]) == [1, 2, 0, 4]
    assert unpack_records(packed, count=4) == recs
    with pytest.raises(ValueError):
        pack_records(recs, capacity=2)
    with pytest.raises(ValueError):
        pack_records(recs, width=2)


# -- columnar framing: parity + split-carving properties ---------------------

def _random_payload(fmt_name: str, rng: np.random.Generator) -> bytes:
    """Adversarial per-format payloads: empty lines, whitespace-only
    lines, CR before LF, trailing record with no final newline, runs of
    FASTA headers (so small splits can be header-only), SMILES lines with
    multi-space separators and missing metadata."""
    lines = []
    for _ in range(int(rng.integers(0, 40))):
        kind = rng.random()
        body = "".join(rng.choice(list("ACGTacgt01xyz"),
                                  size=int(rng.integers(0, 12))))
        if kind < 0.12:
            lines.append("")                          # empty line
        elif kind < 0.2:
            lines.append(" \t " if fmt_name != "smiles" else "  ")
        elif kind < 0.35 and fmt_name == "fasta":
            lines.append(rng.choice([">", ";"]) + "hdr " + body)
        elif kind < 0.35 and fmt_name == "smiles":
            lines.append(body + rng.choice(["", " name 42", "\tmeta",
                                            "  two  spaces"]))
        elif kind < 0.45:
            lines.append(body + "\r")                 # CR before the LF
        else:
            lines.append(body)
    payload = "\n".join(lines)
    if lines and rng.random() < 0.7:
        payload += "\n"                               # maybe no trailing \n
    return payload.encode()


def test_frame_matches_parse_on_adversarial_payloads():
    """Byte parity of the vectorized columnar framing against the legacy
    per-line parser across all three formats and 150 random payloads
    (plus the hand-picked edge cases)."""
    from repro.io.formats import FORMATS
    fixed = [b"", b"\n", b"\n\n\n", b"abc", b"abc\n", b"a\n\nb\n",
             b" \t\n x \n", b">only-header\n", b">h1\n>h2\n;h3\n",
             b"tok rest\n\ntok2\t\n", b"a\r\nb\r\n", b"x"]
    rng = np.random.default_rng(7)
    for name, fmt in FORMATS.items():
        payloads = fixed + [_random_payload(name, rng) for _ in range(50)]
        for payload in payloads:
            legacy = fmt.parse(payload)
            batch = fmt.frame(payload)
            assert batch.to_list() == legacy, (name, payload)


def test_split_carving_exactly_once_property():
    """The InputFormat ownership rule as a property: for random contents
    and random split boundaries, the union of per-split records equals
    the whole-file parse — every record exactly once, in order — on both
    the legacy and the columnar batch read paths."""
    from repro.io.formats import FORMATS
    from repro.io.splits import InputSplit
    rng = np.random.default_rng(11)
    for name, fmt in FORMATS.items():
        for trial in range(12):
            payload = _random_payload(name, rng)
            if not payload:
                continue
            expected = fmt.parse(payload)
            # random carve: sorted unique cut points over [0, size]
            ncuts = int(rng.integers(0, 8))
            cuts = sorted({0, len(payload),
                           *rng.integers(1, max(len(payload), 2),
                                         size=ncuts).tolist()})
            with tempfile.NamedTemporaryFile(suffix=".dat") as f:
                f.write(payload)
                f.flush()
                be = LocalFS(f.name)
                splits = [InputSplit(f.name, a, b, len(payload))
                          for a, b in zip(cuts, cuts[1:])]
                legacy = [r for sp in splits
                          for r in fmt.read_split(be, sp)]
                batched = [r for sp in splits
                           for r in fmt.read_split_batch(be, sp).to_list()]
            assert legacy == expected, (name, trial, cuts, payload)
            assert batched == expected, (name, trial, cuts, payload)


def test_pack_batches_matches_pack_records_oracle():
    """One bulk gather == row-at-a-time packing, over ragged batches
    including zero-length records, empty batches and uniform-stride
    (fast-path) batches."""
    from repro.io.formats import RecordBatch, pack_batches
    rng = np.random.default_rng(3)
    cases = [
        [],                                           # no batches at all
        [[]],                                         # one empty batch
        [[b""], [b"", b""]],                          # zero-length records
        [[b"abc", b"de", b"", b"fghij"]],             # ragged
        [[b"aaaa"] * 5],                              # uniform fast path
        [[b"xy"], [], [b"z" * 30, b""], [b"q"] * 3],  # mixed
    ]
    for _ in range(10):
        cases.append([[bytes(rng.integers(0, 256, int(rng.integers(0, 9)),
                                          dtype=np.uint8).tobytes())
                       for _ in range(int(rng.integers(0, 7)))]
                      for _ in range(int(rng.integers(1, 4)))])
    for recs_per_batch in cases:
        flat = [r for recs in recs_per_batch for r in recs]
        cap = max(len(flat), 1) + int(rng.integers(0, 4))
        w = max((len(r) for r in flat), default=1) + int(rng.integers(0, 4))
        w = max(w, 1)
        oracle = pack_records(flat, capacity=cap, width=w)
        batches = [RecordBatch.from_records(recs)
                   for recs in recs_per_batch]
        got = pack_batches(batches, capacity=cap, width=w)
        np.testing.assert_array_equal(got["data"], oracle["data"])
        np.testing.assert_array_equal(got["len"], oracle["len"])
    with pytest.raises(ValueError):
        pack_batches([RecordBatch.from_records([b"abc"])], width=2)
    with pytest.raises(ValueError):
        pack_batches([RecordBatch.from_records([b"a", b"b"])], capacity=1)


def test_ingest_parser_parity_and_validation(text_file):
    """End-to-end vectorized ingest == legacy ingest (same device bytes),
    pooled == serial, and unknown parser names raise."""
    path, _ = text_file
    mesh = compat.make_mesh((1,), ("data",))
    ref = collect(ingest(text_source(path, split_bytes=128), mesh,
                         parser="legacy"))
    for workers in (1, 4):
        out = collect(ingest(text_source(path, split_bytes=128), mesh,
                             workers=workers))
        np.testing.assert_array_equal(out["data"], ref["data"])
        np.testing.assert_array_equal(out["len"], ref["len"])
    with pytest.raises(ValueError, match="parser"):
        ingest(text_source(path), mesh, parser="simd")


# -- ingestion ---------------------------------------------------------------

def test_ingest_roundtrips_all_records(text_file):
    path, lines = text_file
    source = text_source(path, split_bytes=128)
    mesh = compat.make_mesh((1,), ("data",))
    ds = ingest(source, mesh)
    out = collect(ds)
    got = sorted(unpack_records(out, count=int(np.asarray(
        np.asarray(ds.counts)).sum())))
    assert got == sorted(ln.encode() for ln in lines)


def test_ingest_through_emulated_backend_matches_local(tmp_path):
    p = tmp_path / "g.fa"
    p.write_text(">h\n" + "\n".join(["ATGCGC"] * 50) + "\n")
    mesh = compat.make_mesh((1,), ("data",))
    ref = collect(ingest(fasta_source(str(p), split_bytes=64), mesh))
    for kind in ("hdfs", "swift", "s3"):
        src = fasta_source(str(p), backend=make_backend(kind, str(p)),
                           split_bytes=64)
        out = collect(ingest(src, mesh))
        np.testing.assert_array_equal(out["data"], ref["data"])
        np.testing.assert_array_equal(out["len"], ref["len"])


def test_ingest_capacity_overflow_raises(text_file):
    path, _ = text_file
    mesh = compat.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="capacity"):
        ingest(text_source(path), mesh, capacity=4)


def test_mare_from_source_gc_pipeline(tmp_path):
    p = tmp_path / "g.fa"
    rng = np.random.default_rng(0)
    seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, 3000)])
    p.write_text(">chr\n" + "\n".join(
        seq[i:i + 60] for i in range(0, len(seq), 60)) + "\n")
    total = (MaRe.from_source(fasta_source(str(p), split_bytes=256))
             .map(image="ubuntu", command="grep-chars GC")
             .reduce(image="ubuntu", command="awk-sum")
             .collect(shard=0))
    assert int(total[0][0]) == seq.count("G") + seq.count("C")
