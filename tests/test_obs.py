"""Observability: span tracer (ring, nesting, Chrome-trace export),
metrics registry, per-action phase breakdown, and the disabled-tracing
overhead bound."""
import json
import time

import numpy as np

from repro.core import MaRe, PlanCache
from repro.core.container import ContainerOp
from repro.io import text_source
from repro.obs import (TRACER, MetricsRegistry, Tracer, instant, span,
                       timed, tracing)
from repro.runtime import Executor, MaterializationCache


def _executor() -> Executor:
    return Executor(mat_cache=MaterializationCache())


def _ident_op(name="obs/id"):
    return ContainerOp(image=name, fn=lambda part, **kw: part)


# -- tracer unit behavior -----------------------------------------------------

def test_disabled_span_is_shared_null_object():
    assert not TRACER.enabled
    before = TRACER.events_total
    a, b = span("x", k=1), span("y")
    assert a is b                           # no allocation on the fast path
    with a as s:
        s.set(late=True)                    # all no-ops
    instant("nothing")
    assert TRACER.events_total == before


def test_nested_spans_are_contained_and_args_recorded():
    with tracing() as t:
        with span("outer", k=1) as sp:
            with span("inner"):
                pass
            sp.set(late=2)
        instant("marker", batch=3)
    assert not TRACER.enabled               # tracing() restored the state
    evs = t.events()
    assert [e["name"] for e in evs] == ["inner", "outer", "marker"]
    inner, outer, marker = evs
    assert outer["ts"] <= inner["ts"]
    assert (outer["ts"] + outer["dur"]) >= (inner["ts"] + inner["dur"])
    assert outer["args"] == {"k": 1, "late": 2}
    assert marker["ph"] == "i" and marker["args"] == {"batch": 3}
    assert all(e["ph"] == "X" for e in (inner, outer))


def test_ring_bounds_events_and_counts_drops():
    t = Tracer(capacity=8).start()
    for i in range(20):
        t.instant(f"e{i}")
    assert len(t.events()) == 8
    assert t.events_total == 20
    assert t.events_dropped == 12
    assert [e["name"] for e in t.events()] == [f"e{i}" for i in range(12, 20)]


def test_export_writes_valid_chrome_trace_object(tmp_path):
    with tracing() as t:
        with span("work", n=1):
            pass
    out = t.export(str(tmp_path / "trace.json"))
    with open(out) as f:
        payload = json.load(f)
    assert isinstance(payload["traceEvents"], list)
    assert payload["displayTimeUnit"] == "ms"
    assert payload["otherData"]["events_total"] == 1
    ev = payload["traceEvents"][0]
    assert ev["name"] == "work" and ev["ph"] == "X"
    assert {"ts", "dur", "pid", "tid"} <= set(ev)


def test_timed_accumulates_phases_with_tracing_off():
    assert not TRACER.enabled
    before = TRACER.events_total
    phases = {}
    with timed("p", phases):
        time.sleep(0.01)
    with timed("p", phases):
        pass
    assert phases["p"] >= 0.01              # accumulated across both blocks
    assert TRACER.events_total == before    # no span recorded while off


# -- metrics registry ---------------------------------------------------------

def test_metrics_counter_gauge_histogram_snapshot():
    reg = MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)                 # get-or-create: same instance
    reg.gauge("g").set(7)
    for v in (0.001, 0.002, 0.003):
        reg.histogram("h").observe(v)
    snap = reg.snapshot()
    assert snap["c"] == 5
    assert snap["g"] == 7
    h = snap["h"]
    assert h["count"] == 3
    assert abs(h["mean"] - 0.002) < 1e-9
    assert h["min"] == 0.001 and h["max"] == 0.003
    text = reg.render()
    assert "c" in text and "count=3" in text
    assert reg.render(prefix="h").count("\n") == 0
    reg.reset()
    assert reg.snapshot() == {}


# -- integration: traced source-ingested action -------------------------------

def _contains(outer, inner):
    return (outer["tid"] == inner["tid"]
            and outer["ts"] <= inner["ts"]
            and outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"])


def test_traced_action_exports_nested_spans_and_phases(tmp_path):
    p = tmp_path / "d.txt"
    p.write_text("\n".join(f"line-{i:03d}" for i in range(64)) + "\n")
    ex = _executor()
    with tracing() as t:
        m = MaRe.from_source(text_source(str(p)), executor=ex)
        m.plan_cache = PlanCache()          # fresh: force a real compile
        q = m.repartition_by(
            lambda recs: (recs["data"][:, 0] % 3).astype("int32"))
        q.collect()
    out = t.export(str(tmp_path / "trace.json"))
    with open(out) as f:
        evs = json.load(f)["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"ingest", "ingest.fetch", "ingest.frame", "ingest.gather",
            "ingest.device_put",
            "action", "plan.typecheck", "plan.build", "plan.lower",
            "plan.compile", "dispatch", "counter_sync"} <= names

    # nesting: each executor phase span sits inside an action span on the
    # same thread (Chrome-trace nesting is by containment)
    actions = [e for e in evs if e["name"] == "action"]
    for inner_name in ("plan.build", "plan.lower", "plan.compile",
                       "dispatch", "counter_sync"):
        inner = [e for e in evs if e["name"] == inner_name]
        assert inner, inner_name
        assert all(any(_contains(a, i) for a in actions) for i in inner), \
            inner_name
    # and each per-split fetch sits inside the top-level ingest span's
    # time window (fetches may run on pool threads, so time-only)
    ingest_ev = next(e for e in evs if e["name"] == "ingest")
    for f_ev in (e for e in evs if e["name"] == "ingest.fetch"):
        assert ingest_ev["ts"] <= f_ev["ts"]
        assert (ingest_ev["ts"] + ingest_ev["dur"]
                >= f_ev["ts"] + f_ev["dur"])

    # phase breakdown accounts for the action wall (acceptance: >= 90%)
    rep = q.report()
    assert rep.phases and {"plan.build", "plan.compile",
                           "dispatch"} <= set(rep.phases)
    total = sum(rep.phases.values())
    assert total >= 0.9 * rep.wall_s
    assert total <= rep.wall_s * 1.01       # phases are disjoint sub-spans


def test_mare_metrics_and_trace_to_surface(tmp_path):
    ex = _executor()
    m = MaRe((np.arange(32, dtype=np.int32),), plan_cache=PlanCache(),
             executor=ex).map(op=_ident_op())
    with tracing():
        m.collect()
    out = m.trace_to(str(tmp_path / "t.json"))
    with open(out) as f:
        assert any(e["name"] == "action"
                   for e in json.load(f)["traceEvents"])
    snap = m.metrics()
    assert snap["executor.actions"] >= 1
    assert "phase.dispatch" in snap


# -- overhead bound -----------------------------------------------------------

def test_disabled_tracing_overhead_under_5pct_of_small_action():
    """The instrumentation is always on; with no sink attached a span is
    one attribute load + branch.  Bound: crossing every site a warm fused
    action actually hits (action, cache_lookup, dispatch, device_wait,
    counter_sync + headroom: 16 spans) must cost < 5% of that action."""
    assert not TRACER.enabled
    ex = _executor()
    m = MaRe((np.arange(1 << 20, dtype=np.int32),), plan_cache=PlanCache(),
             executor=ex).map(op=_ident_op())
    m.collect()                             # compile once
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        m.collect()
    action_s = (time.perf_counter() - t0) / reps

    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with span("x"):
            pass
    per_span = (time.perf_counter() - t0) / n
    assert per_span * 16 < 0.05 * action_s, (per_span, action_s)
