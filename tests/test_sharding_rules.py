"""Logical-axis rules: divisibility-safe TP and axis-reuse refusal."""
from jax.sharding import PartitionSpec as P

from repro.sharding import Rules


def _mesh_shape():
    return {"data": 4, "model": 2}


def test_divisible_dims_shard():
    r = Rules(table={"heads": "model", "embed": "data"},
              mesh_shape=_mesh_shape())
    spec = r.spec_for(("embed", "heads"), dims=(8, 6))
    assert spec == P("data", "model")


def test_indivisible_dims_replicate():
    r = Rules(table={"heads": "model"}, mesh_shape=_mesh_shape())
    # 25 heads never shard over a 2-way axis -> replicated
    assert r.spec_for(("heads",), dims=(25,)) == P(None)
    assert r.spec_for(("heads",), dims=(26,)) == P("model")


def test_mesh_axis_used_once():
    r = Rules(table={"a": "model", "b": "model"},
              mesh_shape=_mesh_shape())
    spec = r.spec_for(("a", "b"), dims=(4, 4))
    assert spec == P("model", None)       # second use dropped


def test_tuple_axes():
    r = Rules(table={"batch": ("data", "model")},
              mesh_shape=_mesh_shape())
    assert r.spec_for(("batch",), dims=(8,)) == P(("data", "model"))
    assert r.spec_for(("batch",), dims=(6,)) == P(None)  # 6 % 8 != 0
