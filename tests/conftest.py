"""Main pytest process stays 1-device (multi-device scenarios run in
subprocesses via tests/test_distributed.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
