"""Deprecated-shim contract: every legacy spelling warns EXACTLY once
per process, forwards its arguments unchanged, and the repo-wide pytest
filter (pytest.ini) turns the warnings into errors everywhere else."""
import warnings

import numpy as np
import pytest

from repro import deprecations
from repro.core import MaRe, PlanCache, TextFile
from repro.core.mare import (PAPER_KWARG_ALIASES, PAPER_METHOD_ALIASES)
from repro.deprecations import MaReDeprecationWarning

pytestmark = pytest.mark.filterwarnings(
    "always::repro.deprecations.MaReDeprecationWarning")


@pytest.fixture(autouse=True)
def _fresh_warn_once():
    # warn-once state is process-global; each test starts clean
    deprecations.reset()
    yield
    deprecations.reset()


def _m(n=32):
    return MaRe((np.arange(n, dtype=np.int32),), plan_cache=PlanCache())


def _ident_op():
    from repro.core.container import ContainerOp
    return ContainerOp(image="dep/id", fn=lambda part, **kw: part)


def test_category_is_a_deprecation_warning():
    assert issubclass(MaReDeprecationWarning, DeprecationWarning)


def test_warn_once_is_per_key_not_per_call():
    with pytest.warns(MaReDeprecationWarning):
        _m().collect_first_shard()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        _m().collect_first_shard()          # second call: silent
        with pytest.warns(MaReDeprecationWarning):
            _m().collect_async().result(timeout=60)   # different key
    assert not [w for w in caught
                if issubclass(w.category, MaReDeprecationWarning)]


# -- action shims forward exactly --------------------------------------------

def test_collect_async_forwards(monkeypatch):
    seen = {}
    monkeypatch.setattr(
        MaRe, "collect",
        lambda self, **kw: seen.update(kw) or "value")
    with pytest.warns(MaReDeprecationWarning, match="collect_async"):
        assert _m().collect_async(label="x") == "value"
    assert seen == {"asynchronous": True, "label": "x"}


def test_collect_first_shard_forwards(monkeypatch):
    seen = {}
    monkeypatch.setattr(
        MaRe, "collect",
        lambda self, **kw: seen.update(kw) or "value")
    with pytest.warns(MaReDeprecationWarning,
                      match="collect_first_shard"):
        assert _m().collect_first_shard() == "value"
    assert seen == {"shard": 0}


def test_collect_first_shard_async_forwards(monkeypatch):
    seen = {}
    monkeypatch.setattr(
        MaRe, "collect",
        lambda self, **kw: seen.update(kw) or "value")
    with pytest.warns(MaReDeprecationWarning,
                      match="collect_first_shard_async"):
        assert _m().collect_first_shard_async(label="w3") == "value"
    assert seen == {"shard": 0, "asynchronous": True, "label": "w3"}


def test_collect_shims_equal_canonical_results():
    data = (np.arange(8, dtype=np.int32),)
    with pytest.warns(MaReDeprecationWarning):
        legacy = MaRe(data, plan_cache=PlanCache()).collect_first_shard()
    canonical = MaRe(data, plan_cache=PlanCache()).collect(shard=0)
    assert legacy[0].tolist() == canonical[0].tolist()


# -- last_diagnostics shim ----------------------------------------------------

def test_last_diagnostics_is_view_over_newest_report():
    m = _m().map(op=_ident_op())
    m.collect()
    with pytest.warns(MaReDeprecationWarning, match="last_diagnostics"):
        assert m.last_diagnostics == m.report().diagnostics
    fresh = _m()
    deprecations.reset()
    with pytest.warns(MaReDeprecationWarning):
        assert fresh.last_diagnostics == {}   # no action yet -> empty


# -- paper-spelling aliases ---------------------------------------------------

def test_method_alias_table_is_applied_and_forwards(monkeypatch):
    assert PAPER_METHOD_ALIASES == {"repartitionBy": "repartition_by",
                                    "reduceByKey": "reduce_by_key"}
    calls = {}
    monkeypatch.setattr(
        MaRe, "repartition_by",
        lambda self, *a, **kw: calls.update(args=a, kwargs=kw) or "rb")
    key = lambda recs: recs[0]
    with pytest.warns(MaReDeprecationWarning, match="repartitionBy"):
        assert _m().repartitionBy(key, capacity=7) == "rb"
    assert calls == {"args": (key,), "kwargs": {"capacity": 7}}


def test_reduce_by_key_alias_forwards_all_kwargs(monkeypatch):
    calls = {}
    monkeypatch.setattr(
        MaRe, "reduce_by_key",
        lambda self, *a, **kw: calls.update(args=a, kwargs=kw) or "rbk")
    key = lambda recs: recs[0]
    with pytest.warns(MaReDeprecationWarning, match="reduceByKey"):
        assert _m().reduceByKey(key, num_keys=3, op="max") == "rbk"
    assert calls == {"args": (key,),
                     "kwargs": {"num_keys": 3, "op": "max"}}


def test_mount_kwarg_aliases_translate():
    assert PAPER_KWARG_ALIASES == {"inputMountPoint": "input_mount",
                                   "outputMountPoint": "output_mount"}
    with pytest.warns(MaReDeprecationWarning, match="inputMountPoint"):
        legacy = _m().map(inputMountPoint=TextFile("/x", dtype=np.int32),
                          outputMountPoint=TextFile("/y"),
                          image="ubuntu", command="grep-count 1 2")
    canonical = _m().map(input_mount=TextFile("/x", dtype=np.int32),
                         output_mount=TextFile("/y"),
                         image="ubuntu", command="grep-count 1 2")
    assert legacy.describe() == canonical.describe()


def test_both_alias_and_canonical_kwarg_is_an_error():
    with pytest.raises(TypeError, match="both"):
        _m().map(inputMountPoint=TextFile("/x"),
                 input_mount=TextFile("/x"),
                 image="ubuntu", command="grep-chars GC")
