"""Core MaRe semantics on a single device (shard count 1)."""
from repro import compat
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MaRe, TextFile, RecordMount, FileSetMount, from_host,
                        collect, pull, split_factors)
from repro.core.container import make_partition
from repro.core.tree_reduce import collective_bytes_tree


def test_gc_count_single_device():
    rng = np.random.default_rng(0)
    dna = rng.integers(0, 4, size=333).astype(np.int32)
    true_gc = int(np.sum((dna == 2) | (dna == 3)))
    out = (MaRe((dna,))
           .map(input_mount=TextFile("/dna"),
                output_mount=TextFile("/count"),
                image="ubuntu", command="grep-count 2 3")
           .reduce(input_mount=TextFile("/counts"),
                   output_mount=TextFile("/sum"),
                   image="ubuntu", command="awk-sum"))
    assert int(out.collect(shard=0)[0][0]) == true_gc


def test_map_is_lazy_and_fused():
    m = MaRe((np.arange(10, dtype=np.int32),))
    m2 = m.map(image="toolbox/concat").map(image="toolbox/concat")
    assert len(m2.plan.ops) == 2          # fused into one pending stage
    got = m2.collect()
    assert sorted(got[0].tolist()) == list(range(10))


def test_reduce_requires_assoc_commutative():
    from repro.core.container import ContainerOp

    def not_ac(part, **kw):
        return part

    op = ContainerOp(image="bad", fn=not_ac)
    with pytest.raises(ValueError, match="associative"):
        MaRe((np.arange(4, dtype=np.int32),)).reduce(op=op)


def test_dataset_roundtrip_uneven():
    data = (np.arange(7, dtype=np.int32),
            np.arange(14, dtype=np.float32).reshape(7, 2))
    mesh = compat.make_mesh((1,), ("data",))
    ds = from_host(data, mesh)
    got = collect(ds)
    np.testing.assert_array_equal(got[0], data[0])
    np.testing.assert_array_equal(got[1], data[1])


def test_mount_validation():
    rm = RecordMount("/x", dtype=jnp.int32)
    rm.validate((jnp.zeros((3,), jnp.int32),))
    with pytest.raises(ValueError, match="dtype"):
        rm.validate((jnp.zeros((3,), jnp.float32),))
    fm = FileSetMount("/y", keys=("a",))
    fm.validate({"a": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="missing"):
        fm.validate({"b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="dict"):
        fm.validate((jnp.zeros((2,)),))


def test_registry_pull_unknown():
    with pytest.raises(KeyError, match="not found"):
        pull("no/such/image")


def test_split_factors():
    assert split_factors(16, 2) == [4, 4]
    assert split_factors(16, 4) == [2, 2, 2, 2]
    assert split_factors(8, 2) == [2, 4]
    assert split_factors(1, 2) == [1, 1]
    for n in (2, 6, 12, 16, 64, 256):
        for k in (1, 2, 3):
            f = split_factors(n, k)
            assert len(f) == k
            p = 1
            for x in f:
                p *= x
            assert p == n


def test_collective_bytes_tree_monotone():
    """Deeper trees never ship more bytes per level-sum than depth-1
    (the paper's motivation for K>1 when partitions are large)."""
    b1 = collective_bytes_tree(1000, 16, depth=1)
    b2 = collective_bytes_tree(1000, 16, depth=2)
    assert b2 <= b1


def test_topk_image_masks_invalid():
    op = pull("toolbox/topk", k=3)
    recs = (jnp.asarray([5.0, 4.0, 3.0, 99.0, 98.0]),
            jnp.arange(5, dtype=jnp.int32))
    part = make_partition(recs, 3)    # only first 3 valid
    out = op(part)
    assert set(np.asarray(out.records[1])[:3].tolist()) == {0, 1, 2}
