"""Typed image manifests: schema unification, command grammar, plan-time
type checking / capacity inference, monoid resolution, mount wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ArgSpec, CommandSpec, ImageManifest, MaRe, PlanCache,
                        PlanTypeError, RecordMount, Registry, SAME, Schema,
                        SchemaMismatch, TextFile, bytes_record_schema, field,
                        pull, schema_of_records)
from repro.core.container import ContainerOp, make_partition
from repro.core.images import fn_image
from repro.core.schema import substitute, unify
from repro.io.formats import FORMATS, pack_records


# -- schema primitives --------------------------------------------------------

def test_schema_of_records_and_describe():
    recs = {"data": np.zeros((4, 70), np.uint8),
            "len": np.zeros((4,), np.int32)}
    s = schema_of_records(recs)
    assert s.concrete
    assert s.describe() == "{data: u8[70], len: i32}"
    assert schema_of_records((np.zeros((3,), np.int32),)).describe() \
        == "(i32)"


def test_unify_binds_symbolic_dims():
    declared = bytes_record_schema()            # {"data": u8[W], "len": i32}
    actual = schema_of_records({"data": np.zeros((4, 70), np.uint8),
                                "len": np.zeros((4,), np.int32)})
    env = unify(declared, actual)
    assert env["W"] == 70
    assert substitute(declared, env).describe() == "{data: u8[70], len: i32}"


def test_unify_mismatches_raise_with_leaf_path():
    declared = bytes_record_schema()
    wrong_dtype = schema_of_records({"data": np.zeros((4, 70), np.int32),
                                     "len": np.zeros((4,), np.int32)})
    with pytest.raises(SchemaMismatch, match="dtype"):
        unify(declared, wrong_dtype)
    wrong_structure = schema_of_records((np.zeros((4,), np.int32),))
    with pytest.raises(SchemaMismatch, match="structure"):
        unify(declared, wrong_structure)


def test_format_schema_matches_packed_records():
    packed = pack_records([b"ACGT", b"GG"], capacity=4)
    for fmt in FORMATS.values():
        env = unify(fmt.schema, schema_of_records(packed))
        assert env["W"] == 4


# -- command grammar (pull-time) ----------------------------------------------

def test_grammar_unknown_command_and_missing_arg():
    with pytest.raises(ValueError, match="unknown command 'grep-lines'"):
        pull("ubuntu", command="grep-lines GC")
    with pytest.raises(ValueError, match="missing required argument"):
        pull("ubuntu", command="grep-chars")
    with pytest.raises(ValueError, match="requires a command; grammar"):
        pull("ubuntu")


def test_grammar_typed_args_and_dispatch():
    op = pull("ubuntu", command="grep-count 2 3")
    assert op.params["codes"] == (2, 3)          # typed, not shlex strings
    with pytest.raises(ValueError, match="bad argument for 'codes'"):
        pull("ubuntu", command="grep-count two")
    with pytest.raises(ValueError, match="unexpected arguments"):
        pull("ubuntu", command="awk-sum extra")
    # command dispatch: awk-sum resolves its own implementation + monoid
    awk = pull("ubuntu", command="awk-sum")
    assert awk.associative_commutative
    assert awk.contract.monoid == "sum"
    assert pull("kmer-stats", command="kmer-stats 4").params["k"] == 4


def test_command_argv_overrides_python_kwargs():
    op = pull("kmer-stats", command="kmer-stats 5", k=9)
    assert op.params["k"] == 5                   # the command IS the interface


# -- plan-time type checking (acceptance criteria) ----------------------------

def test_mistyped_pipeline_fails_at_build_not_trace():
    """grep-count emits (i32); grep-chars requires byte records — the chain
    must fail while BUILDING, before anything compiles."""
    cache = PlanCache()
    m = MaRe((np.arange(16, dtype=np.int32),), plan_cache=cache).map(
        image="ubuntu", command="grep-count 2 3")
    with pytest.raises(PlanTypeError) as exc:
        m.map(image="ubuntu", command="grep-chars GC")
    msg = str(exc.value)
    assert "stage 0" in msg                      # names the stage
    assert "{data: u8[W], len: i32}" in msg      # both schemas in message
    assert "(i32)" in msg
    assert "grep-chars" in msg and "grep-count" in msg
    assert cache.stats()["misses"] == 0          # nothing was compiled


def test_reduce_by_key_num_keys_below_declared_key_space():
    packed = pack_records([b"ACGTACGT", b"GGGGCCCC"], capacity=4)
    m = MaRe(packed, plan_cache=PlanCache()).map(image="kmer-stats", k=3)
    with pytest.raises(PlanTypeError, match="num_keys=10 is smaller"):
        m.reduce_by_key(lambda r: r[0], value_by=lambda r: (r[1],),
                        op="sum", num_keys=10)   # key space is 4**3 = 64


def test_reduce_by_key_num_keys_inferred_from_manifest():
    packed = pack_records([b"ACGTACGT", b"GGGGCCCC"], capacity=4)
    m = (MaRe(packed, plan_cache=PlanCache())
         .map(image="kmer-stats", k=3)
         .reduce_by_key(lambda r: r[0], value_by=lambda r: (r[1],),
                        op="sum"))              # num_keys omitted
    assert m.plan.stages[-1].num_keys == 4 ** 3
    keys, (occ,), cnt = m.collect()
    assert int(occ.sum()) == 2 * (8 - 3 + 1)     # all windows valid ACGT


def test_key_space_bound_skipped_when_key_by_remaps():
    """The declared key_space describes the record's key leaf; a key_by
    that remaps keys into a smaller range must not be rejected."""
    packed = pack_records([b"ACGTACGT", b"GGGGCCCC"], capacity=4)
    m = (MaRe(packed, plan_cache=PlanCache())
         .map(image="kmer-stats", k=3)
         .reduce_by_key(lambda r: r[0] % 16, value_by=lambda r: (r[1],),
                        op="sum", num_keys=16))    # < 4**3, but remapped
    keys, (occ,), _ = m.collect()
    assert int(occ.sum()) == 2 * (8 - 3 + 1)
    assert all(0 <= int(k) < 16 for k in keys)


def test_key_space_bound_skipped_when_keyed_on_other_leaf():
    """key_space describes the FIRST record leaf; keying on a different
    column must not trip the bound check."""
    packed = pack_records([b"ACGTACGT"], capacity=2)
    m = (MaRe(packed, plan_cache=PlanCache())
         .map(image="kmer-stats", k=2)
         .reduce_by_key(lambda r: r[1], value_by=lambda r: (r[1],),
                        op="sum", num_keys=2))    # keys on the ones column
    keys, (s,), _ = m.collect()
    assert set(int(k) for k in keys) <= {0, 1}


def test_single_leaf_schema_accepts_bare_array_records():
    """grep-count reads 'the one record array' via tree.leaves, and its
    contract must accept any single-leaf pytree — including a bare
    ndarray, which worked pre-manifest."""
    dna = np.array([2, 3, 0, 1, 2], np.int32)
    m = (MaRe(dna, plan_cache=PlanCache())     # bare array, no tuple wrap
         .map(image="ubuntu", command="grep-count 2 3"))
    assert int(np.asarray(m.collect()).sum()) == 3


def test_fn_image_with_grammarless_manifest_forwards_command():
    seen = {}

    def tool(part, command="", **kw):
        seen["command"] = command
        return part

    reg = Registry()
    fn_image("anon/manifested-cmd", tool, registry=reg,
             manifest=ImageManifest(output_schema=SAME))
    op = reg.pull("anon/manifested-cmd", command="--flag x")
    op(make_partition((jnp.arange(4, dtype=jnp.int32),), 4))
    assert seen["command"] == "--flag x"


def test_optional_variadic_absent_preserves_kwargs():
    op = pull("ubuntu", command="grep-count", codes=(2, 3))
    assert op.params["codes"] == (2, 3)   # empty argv must not clobber


def test_reduce_by_key_num_keys_required_without_key_space():
    keys = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError, match="num_keys not given"):
        MaRe((keys,)).reduce_by_key(lambda r: r[0], op="sum")


def test_key_by_type_checked_at_build():
    vals = np.linspace(0, 1, 16, dtype=np.float32)
    with pytest.raises(PlanTypeError, match="key_by must return one int"):
        MaRe((vals,)).reduce_by_key(lambda r: r[0], op="sum", num_keys=4)
    with pytest.raises(PlanTypeError, match="key_by must return one int"):
        MaRe((vals,)).repartition_by(lambda r: r[0])


def test_capacity_transfer_inferred_in_describe():
    packed = pack_records([b"ACGTACGT"] * 3, capacity=8, width=8)
    m = MaRe(packed, plan_cache=PlanCache()).map(image="kmer-stats", k=3)
    # width 8, k=3: out capacity = per-shard cap * (8 - 3 + 1)
    cap = m._dataset.capacity
    d = m.describe()
    assert f"(i32, i32)#{cap * 6}" in d
    assert "{data: u8[8], len: i32}" in d        # input schema at boundary 0


def test_capacity_transfer_failure_names_stage():
    packed = pack_records([b"ACG"] * 8, capacity=8, width=3)
    with pytest.raises(PlanTypeError, match="stage 0.*capacity transfer"):
        MaRe(packed).map(image="kmer-stats", k=8)   # k=8 > width 3


def test_monoid_resolution_via_manifest_sum_image():
    keys = np.array([0, 1, 0, 1], np.int32)
    vals = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    m = MaRe((keys, vals), plan_cache=PlanCache()).reduce_by_key(
        lambda r: r[0], value_by=lambda r: (r[1],),
        image="toolbox/sum", num_keys=2)
    assert m.plan.stages[-1].op == "sum"
    out_keys, (out_sum,), _ = m.collect()
    got = {int(k): float(s) for k, s in zip(out_keys, out_sum)}
    assert got == {0: 4.0, 1: 6.0}


# -- mount wiring (plan-time + execution-time) --------------------------------

def test_mount_contract_checked_at_plan_time():
    cache = PlanCache()
    m = MaRe((np.arange(8, dtype=np.float32),), plan_cache=cache)
    with pytest.raises(PlanTypeError) as exc:
        m.map(image="toolbox/concat",
              input_mount=TextFile("/x", dtype=jnp.int32))
    assert "stage 0" in str(exc.value)
    assert "input mount" in str(exc.value)
    assert cache.stats()["misses"] == 0


def test_mount_validation_fires_at_execution_with_stage_and_image():
    """Ops without manifests leave the schema unknown, so the mount check
    falls through to stage execution — and must name stage + image."""

    def to_float(part, **kw):
        return make_partition(
            (jax.tree.leaves(part.records)[0].astype(jnp.float32),),
            part.count)

    op1 = ContainerOp(image="anon/to-float", fn=to_float)
    op2 = ContainerOp(
        image="anon/wants-int", fn=lambda part, **kw: part,
        input_mount=RecordMount("/x", dtype=jnp.int32))
    m = (MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache())
         .map(op=op1).map(op=op2))               # builds fine: schema unknown
    with pytest.raises(ValueError) as exc:
        m.collect()
    msg = str(exc.value)
    assert "stage 0" in msg and "anon/wants-int" in msg
    assert "float32" in msg


def test_reduce_mount_validation_fires_with_stage_and_image():
    def passthrough(part, **kw):
        return part

    hide = ContainerOp(image="anon/hide", fn=passthrough)
    red = ContainerOp(image="anon/reduce", fn=passthrough,
                      associative_commutative=True,
                      input_mount=RecordMount("/r", dtype=jnp.float64))
    m = (MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache())
         .map(op=hide).reduce(op=red))
    with pytest.raises(ValueError) as exc:
        m.collect()
    msg = str(exc.value)
    assert "stage 1" in msg and "anon/reduce" in msg


# -- fn_image command forwarding (satellite) ----------------------------------

def test_fn_image_forwards_command_string():
    seen = {}

    def tool(part, command="", **kw):
        seen["command"] = command
        return part

    reg = Registry()
    fn_image("anon/cmd-tool", tool, registry=reg)
    op = reg.pull("anon/cmd-tool", command="frobnicate --fast")
    part = make_partition((jnp.arange(4, dtype=jnp.int32),), 4)
    op(part)
    assert seen["command"] == "frobnicate --fast"


def test_fn_image_without_command_param_still_works():
    def plain(part):
        return part

    reg = Registry()
    fn_image("anon/plain-tool", plain, registry=reg)
    op = reg.pull("anon/plain-tool")
    part = make_partition((jnp.arange(4, dtype=jnp.int32),), 4)
    out = op(part)
    assert out.capacity == 4


def test_fn_image_with_manifest_participates_in_inference():
    def doubler(part, **kw):
        (x,) = part.records
        return make_partition((x * 2,), part.count)

    reg = Registry()
    fn_image("anon/doubler", doubler, registry=reg,
             manifest=ImageManifest(
                 input_schema=Schema((field(jnp.int32),)),
                 output_schema=SAME))
    m = MaRe((np.arange(8, dtype=np.int32),), registry=reg,
             plan_cache=PlanCache()).map(image="anon/doubler")
    assert "(i32)" in m.describe()
    with pytest.raises(PlanTypeError, match="input schema mismatch"):
        MaRe((np.arange(8, dtype=np.float32),), registry=reg,
             plan_cache=PlanCache()).map(image="anon/doubler")


def test_shuffle_capacity_inference_matches_materialized():
    """Post-shuffle inferred capacity must equal the real output capacity
    (shuffle_partition: axis_size * send capacity), so downstream
    capacity transfers and keyBy checks see the true shapes."""
    m = (MaRe((np.arange(32, dtype=np.int32),), plan_cache=PlanCache())
         .map(image="toolbox/concat")
         .repartition_by(lambda r: r[0] % 3))
    inferred = m._stage_states()[-1].capacity
    assert inferred == m.dataset.capacity
    # explicit send capacity: output is axis_size * capacity (no action —
    # an undersized capacity would overflow at action time on 1 device)
    m2 = (MaRe((np.arange(32, dtype=np.int32),), plan_cache=PlanCache())
          .repartition_by(lambda r: r[0] % 3, capacity=16))
    assert m2._stage_states()[-1].capacity == 16 * m2.num_partitions()


def test_topk_image_handles_integer_scores():
    op = pull("toolbox/topk", k=2)
    part = make_partition((jnp.asarray([5, 9, 1, 7], jnp.int32),), 3)
    out = op(part)                     # record 7 is masked out (count=3)
    assert sorted(np.asarray(out.records[0])[:2].tolist()) == [5, 9]


# -- grammar spec building ----------------------------------------------------

def test_variadic_required_argument_enforced():
    manifest = ImageManifest(commands=(
        CommandSpec("need-args",
                    args=(ArgSpec("xs", type=int, variadic=True),)),))
    with pytest.raises(ValueError, match="missing required argument 'xs'"):
        manifest.parse_command("need-args", image="anon/v")
    _, params = manifest.parse_command("need-args 1 2", image="anon/v")
    assert params == {"xs": (1, 2)}

def test_custom_manifest_grammar_roundtrip():
    manifest = ImageManifest(commands=(
        CommandSpec("tool", args=(ArgSpec("n", type=int),
                                  ArgSpec("names", required=False,
                                          variadic=True))),))
    spec, params = manifest.parse_command("tool 3 a b", image="anon/t")
    assert spec.name == "tool"
    assert params == {"n": 3, "names": ("a", "b")}
    spec, params = manifest.parse_command("tool 3", image="anon/t")
    assert params == {"n": 3}     # optional variadic absent: emits nothing
