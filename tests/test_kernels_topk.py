"""Streaming top-k kernel vs lax.top_k oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import topk_ref, topk_reduce

RNG = np.random.default_rng(1)


@pytest.mark.parametrize("n,k,vc,block", [
    (1000, 30, 1000, 128), (257, 10, 200, 64), (64, 5, 64, 8),
    (4096, 50, 4000, 1024), (100, 100, 100, 32),
])
def test_topk_vs_ref(n, k, vc, block):
    s = jnp.asarray(RNG.normal(size=n), jnp.float32)
    v, i = topk_reduce(s, k, jnp.int32(vc), block=block, interpret=True)
    rv, ri = topk_ref(s, k, vc)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv), rtol=1e-6)
    assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())


def test_topk_with_duplicates():
    s = jnp.asarray(np.repeat([3.0, 1.0, 2.0], 30), jnp.float32)
    v, i = topk_reduce(s, 5, block=16, interpret=True)
    assert np.allclose(np.asarray(v), 3.0)
    assert len(set(np.asarray(i).tolist())) == 5  # distinct indices
