"""Serving layer: DRR scheduler, admission, cross-session batching,
per-tenant cache partitions, report streams, and tenant isolation."""
import threading

import numpy as np
import pytest

from repro.core import MaRe, PlanCache
from repro.core.container import ContainerOp
from repro.core.dataset import from_host
from repro.obs import METRICS
from repro.runtime import Executor, MaterializationCache, estimate_nbytes
from repro.serve import (AdmissionError, DeficitRoundRobin, QueryService,
                         ServiceConfig, Session)


# -- scheduler (no jax) -------------------------------------------------------

def test_drr_alternates_equal_cost_tenants():
    drr = DeficitRoundRobin(quantum=1.0)
    for i in range(3):
        drr.offer("a", f"a{i}", cost=1.0)
        drr.offer("b", f"b{i}", cost=1.0)
    taken = [drr.take(timeout=0) for _ in range(6)]
    # equal costs + quantum 1: strict alternation, no tenant bursts
    assert taken == ["a0", "b0", "a1", "b1", "a2", "b2"]
    assert drr.take(timeout=0) is None


def test_drr_serves_cost_share_not_item_share():
    # tenant "big" queues 4-cost items, "small" 1-cost: over any window
    # both get the same COST share, so "small" gets ~4x the items
    drr = DeficitRoundRobin(quantum=2.0, max_queued_per_tenant=16)
    for i in range(4):
        drr.offer("big", f"B{i}", cost=4.0)
    for i in range(12):
        drr.offer("small", f"s{i}", cost=1.0)
    first8 = [drr.take(timeout=0) for _ in range(8)]
    n_small = sum(1 for t in first8 if t.startswith("s"))
    assert n_small >= 2 * (8 - n_small)

def test_drr_admission_limits_both_scopes():
    drr = DeficitRoundRobin(max_queued_per_tenant=2, max_queued_total=3)
    drr.offer("a", 1)
    drr.offer("a", 2)
    with pytest.raises(AdmissionError) as e:
        drr.offer("a", 3)
    assert e.value.scope == "tenant" and e.value.tenant == "a"
    drr.offer("b", 4)
    with pytest.raises(AdmissionError) as e:
        drr.offer("b", 5)
    assert e.value.scope == "total"
    assert drr.depths() == {"a": 2, "b": 1}


def test_drr_extract_pulls_matches_from_all_tenants():
    drr = DeficitRoundRobin()
    drr.offer("a", ("k1", "a0"))
    drr.offer("a", ("k2", "a1"))
    drr.offer("b", ("k1", "b0"))
    out = drr.extract(lambda it: it[0] == "k1")
    assert sorted(v for _, v in out) == ["a0", "b0"]
    assert len(drr) == 1
    assert drr.take(timeout=0) == ("k2", "a1")
    assert drr.take(timeout=0) is None


def test_drr_take_blocks_until_offer():
    drr = DeficitRoundRobin()
    got = []
    t = threading.Thread(target=lambda: got.append(drr.take(timeout=5)))
    t.start()
    drr.offer("a", "x")
    t.join(timeout=5)
    assert got == ["x"]


# -- service fixtures ---------------------------------------------------------

def _service(**over) -> QueryService:
    cfg = dict(batch_window_s=0.0)
    cfg.update(over)
    return QueryService(
        executor=Executor(plan_cache=PlanCache(),
                          mat_cache=MaterializationCache()),
        config=ServiceConfig(**cfg))


def _double_op(name="serve/double"):
    return ContainerOp(image=name, fn=lambda part, **kw: part)


_OP = _double_op()


def _data(n=32):
    return (np.arange(n, dtype=np.int32),)


def _bad_keys(recs):
    return recs[0]            # 0..31, far outside num_keys=2


def _good_keys(recs):
    return recs[0] % 2


def _vals(recs):
    return (recs[0],)


# -- sessions: routing, reports, admission ------------------------------------

def test_session_sync_collect_routes_through_service():
    with _service() as svc:
        sess = svc.session("alice")
        out = sess.mare(_data()).map(op=_OP).collect()
        assert out[0].tolist() == list(range(32))
        rep = sess.report()
        assert rep is not None and rep.tenant == "alice"
        assert rep.batch_size == 1
        assert sess.reports.appended == 1
        # the executor's global history carries the dispatch too
        assert svc.executor.reports.latest.tenant == "alice"


def test_session_async_collect_and_labels():
    with _service() as svc:
        sess = svc.session("alice")
        h = sess.mare(_data()).map(op=_OP).collect(asynchronous=True,
                                                   label="q0")
        assert h.result(timeout=60)[0].tolist() == list(range(32))
        assert h.report.tenant == "alice" and h.report.label == "q0"


def test_admission_rejection_raises_and_counts():
    METRICS.reset()
    with _service(max_queued_per_tenant=0) as svc:
        sess = svc.session("carol")
        with pytest.raises(AdmissionError):
            sess.mare(_data()).map(op=_OP).collect()
    assert METRICS.snapshot()["serve.admission_rejected"] == 1


def test_session_rejects_reserved_mare_kwargs():
    with _service() as svc:
        sess = svc.session("alice")
        with pytest.raises(TypeError, match="executor"):
            sess.mare(_data(), executor=svc.executor)


def test_report_stream_follow_blocks_until_report():
    with _service() as svc:
        sess = svc.session("alice")
        got = []
        t = threading.Thread(
            target=lambda: got.append(sess.follow(0, timeout=30)))
        t.start()
        sess.mare(_data()).map(op=_OP).collect()
        t.join(timeout=30)
        assert len(got) == 1 and [r.tenant for r in got[0]] == ["alice"]


# -- cross-session batching ---------------------------------------------------

def test_same_query_from_two_sessions_coalesces():
    METRICS.reset()
    with _service(batch_window_s=0.5) as svc:
        a, b = svc.session("alice"), svc.session("bob")
        ds = from_host(_data(), a.mare(_data())._dataset.mesh)
        # async back-to-back: both queued before the pump's batch window
        # closes, so they must share ONE dispatch
        ha = a.mare(ds).map(op=_OP).collect(asynchronous=True)
        hb = b.mare(ds).map(op=_OP).collect(asynchronous=True)
        va, vb = ha.result(timeout=60), hb.result(timeout=60)
        assert va[0].tolist() == vb[0].tolist()
        assert ha.report.batch_size == 2 and hb.report.batch_size == 2
        assert ha.report.batch_leader == hb.report.batch_leader
        assert {ha.report.tenant, hb.report.tenant} == {"alice", "bob"}
        assert a.reports.appended == 1 and b.reports.appended == 1
    snap = METRICS.snapshot()
    assert snap["serve.batched_followers"] == 1
    assert snap["serve.queue_depth.alice"] == 0
    assert snap["serve.queue_depth.bob"] == 0


def test_different_plans_never_coalesce():
    other = _double_op("serve/other")
    with _service(batch_window_s=0.3) as svc:
        a, b = svc.session("alice"), svc.session("bob")
        ds = from_host(_data(), a.mare(_data())._dataset.mesh)
        ha = a.mare(ds).map(op=_OP).collect(asynchronous=True)
        hb = b.mare(ds).map(op=other).collect(asynchronous=True)
        ha.result(timeout=60), hb.result(timeout=60)
        assert ha.report.batch_size == 1
        assert hb.report.batch_size == 1


# -- per-tenant cache partitions ----------------------------------------------

def test_tenant_persist_charged_to_owner_partition():
    with _service() as svc:
        a, b = svc.session("alice"), svc.session("bob")
        a.mare(_data()).persist()
        assert a.cache_bytes()["device"] > 0
        assert b.cache_bytes() == {"device": 0, "host": 0}


def test_tenant_eviction_stays_within_owner():
    probe = estimate_nbytes(
        Session("probe").mare(_data())._dataset)
    budget = int(probe * 2.5)       # fits 2 entries, 3rd must evict
    with _service(tenant_device_budget_bytes=budget) as svc:
        a, b = svc.session("alice"), svc.session("bob")
        b.mare(_data()).persist()
        b_bytes = b.cache_bytes()["device"]
        for i in range(3):          # distinct datasets -> distinct entries
            a.mare((np.arange(32, dtype=np.int32) + i,)).persist()
        cache = svc.executor.mat_cache
        # alice stayed within her partition by evicting HER entries;
        # bob's entry is untouched and no violation was recorded
        assert a.cache_bytes()["device"] <= budget
        assert b.cache_bytes()["device"] == b_bytes
        assert cache.stats()["tenant_budget_violations"] == 0


def test_shared_prefix_read_counts_shared_hit():
    op = _double_op("serve/prefix")
    with _service() as svc:
        a, b = svc.session("alice"), svc.session("bob")
        ds = from_host(_data(), a.mare(_data())._dataset.mesh)
        a.mare(ds).map(op=op).persist()
        out = b.mare(ds).map(op=op).collect()
        assert out[0].tolist() == list(range(32))
        assert b.report().cached_stages == 1
        assert svc.executor.mat_cache.stats()["shared_hits"] >= 1


# -- tenant isolation ---------------------------------------------------------

def test_key_overflow_in_one_session_never_poisons_another():
    with _service() as svc:
        a, b = svc.session("alice"), svc.session("bob")
        bad = (a.mare(_data())
               .reduce_by_key(_bad_keys, value_by=_vals, op="sum",
                              num_keys=2))
        with pytest.raises(RuntimeError, match="overflow"):
            bad.collect()
        # the failure is alice's alone: bob's session still serves, the
        # pump and executor threads survived, and alice can query again
        good = (b.mare(_data())
                .reduce_by_key(_good_keys, value_by=_vals, op="sum",
                               num_keys=2))
        keys, (vals,), counts = good.collect()
        assert sorted(np.asarray(keys).tolist()) == [0, 1]
        assert b.report().tenant == "bob"
        out = a.mare(_data()).map(op=_OP).collect()
        assert out[0].tolist() == list(range(32))


# -- weighted DRR (priority tiers) --------------------------------------------

def test_drr_weights_bias_cost_share():
    # gold (weight 3) vs bronze (weight 1), equal-cost items: served cost
    # over a saturated window tracks the weight ratio
    drr = DeficitRoundRobin(quantum=1.0, max_queued_per_tenant=32,
                            weights={"gold": 3.0})
    for i in range(16):
        drr.offer("gold", f"g{i}", cost=1.0)
        drr.offer("bronze", f"b{i}", cost=1.0)
    first12 = [drr.take(timeout=0) for _ in range(12)]
    n_gold = sum(1 for t in first12 if t.startswith("g"))
    assert n_gold >= 2 * (12 - n_gold)
    # ...but bronze is never starved outright
    assert any(t.startswith("b") for t in first12)


def test_drr_weight_validation_and_set_weight():
    drr = DeficitRoundRobin()
    assert drr.weight("anyone") == 1.0
    drr.set_weight("vip", 2.5)
    assert drr.weight("vip") == 2.5
    with pytest.raises(ValueError):
        drr.set_weight("vip", 0.0)
    with pytest.raises(ValueError):
        DeficitRoundRobin(weights={"x": -1.0})
    with pytest.raises(ValueError):
        DeficitRoundRobin(default_weight=0.0)


def test_drr_total_cost_tracks_offer_take_extract():
    drr = DeficitRoundRobin(quantum=8.0)
    drr.offer("a", ("k1", "a0"), cost=3.0)
    drr.offer("b", ("k2", "b0"), cost=2.0)
    assert drr.total_cost() == 5.0
    drr.take(timeout=0)
    assert drr.total_cost() == 2.0
    drr.extract(lambda it: it[0] == "k2")
    assert drr.total_cost() == 0.0


def test_service_config_weights_reach_scheduler():
    with _service(tenant_weights={"gold": 3.0},
                  default_weight=2.0) as svc:
        assert svc.scheduler.weight("gold") == 3.0
        assert svc.scheduler.weight("anyone") == 2.0


# -- latency-aware admission --------------------------------------------------

def test_latency_admission_rejects_on_predicted_delay():
    METRICS.reset()
    with _service(max_predicted_delay_s=0.5,
                  max_queued_per_tenant=64, max_queued_total=64) as svc:
        # observed pace: 1 s per cost unit -> a 1-stage action predicts
        # (0 backlog + 1) * 1.0 = 1 s > 0.5 s bound
        svc.observe_service_rate(wall_s=1.0, cost=1.0)
        sess = svc.session("dave")
        with pytest.raises(AdmissionError) as e:
            sess.mare(_data()).map(op=_OP).collect()
        assert e.value.scope == "latency"
        assert sess.queue_depth() == 0      # nothing was queued
    snap = METRICS.snapshot()
    assert snap["serve.latency_rejected"] == 1
    assert snap["serve.admission_rejected"] == 1


def test_latency_admission_cold_start_admits():
    # no completed dispatch yet -> no rate estimate -> admit even under a
    # bound nothing could meet once the estimator is warm
    with _service(max_predicted_delay_s=1e-9) as svc:
        sess = svc.session("erin")
        out = sess.mare(_data()).map(op=_OP).collect()
        assert out[0].tolist() == list(range(32))
        # that dispatch seeded the estimator
        assert svc.service_rate() is not None


def test_latency_admission_admits_under_fast_rate():
    with _service(max_predicted_delay_s=10.0) as svc:
        svc.observe_service_rate(wall_s=0.001, cost=1.0)
        sess = svc.session("fay")
        out = sess.mare(_data()).map(op=_OP).collect()
        assert out[0].tolist() == list(range(32))


def test_async_failure_isolated_to_its_batch():
    with _service(batch_window_s=0.2) as svc:
        a, b = svc.session("alice"), svc.session("bob")
        ha = (a.mare(_data())
              .reduce_by_key(_bad_keys, value_by=_vals, op="sum",
                             num_keys=2)
              .collect(asynchronous=True))
        hb = b.mare(_data()).map(op=_OP).collect(asynchronous=True)
        with pytest.raises(RuntimeError, match="overflow"):
            ha.result(timeout=60)
        assert hb.result(timeout=60)[0].tolist() == list(range(32))
