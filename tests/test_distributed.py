"""Run multi-device scenarios in isolated subprocesses (each sets
XLA_FLAGS=--xla_force_host_platform_device_count=8 before importing jax,
per the dry-run isolation rule: the main pytest process stays 1-device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

SCRIPTS = ["mare_e2e.py", "moe_sharded.py", "grad_sync.py",
           "elastic_reshard.py", "dryrun_small.py", "ssm_cp.py",
           "ingest_waves.py", "keyed_skew.py"]


@pytest.mark.parametrize("script", SCRIPTS)
def test_distributed(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "distributed", script)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{script} failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-4000:]}")
    assert "OK" in proc.stdout
