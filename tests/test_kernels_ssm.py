"""Fused selective-scan kernel vs sequential oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ssm_scan_fused, ssm_scan_ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("b,t,d,n,chunk", [
    (2, 64, 32, 8, 16), (1, 100, 16, 4, 32), (1, 33, 8, 4, 8),
    (3, 16, 8, 2, 16),
])
def test_ssm_scan_vs_ref(b, t, d, n, chunk):
    xc = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    xp = jnp.asarray(RNG.normal(size=(d, 2 * n + 1)) * 0.3, jnp.float32)
    dtb = jnp.asarray(RNG.normal(size=(d,)) * 0.1, jnp.float32)
    al = jnp.asarray(np.log(RNG.uniform(0.5, 2.0, (d, n))), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, d, n)) * 0.2, jnp.float32)
    y, h = ssm_scan_fused(xc, xp, dtb, al, h0, chunk=chunk,
                          interpret=True)
    ry, rh = ssm_scan_ref(xc, xp, dtb, al, h0)
    assert float(jnp.max(jnp.abs(y - ry))) < 1e-4
    assert float(jnp.max(jnp.abs(h - rh))) < 1e-4


def test_ssm_scan_state_chaining():
    """Running two halves with carried state == one full pass."""
    b, t, d, n = 1, 64, 16, 4
    xc = jnp.asarray(RNG.normal(size=(b, t, d)), jnp.float32)
    xp = jnp.asarray(RNG.normal(size=(d, 2 * n + 1)) * 0.3, jnp.float32)
    dtb = jnp.zeros((d,), jnp.float32)
    al = jnp.asarray(np.log(RNG.uniform(0.5, 2.0, (d, n))), jnp.float32)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_full, h_full = ssm_scan_fused(xc, xp, dtb, al, h0, chunk=16,
                                    interpret=True)
    y1, h_mid = ssm_scan_fused(xc[:, :32], xp, dtb, al, h0, chunk=16,
                               interpret=True)
    y2, h_end = ssm_scan_fused(xc[:, 32:], xp, dtb, al, h_mid, chunk=16,
                               interpret=True)
    assert float(jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full))) \
        < 1e-4
    assert float(jnp.max(jnp.abs(h_end - h_full))) < 1e-4
