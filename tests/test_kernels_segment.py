"""Segment-reduce kernel (bounded key table) vs jnp oracle vs numpy."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import segment_reduce, segment_reduce_ref
from repro.kernels.segment_reduce import monoid_identity, resolve_use_kernel

RNG = np.random.default_rng(2)


def _case(n, num_keys, d, dtype, spill=True):
    lo = -3 if spill else 0
    hi = num_keys + (5 if spill else 0)
    keys = RNG.integers(lo, hi, size=n).astype(np.int32)
    if np.issubdtype(dtype, np.floating):
        vals = RNG.normal(size=(n, d) if d else (n,)).astype(dtype)
    else:
        vals = RNG.integers(0, 100, size=(n, d) if d else (n,)).astype(dtype)
    valid = RNG.random(n) < 0.8
    return keys, vals, valid


def _np_segment_sum(keys, vals, valid, num_keys):
    ok = valid & (keys >= 0) & (keys < num_keys)
    tab = np.zeros((num_keys,) + vals.shape[1:], vals.dtype)
    np.add.at(tab, keys[ok], vals[ok])
    cnt = np.bincount(keys[ok], minlength=num_keys)
    ovf = int(np.sum(valid & ~((keys >= 0) & (keys < num_keys))))
    return tab, cnt, ovf


@pytest.mark.parametrize("n,num_keys,d,block", [
    (1000, 37, 3, 128), (256, 128, 0, 64), (64, 8, 1, 8), (513, 200, 2, 256),
])
def test_segment_sum_kernel_vs_numpy(n, num_keys, d, block):
    keys, vals, valid = _case(n, num_keys, d, np.float32)
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), num_keys,
                         op="sum", valid=jnp.asarray(valid),
                         use_kernel=True, block=block, interpret=True)
    tab, cnt, ovf = _np_segment_sum(keys, vals, valid, num_keys)
    np.testing.assert_allclose(np.asarray(got.values[0]), tab,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.counts), cnt)
    assert int(got.overflow) == ovf


def test_segment_sum_kernel_matches_ref_int32():
    keys, vals, valid = _case(500, 64, 2, np.int32)
    ker = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), 64,
                         op="sum", valid=jnp.asarray(valid),
                         use_kernel=True, block=128, interpret=True)
    ref = segment_reduce_ref(jnp.asarray(keys), (jnp.asarray(vals),), 64,
                             op="sum", valid=jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(ker.values[0]),
                                  np.asarray(ref.values[0]))
    np.testing.assert_array_equal(np.asarray(ker.counts),
                                  np.asarray(ref.counts))
    assert int(ker.overflow) == int(ref.overflow)


@pytest.mark.parametrize("op", ["max", "min"])
def test_segment_minmax_ref_vs_numpy(op):
    keys, vals, valid = _case(400, 32, 0, np.float32)
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), 32,
                         op=op, valid=jnp.asarray(valid))
    ok = valid & (keys >= 0) & (keys < 32)
    ident = float(monoid_identity(op, jnp.float32))
    exp = np.full(32, ident, np.float32)
    (np.maximum if op == "max" else np.minimum).at(exp, keys[ok], vals[ok])
    np.testing.assert_allclose(np.asarray(got.values[0]), exp, rtol=1e-6)


def test_segment_reduce_pytree_and_empty_values():
    keys = jnp.asarray(np.arange(16) % 4, jnp.int32)
    vals = {"a": jnp.ones((16,), jnp.float32),
            "b": jnp.ones((16, 2), jnp.int32)}
    got = segment_reduce(keys, vals, 4, op="sum", use_kernel=True)
    np.testing.assert_allclose(np.asarray(got.values["a"]), 4.0)
    np.testing.assert_array_equal(np.asarray(got.counts), [4, 4, 4, 4])
    empty = segment_reduce(keys, (), 4, op="sum", use_kernel=True)
    np.testing.assert_array_equal(np.asarray(empty.counts), [4, 4, 4, 4])
    assert int(empty.overflow) == 0


def test_segment_reduce_all_invalid():
    keys = jnp.asarray(np.zeros(32), jnp.int32)
    valid = jnp.zeros((32,), bool)
    for uk in (False, True):
        got = segment_reduce(keys, (jnp.ones((32,), jnp.float32),), 8,
                             op="sum", valid=valid, use_kernel=uk)
        assert np.asarray(got.counts).sum() == 0
        assert np.asarray(got.values[0]).sum() == 0
        assert int(got.overflow) == 0


def test_kernel_dispatch_policy():
    assert resolve_use_kernel(True, "sum") is True
    assert resolve_use_kernel(False, "sum") is False
    assert resolve_use_kernel(True, "max") is False   # kernel is sum-only
    assert resolve_use_kernel(None, "sum") in (True, False)


def test_unknown_monoid_raises():
    with pytest.raises(ValueError, match="unknown segment-reduce op"):
        segment_reduce_ref(jnp.zeros((4,), jnp.int32),
                           (jnp.zeros((4,), jnp.float32),), 2, op="mean")


# -- degenerate tilings & strategy engine (tiled kernel + autotuner) ----------

@pytest.mark.parametrize("n,num_keys,d,block,key_block", [
    (0, 8, 2, 64, 8),        # empty shard (short-circuits to scatter)
    (64, 1, 1, 16, 1),       # single key: one-row table
    (513, 200, 2, 128, 96),  # num_keys not divisible by key_block
    (200, 64, 3, 512, 16),   # block > n, many key tiles
])
def test_tiled_degenerate_tilings_match_numpy(n, num_keys, d, block,
                                              key_block):
    keys, vals, valid = _case(n, num_keys, d, np.float32)
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), num_keys,
                         op="sum", valid=jnp.asarray(valid),
                         use_kernel=True, block=block, key_block=key_block,
                         interpret=True)
    tab, cnt, ovf = _np_segment_sum(keys, vals, valid, num_keys)
    np.testing.assert_allclose(np.asarray(got.values[0]), tab,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.counts), cnt)
    assert int(got.overflow) == ovf


def test_tiled_all_masked_records():
    keys = jnp.asarray(np.full(64, 5, np.int32))
    valid = jnp.zeros((64,), bool)
    got = segment_reduce(keys, (jnp.ones((64, 2), jnp.float32),), 32,
                         op="sum", valid=valid, use_kernel=True,
                         block=16, key_block=8, interpret=True)
    assert np.asarray(got.values[0]).sum() == 0
    assert np.asarray(got.counts).sum() == 0
    assert int(got.overflow) == 0


def test_tiled_hot_key_distribution():
    n, num_keys = 1024, 64
    keys = np.where(RNG.random(n) < 0.9, 7,
                    RNG.integers(0, num_keys, n)).astype(np.int32)
    vals = RNG.integers(0, 100, (n, 2)).astype(np.int32)
    valid = RNG.random(n) < 0.8
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), num_keys,
                         op="sum", valid=jnp.asarray(valid),
                         use_kernel=True, block=128, key_block=16,
                         interpret=True)
    tab, cnt, ovf = _np_segment_sum(keys, vals, valid, num_keys)
    np.testing.assert_array_equal(np.asarray(got.values[0]), tab)
    np.testing.assert_array_equal(np.asarray(got.counts), cnt)


@pytest.mark.parametrize("strategy", ["scatter", "fused", "sorted"])
def test_explicit_strategies_match_reference(strategy):
    keys, vals, valid = _case(777, 101, 2, np.int32)
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), 101,
                         op="sum", valid=jnp.asarray(valid),
                         strategy=strategy)
    ref = segment_reduce_ref(jnp.asarray(keys), (jnp.asarray(vals),), 101,
                             op="sum", valid=jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(got.values[0]),
                                  np.asarray(ref.values[0]))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))
    assert int(got.overflow) == int(ref.overflow)


def test_fused_strategy_mixed_dtypes_pytree():
    keys, _, valid = _case(300, 17, 1, np.float32)
    vals = {"f": jnp.asarray(RNG.normal(size=(300, 2)).astype(np.float32)),
            "i": jnp.asarray(RNG.integers(0, 9, 300).astype(np.int32))}
    got = segment_reduce(jnp.asarray(keys), vals, 17, op="sum",
                         valid=jnp.asarray(valid), strategy="fused")
    ref = segment_reduce_ref(jnp.asarray(keys), vals, 17, op="sum",
                             valid=jnp.asarray(valid))
    np.testing.assert_allclose(np.asarray(got.values["f"]),
                               np.asarray(ref.values["f"]), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(got.values["i"]),
                                  np.asarray(ref.values["i"]))
    np.testing.assert_array_equal(np.asarray(got.counts),
                                  np.asarray(ref.counts))


def test_tuned_default_matches_reference_and_reports():
    from repro.kernels.segment_reduce import tune_report
    keys, vals, valid = _case(900, 50, 1, np.int32)
    got = segment_reduce(jnp.asarray(keys), (jnp.asarray(vals),), 50,
                         op="sum", valid=jnp.asarray(valid))  # autotuned
    ref = segment_reduce_ref(jnp.asarray(keys), (jnp.asarray(vals),), 50,
                             op="sum", valid=jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(got.values[0]),
                                  np.asarray(ref.values[0]))
    entries = [e for e in tune_report() if e["n"] == 900]
    assert entries, "autotuner should have recorded this shape"
    assert entries[0]["candidates"], "candidates should have been timed"
    assert entries[0]["chosen"] in {c["candidate"]
                                    for c in entries[0]["candidates"]}


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown segment-reduce strategy"):
        segment_reduce(jnp.zeros((4,), jnp.int32),
                       (jnp.zeros((4,), jnp.float32),), 2,
                       strategy="magic")
