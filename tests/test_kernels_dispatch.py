"""MoE dispatch-slotting kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch_ref, moe_dispatch

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("n,g,block", [
    (512, 8, 64), (1000, 32, 256), (77, 3, 16), (64, 64, 64), (256, 1, 64),
])
def test_dispatch_vs_ref(n, g, block):
    a = jnp.asarray(RNG.integers(0, g, size=n), jnp.int32)
    p, c = moe_dispatch(a, g, block=block, interpret=True)
    rp, rc = dispatch_ref(a, g)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(rp))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))


def test_dispatch_positions_are_slots():
    """positions must be a valid dense slotting: within each group the
    positions are exactly 0..count-1."""
    a = jnp.asarray(RNG.integers(0, 7, size=300), jnp.int32)
    p, c = moe_dispatch(a, 7, block=32, interpret=True)
    p, c, a = map(np.asarray, (p, c, a))
    for g in range(7):
        slots = sorted(p[a == g].tolist())
        assert slots == list(range(c[g]))
