"""Per-arch smoke tests (assignment deliverable f): every assigned
architecture instantiates a REDUCED config of the same family and runs one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.models.common import param_count_analytic
from repro.optim import adamw
from repro.optim.schedule import constant
from repro.train import StepConfig, init_train_state, make_train_step

RNG = np.random.default_rng(0)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(b, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm" and cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_patches, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    assert cfg.family == get_config(arch).family
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    logits = jax.jit(model.forward)(params, batch)
    b, s = batch["tokens"].shape
    expect_s = s + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (b, expect_s, cfg.vocab_size), arch
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    opt = adamw()
    state = init_train_state(model, opt, KEY)
    step = jax.jit(make_train_step(model, opt, constant(1e-3),
                                   StepConfig()))
    state2, metrics = step(state, make_batch(cfg))
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(state2.step) == 1
    # params actually changed
    moved = any(bool(jnp.any(a != b)) for a, b in zip(
        jax.tree.leaves(state.params), jax.tree.leaves(state2.params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_scale(arch):
    """Full configs land within a sane band of their advertised scale."""
    cfg = get_config(arch)
    n = param_count_analytic(cfg)
    bands = {"kimi-k2-1t-a32b": (0.8e12, 1.3e12),
             "granite-moe-1b-a400m": (0.7e9, 1.6e9),
             "phi3-mini-3.8b": (3.0e9, 4.6e9),
             "deepseek-67b": (55e9, 75e9),
             "smollm-135m": (0.1e9, 0.17e9),
             "llama3.2-1b": (0.9e9, 1.6e9),
             "whisper-base": (0.05e9, 0.11e9),
             "hymba-1.5b": (1.0e9, 2.2e9),
             "internvl2-1b": (0.4e9, 1.0e9),
             "xlstm-1.3b": (0.7e9, 1.8e9)}
    lo, hi = bands[arch]
    assert lo <= n <= hi, (arch, n)
