"""8-device skew-aware keyed exchange: the salted two-hop path must
shrink exchange buffers on hot-key data (it cannot on 1 device — there
is nowhere to spread — so these properties live here, not in
tests/test_planner.py), and ``max_send_count`` must be a valid feedback
capacity for re-planning."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.core import MaRe, PlanCache

rng = np.random.default_rng(5)
n, num_keys, hot, frac = 2048, 32, 7, 0.9
keys = np.where(rng.random(n) < frac, hot,
                rng.integers(0, num_keys, n)).astype(np.int32)
vals = rng.integers(0, 10, n).astype(np.int32)
expected = {int(k): (int(vals[keys == k].sum()), int((keys == k).sum()))
            for k in np.unique(keys)}


def keyed(**kw):
    return MaRe((keys, vals), plan_cache=PlanCache()).reduce_by_key(
        lambda r: r[0], value_by=lambda r: (r[1],), op="sum",
        num_keys=num_keys, combiner=False, **kw)


# salted parity: the two-hop exchange is lossless and exact on hot keys
sal = keyed(salt=8)
out_keys, (out_sum,), out_cnt = sal.collect()
got = {int(k): (int(s), int(c))
       for k, s, c in zip(out_keys, out_sum, out_cnt)}
assert got == expected, (got, expected)
assert sal.report().diagnostics["stage0.shuffle_dropped"] == 0
assert sal.report().diagnostics["stage0.key_overflow"] == 0

# salting shrinks the static exchange buffers vs the single-hop baseline
raw = keyed()
raw.collect()
rows_raw = raw.report().diagnostics["stage0.exchange_buffer_rows"]
rows_sal = sal.report().diagnostics["stage0.exchange_buffer_rows"]
assert rows_sal < rows_raw, (rows_sal, rows_raw)
# hop-1 spreads the hot key: no destination sees ~90% of a shard
assert (sal.report().diagnostics["stage0.max_send_count"]
        < raw.report().diagnostics["stage0.max_send_count"])

# max_send_count is a valid feedback capacity: re-plan with the reported
# tight bound, still lossless, smaller buffers
tight = raw.report().diagnostics["stage0.max_send_count"]
assert 0 < tight <= len(keys)
rerun = keyed(capacity=tight)
rerun.collect()
assert rerun.report().diagnostics["stage0.shuffle_dropped"] == 0
assert (rerun.report().diagnostics["stage0.exchange_buffer_rows"]
        < raw.report().diagnostics["stage0.exchange_buffer_rows"])

print("OK")
