"""Context-parallel SSM == unsharded ssm_block (seq sharded over 8)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.common import ModelConfig
from repro.models import ssm as ssm_lib
from repro.models.ssm_cp import ssm_block_context_parallel

cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  ssm_state=8, ssm_chunk=8, dtype="float32", remat=False)
p = ssm_lib.init_ssm(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.float32)
y_ref, _ = ssm_lib.ssm_block(p, x, cfg)
mesh = compat.make_mesh((1, 8), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P(None, "model", None)))
y_cp = jax.jit(lambda x: ssm_block_context_parallel(
    p, x, cfg, mesh, batch_axes=None))(xs)
err = float(jnp.max(jnp.abs(y_ref - y_cp)))
assert err < 1e-4, err
print("OK ssm_cp err", err)
