"""Miniature dry-run: lower+compile train/serve steps on a 2x4 mesh for a
reduced arch of each family (the full 512-dev dry-run is launch/dryrun.py)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from repro import compat
from repro.configs import get_smoke_config
from repro.launch.dryrun_lib import dry_run_cell
from repro.configs.shapes import ShapeConfig

mesh = compat.make_mesh((2, 4), ("data", "model"))
shape_train = ShapeConfig("tiny_train", "train", 32, 8)
shape_dec = ShapeConfig("tiny_dec", "decode", 64, 8)
for arch in ("smollm-135m", "granite-moe-1b-a400m", "hymba-1.5b",
             "xlstm-1.3b", "whisper-base", "internvl2-1b"):
    cfg = get_smoke_config(arch).scaled(dtype="float32")
    r = dry_run_cell(cfg, shape_train, mesh, extract_collectives=False)
    assert r["flops"] >= 0, arch
    r2 = dry_run_cell(cfg, shape_dec, mesh, extract_collectives=False)
    print("OK", arch, f"train_flops={r['flops']:.3g}")
print("OK dryrun_small")
