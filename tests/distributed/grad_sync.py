"""mare_tree (paper) vs fused (XLA) gradient sync: identical updates."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.optim.schedule import constant
from repro.train import StepConfig, init_train_state, make_train_step
from repro.sharding import data_only_rules

cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", remat=False)
model = build_model(cfg)
opt = adamw()
mesh = compat.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 64, (16, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 64, (16, 16)), jnp.int32)}
state = init_train_state(model, opt, jax.random.PRNGKey(0))
s_f, m_f = jax.jit(make_train_step(model, opt, constant(1e-3),
                                   StepConfig(grad_sync="fused")))(state, batch)
rules = data_only_rules(mesh)
for depth in (1, 2, 3):
    step_t = make_train_step(model, opt, constant(1e-3),
                             StepConfig(grad_sync="mare_tree",
                                        tree_depth=depth),
                             mesh=mesh, rules=rules)
    bs = jax.tree.map(lambda x: jax.device_put(
        x, NamedSharding(mesh, P("data"))), batch)
    s_t, m_t = jax.jit(step_t)(state, bs)
    md = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), s_f.params, s_t.params)))
    assert md < 1e-5, (depth, md)
print("OK grad_sync")
