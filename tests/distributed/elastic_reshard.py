"""Checkpoint on an 8-shard mesh, restore onto a 4-shard mesh (elastic)."""
import os
import tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.train import init_train_state

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", remat=False)
model = build_model(cfg)
state = init_train_state(model, adamw(), jax.random.PRNGKey(0))
mesh8 = compat.make_mesh((8,), ("data",))
mesh4 = compat.make_mesh((4, 2), ("data", "model"))
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, state, blocking=True)
    shard = jax.tree.map(lambda _: NamedSharding(mesh4, P()), state)
    restored = mgr.restore(state, shardings=shard)
    ok = jax.tree.all(jax.tree.map(
        lambda a, b: bool(jnp.all(a == b)), state.params, restored.params))
    assert ok
print("OK elastic_reshard")
