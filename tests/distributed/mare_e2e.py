"""8-device MaRe end-to-end: GC count (Listing 1), topk reduce depths,
repartition_by colocation + multiset preservation."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.core import MaRe, TextFile

rng = np.random.default_rng(0)
dna = rng.integers(0, 4, size=1000).astype(np.int32)
true_gc = int(np.sum((dna == 2) | (dna == 3)))
out = (MaRe((dna,))
       .map(input_mount=TextFile("/dna"), output_mount=TextFile("/count"),
            image="ubuntu", command="grep-count 2 3")
       .reduce(input_mount=TextFile("/counts"), output_mount=TextFile("/sum"),
               image="ubuntu", command="awk-sum"))
res = out.collect(shard=0)
assert int(res[0][0]) == true_gc, (res, true_gc)

scores = rng.normal(size=500).astype(np.float32)
payload = np.arange(500, dtype=np.int32)
true_top = set(np.argsort(-scores)[:30].tolist())
for depth in (1, 2, 3):
    r = MaRe((scores, payload)).reduce(image="toolbox/topk", k=30, depth=depth)
    _, p_out = r.collect(shard=0)
    assert set(p_out.tolist()) == true_top, depth

vals = np.arange(64, dtype=np.int32)
m3 = MaRe((vals,)).repartition_by(lambda recs: recs[0] % 5)
got = m3.collect()
assert sorted(got[0].tolist()) == sorted(vals.tolist())
ds = m3.dataset
counts = jax.device_get(ds.counts); recs = jax.device_get(ds.records[0])
cap = ds.capacity
keysets = [set((recs[s*cap:s*cap+counts[s]] % 5).tolist())
           for s in range(ds.num_shards)]
for i in range(len(keysets)):
    for j in range(i + 1, len(keysets)):
        assert not (keysets[i] & keysets[j])
print("OK mare_e2e")
