"""8-shard ingestion + out-of-core waves: GC count over FASTA via every
storage backend matches the host reference exactly (locality: each shard
fetches only its assigned byte-range splits)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile

import numpy as np
import jax
from repro import compat
from repro.core import MaRe, collect
from repro.io import (WaveRunner, fasta_source, ingest, make_backend,
                      unpack_records)

assert jax.device_count() == 8

rng = np.random.default_rng(11)
seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, 20_000)])
tmp = tempfile.mkdtemp(prefix="mare_dist_")
path = os.path.join(tmp, "genome.fa")
with open(path, "w") as f:
    f.write(">chr1\n")
    for i in range(0, len(seq), 60):
        f.write(seq[i:i + 60] + "\n")
expected = seq.count("G") + seq.count("C")

mesh = compat.make_mesh((8,), ("data",))

# ingestion round-trip across 8 shards: every sequence line exactly once
ds = ingest(fasta_source(path, split_bytes=1 << 10), mesh)
assert ds.num_shards == 8
out = collect(ds)
recs = sorted(r for r in unpack_records(out) if r)
ref = sorted(seq[i:i + 60].encode() for i in range(0, len(seq), 60))
assert recs == ref, (len(recs), len(ref))

# GC pipeline on 8 shards through each backend, forced multi-wave
for kind in ("local", "hdfs", "swift", "s3"):
    src = fasta_source(path, backend=make_backend(kind, path),
                       split_bytes=1 << 10)
    runner = (WaveRunner(src, mesh=mesh, wave_bytes=1 << 13)
              .map(image="ubuntu", command="grep-chars GC")
              .reduce(image="ubuntu", command="awk-sum"))
    (total,) = runner.collect()
    assert runner.stats["num_waves"] >= 2, runner.stats
    assert int(total[0]) == expected, (kind, int(total[0]), expected)

# single-shot from_source on 8 shards
total = (MaRe.from_source(fasta_source(path, split_bytes=1 << 10),
                          mesh=mesh)
         .map(image="ubuntu", command="grep-chars GC")
         .reduce(image="ubuntu", command="awk-sum")
         .collect(shard=0))
assert int(total[0][0]) == expected

print("OK")
