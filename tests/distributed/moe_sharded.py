"""Sharded MoE (both layouts) == dense reference on a 2x4 mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro import compat
from repro.models.common import ModelConfig
from repro.models import moe as moe_lib
from repro.sharding import make_rules, use_rules

cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=0, moe_d_ff=16,
                  num_experts=8, experts_per_token=2, vocab_size=64,
                  dtype="float32", remat=False, capacity_factor=8.0)
p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
mesh = compat.make_mesh((2, 4), ("data", "model"))
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 32)), jnp.float32)
y_dense, _ = moe_lib.moe_ffn_dense(p, x, cfg)
for mode in ("weight_gather", "token_gather"):
    with use_rules(make_rules(mesh), mesh):
        y_s, st = jax.jit(lambda p, x: moe_lib.moe_ffn_sharded(
            p, x, cfg, mode=mode))(p, x)
    assert float(jnp.max(jnp.abs(y_dense - y_s))) < 1e-4, mode
    assert float(st.dropped) == 0.0
print("OK moe_sharded")
