"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.shuffle import _pack_by_dest, hash_keys
from repro.core.tree_reduce import split_factors
from repro.optim.compression import compress_int8, decompress_int8

SETTINGS = dict(max_examples=30, deadline=None)


@given(st.integers(1, 512), st.integers(1, 5))
@settings(**SETTINGS)
def test_split_factors_product(n, k):
    f = split_factors(n, k)
    assert len(f) == k
    p = 1
    for x in f:
        p *= x
    assert p == n


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=64),
       st.integers(2, 8))
@settings(**SETTINGS)
def test_pack_by_dest_preserves_multiset(keys, ndest):
    """repartitionBy invariant: with capacity == n_records the pack step
    is lossless and every record lands in its hashed destination."""
    keys_a = jnp.asarray(keys, jnp.int32)
    n = len(keys)
    recs = (jnp.arange(n, dtype=jnp.int32),)
    dest = (hash_keys(keys_a) % ndest).astype(jnp.int32)
    valid = jnp.ones((n,), bool)
    pack = _pack_by_dest(recs, dest, valid, ndest, n)
    assert int(pack.dropped) == 0
    (vals,) = pack.buffer
    counts = pack.counts
    got = []
    cn = np.asarray(counts)
    for d in range(ndest):
        got += np.asarray(vals[d, :cn[d]]).tolist()
        # each packed record's key must hash to d
        for r in np.asarray(vals[d, :cn[d]]).tolist():
            assert int(hash_keys(keys_a[r]) % ndest) == d
    assert sorted(got) == list(range(n))


@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=32))
@settings(**SETTINGS)
def test_hash_keys_deterministic(keys):
    a = hash_keys(jnp.asarray(keys, jnp.int32))
    b = hash_keys(jnp.asarray(keys, jnp.int32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1,
                max_size=128))
@settings(**SETTINGS)
def test_int8_compression_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = compress_int8(x)
    deq = decompress_int8(q, s)
    # error bounded by half a quantization step
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(deq - x))) <= max(amax / 127.0, 1e-9)


@given(st.integers(0, 100), st.integers(1, 30), st.integers(2, 5))
@settings(**SETTINGS)
def test_mare_reduce_depth_invariance(seed, n, k):
    """Paper §1.2.2: for associative+commutative combiners the reduce
    result is independent of tree depth K (single shard: exercise the
    local pre-combine + identity tree)."""
    from repro.core import MaRe
    rng = np.random.default_rng(seed)
    scores = rng.normal(size=n).astype(np.float32)
    want = set(np.argsort(-scores)[:min(5, n)].tolist())
    results = []
    for depth in (1, k):
        r = MaRe((scores, np.arange(n, dtype=np.int32))).reduce(
            image="toolbox/topk", k=5, depth=depth)
        _, idx = r.collect(shard=0)
        results.append(set(idx.tolist()))
    assert results[0] == results[1] == want


@given(st.lists(st.integers(0, 6), min_size=1, max_size=48),
       st.integers(2, 6))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(dests, ndest):
    """unpack_gather(pack(x)) returns each record's own row (or zeros if
    dropped) — the MoE dispatch invariant."""
    from repro.core.shuffle import unpack_gather
    n = len(dests)
    recs = jnp.arange(n * 2, dtype=jnp.float32).reshape(n, 2)
    dest = jnp.asarray([d % ndest for d in dests], jnp.int32)
    pack = _pack_by_dest((recs,), dest, jnp.ones((n,), bool), ndest, n)
    flat = pack.buffer[0].reshape(ndest * n, 2)
    back = unpack_gather(flat, pack, n)
    np.testing.assert_allclose(np.asarray(back), np.asarray(recs))
