"""input_specs / rules_for coverage for every assigned cell (no
compilation — structural checks only)."""
import pytest

from repro.configs import ARCH_IDS, cells, get_config, shape_skip_reason
from repro.launch.dryrun_lib import input_specs
from repro.configs.shapes import SHAPES


def test_cell_count_and_skips():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40                      # 10 archs x 4 shapes
    runnable = [c for c in all_cells if c[2] is None]
    assert len(runnable) == 32                       # 8 long_500k skips
    skipped = [c for c in all_cells if c[2] is not None]
    assert {a for a, _, _ in skipped} == {
        "kimi-k2-1t-a32b", "granite-moe-1b-a400m", "phi3-mini-3.8b",
        "deepseek-67b", "smollm-135m", "llama3.2-1b", "whisper-base",
        "internvl2-1b"}
    assert all(s == "long_500k" for _, s, _ in skipped)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_model_inputs(arch):
    cfg = get_config(arch)
    for shape in SHAPES:
        if shape_skip_reason(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "train":
            assert "labels" in specs
            assert specs["tokens"].shape[0] == shape.global_batch
        if shape.is_decode:
            assert specs["tokens"].shape == (shape.global_batch,)
        if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
            assert "patch_embeds" in specs
            # patches + text == assigned seq_len
            assert (specs["patch_embeds"].shape[1] +
                    specs["tokens"].shape[1]) == shape.seq_len
        if cfg.family == "audio" and shape.kind == "train":
            assert specs["frames"].shape[1] == cfg.encoder_seq
