"""Runtime layer: lineage-keyed materialization cache (prefix reuse,
budgeted LRU tiers), async action engine, per-action report history."""
import threading
import time

import jax
import numpy as np
import pytest

from repro import compat
from repro.core import MaRe, PlanCache, from_host
from repro.core.container import ContainerOp
from repro.io import text_source
from repro.runtime import (Executor, MaterializationCache, estimate_nbytes,
                           host_root)
from repro.runtime.reports import ActionReport, ReportLog


def _executor(**cache_kw) -> Executor:
    return Executor(mat_cache=MaterializationCache(**cache_kw))


def _counting_op(name="rt/counter"):
    """An op whose fn counts how many times it is TRACED (not executed):
    a cached-prefix action compiles a suffix-only program, so the prefix
    op must not appear in any new trace."""
    traces = {"n": 0}

    def fn(part, **kw):
        traces["n"] += 1
        return part

    return ContainerOp(image=name, fn=fn), traces


def _ident_op(name="rt/id"):
    return ContainerOp(image=name, fn=lambda part, **kw: part)


def _key_mod3(recs):
    return recs[0] % 3


def _data(n=32, seed=0):
    return (np.arange(n, dtype=np.int32),)


# -- prefix cache: hit/miss across forked handles -----------------------------

def test_persist_prefix_hit_on_forked_handle():
    op, traces = _counting_op()
    cache = PlanCache()
    ex = _executor()
    base = MaRe(_data(), plan_cache=cache, executor=ex)

    base.map(op=op).persist()
    traces_after_persist = traces["n"]
    assert traces_after_persist == 1

    # a FORK of base rebuilding the same map prefix + a new suffix: the
    # prefix is served from the cache, so the suffix-only program never
    # traces the map op again
    q = base.map(op=op).repartition_by(_key_mod3)
    got = q.collect()
    assert sorted(got[0].tolist()) == list(range(32))
    assert traces["n"] == traces_after_persist
    report = q.report()
    assert report.cached_stages == 1 and report.total_stages == 2
    assert report.cache_tier == "device"


def test_whole_plan_hit_compiles_and_executes_nothing():
    op, traces = _counting_op()
    cache = PlanCache()
    ex = _executor()
    base = MaRe(_data(), plan_cache=cache, executor=ex)
    base.map(op=op).persist()
    compiles_after_persist = cache.stats()["misses"]

    q = base.map(op=op)                     # exactly the persisted plan
    got = q.collect()
    assert sorted(got[0].tolist()) == list(range(32))
    report = q.report()
    assert report.cached_stages == report.total_stages == 1
    assert report.programs_compiled == 0
    assert cache.stats()["misses"] == compiles_after_persist


def test_different_prefix_misses():
    op_a, _ = _counting_op("rt/a")
    op_b, traces_b = _counting_op("rt/b")
    ex = _executor()
    base = MaRe(_data(), plan_cache=PlanCache(), executor=ex)
    base.map(op=op_a).persist()

    q = base.map(op=op_b)                   # different op -> different node
    q.collect()
    assert q.report().cached_stages == 0
    assert traces_b["n"] == 1               # really executed


def test_separately_parallelized_hosts_do_not_share_lineage():
    """Equal host arrays parallelized twice get distinct roots — content
    identity is unknown, so never a false hit."""
    op, _ = _counting_op()
    ex = _executor()
    MaRe(_data(), plan_cache=PlanCache(), executor=ex).map(op=op).persist()
    q = MaRe(_data(), plan_cache=PlanCache(), executor=ex).map(op=op)
    q.collect()
    assert q.report().cached_stages == 0


def test_cache_is_persist_sugar():
    op, _ = _counting_op()
    ex = _executor()
    base = MaRe(_data(), plan_cache=PlanCache(), executor=ex)
    cached = base.map(op=op).cache()
    assert len(ex.mat_cache) == 1
    assert cached.plan.empty
    q = base.map(op=op)
    q.collect()
    assert q.report().cached_stages == 1


def test_ingest_lineage_is_content_keyed(tmp_path):
    """Re-opening the same source reaches materializations persisted by a
    previous handle (roots digest the resolved splits + geometry)."""
    p = tmp_path / "d.txt"
    p.write_text("\n".join(f"line-{i}" for i in range(50)) + "\n")
    op, traces = _counting_op()
    ex = _executor()
    cache = PlanCache()

    m1 = MaRe.from_source(text_source(str(p)), executor=ex)
    m1.plan_cache = cache
    m1.map(op=op).persist()
    after_persist = traces["n"]

    m2 = MaRe.from_source(text_source(str(p)), executor=ex)
    m2.plan_cache = cache
    q = m2.map(op=op)
    q.collect()
    assert q.report().cached_stages == 1
    assert traces["n"] == after_persist


# -- budgeted LRU tiers -------------------------------------------------------

def _tiny_ds(mesh, n=8, fill=0):
    ds = from_host((np.full(n, fill, np.int32),), mesh)
    ds.lineage = host_root("test")
    return ds


def test_estimate_nbytes_schema_based():
    mesh = compat.make_mesh((1,), ("data",))
    ds = _tiny_ds(mesh, n=8)
    assert estimate_nbytes(ds) == 8 * 4 + 4     # records + counts


def test_device_eviction_spills_to_host_then_hits():
    mesh = compat.make_mesh((1,), ("data",))
    a, b = _tiny_ds(mesh, fill=1), _tiny_ds(mesh, fill=2)
    # budget fits exactly one 36-byte entry: putting b evicts a (LRU)
    cache = MaterializationCache(device_budget_bytes=40)
    cache.put(a)
    cache.put(b)
    assert cache.stats()["spills"] == 1
    assert cache.entry(a.lineage).tier == "host"
    assert cache.entry(b.lineage).tier == "device"

    got = cache.get(a.lineage)              # host hit: re-placed on mesh
    assert got is not None
    assert np.asarray(got.records[0]).tolist() == [1] * 8
    assert got.lineage == a.lineage
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["host_hits"] == 1


def test_host_eviction_drops_lru():
    mesh = compat.make_mesh((1,), ("data",))
    a, b = _tiny_ds(mesh, fill=1), _tiny_ds(mesh, fill=2)
    cache = MaterializationCache(device_budget_bytes=40,
                                 host_budget_bytes=40)
    cache.put(a)
    cache.put(b)                            # a spills to host (fits)
    c = _tiny_ds(mesh, fill=3)
    cache.put(c)                            # b spills; host over budget
    stats = cache.stats()
    assert stats["spills"] == 2
    assert stats["drops"] == 1
    assert cache.entry(a.lineage) is None   # LRU host entry dropped
    assert cache.entry(b.lineage).tier == "host"
    assert cache.entry(c.lineage).tier == "device"
    assert cache.get(a.lineage) is None     # recompute from lineage


def test_prefix_hit_from_host_tier_via_executor():
    op, traces = _counting_op()
    # device budget below one dataset: persist lands on device then is
    # immediately spilled -> the later hit comes from the host tier
    ex = _executor(device_budget_bytes=1)
    base = MaRe(_data(), plan_cache=PlanCache(), executor=ex)
    base.map(op=op).persist()
    assert ex.mat_cache.stats()["spills"] == 1

    q = base.map(op=op).repartition_by(_key_mod3)
    got = q.collect()
    assert sorted(got[0].tolist()) == list(range(32))
    assert q.report().cached_stages == 1
    assert q.report().cache_tier == "host"
    assert traces["n"] == 1                 # prefix still not re-traced


# -- async action engine ------------------------------------------------------

def test_async_actions_preserve_fifo_order():
    op, _ = _counting_op()
    ex = _executor()
    cache = PlanCache()
    handles = []
    for i in range(5):
        m = MaRe((np.full(16, i, np.int32),), plan_cache=cache,
                 executor=ex).map(op=op)
        handles.append(m.collect(asynchronous=True, label=f"q{i}"))
    for i, h in enumerate(handles):
        got = h.result(timeout=60)
        assert got[0].tolist() == [i] * 16
        assert h.done()
        assert h.report is not None and h.report.label == f"q{i}"
    assert [r.label for r in ex.reports] == [f"q{i}" for i in range(5)]
    ids = [r.action_id for r in ex.reports]
    assert ids == sorted(ids)               # dispatched in submit order


def test_async_action_delivers_exceptions():
    ex = _executor()
    m = (MaRe((np.arange(4 * jax.device_count(), dtype=np.int32),),
              plan_cache=PlanCache(), executor=ex)
         .repartition_by(lambda recs: recs[0] * 0, capacity=1))
    h = m.collect(asynchronous=True)
    with pytest.raises(RuntimeError, match="overflow"):
        h.result(timeout=60)


def test_async_result_timeout_does_not_poison_handle():
    ex = _executor()
    release = threading.Event()
    h = ex.submit(lambda handle: (release.wait(30), "ok")[1], label="slow")
    with pytest.raises(TimeoutError):
        h.result(timeout=0.05)
    assert not h.done()
    release.set()
    assert h.result(timeout=30) == "ok"     # later call still succeeds
    assert h.done()


def test_queue_wait_measured_separately_from_execution():
    ex = _executor()
    gate = threading.Event()
    ex.submit(lambda handle: gate.wait(30))     # hog the dispatch thread
    op, _ = _counting_op("rt/qw")
    m = MaRe(_data(), plan_cache=PlanCache(), executor=ex).map(op=op)
    t_submit = time.monotonic()
    h = m.collect(asynchronous=True, label="queued")
    time.sleep(0.25)
    gate.set()
    h.result(timeout=60)
    elapsed = time.monotonic() - t_submit
    assert h.queue_wait_s >= 0.2
    rep = h.report
    assert rep.queue_wait_s == h.queue_wait_s
    assert f"queue_wait={rep.queue_wait_s * 1e3:.1f}ms" in rep.describe()
    # wait and execution are disjoint sub-intervals of submit->result:
    # wall_s starts at dequeue, the wait is not folded into it
    assert rep.queue_wait_s + rep.wall_s <= elapsed + 0.05


def test_reportlog_overflow_bounds_history_but_counts_monotonically():
    log = ReportLog(maxlen=4)
    for _ in range(10):
        log.append(ActionReport(action_id=log.new_id(), plan="p",
                                total_stages=1))
    assert len(log) == 4                    # history bounded at maxlen
    assert log.appended == 10               # lifetime count keeps going
    assert [r.action_id for r in log] == [6, 7, 8, 9]
    assert log.new_id() == 10               # ids never reused
    assert log.latest.action_id == 9


def test_reportlog_summary_renders_phase_table():
    log = ReportLog()
    assert log.summary() == "ReportLog: no actions recorded"
    log.append(ActionReport(action_id=0, plan="p", total_stages=2,
                            cached_stages=1, programs_compiled=1,
                            wall_s=0.2, queue_wait_s=0.1,
                            phases={"dispatch": 0.15,
                                    "counter_sync": 0.05}))
    s = log.summary()
    assert "1 retained / 1 total actions" in s
    assert "queue_wait=0.100s" in s
    assert "2 planned, 1 served from cache" in s
    assert "programs compiled: 1" in s
    assert "dispatch" in s and "75.0%" in s     # 0.15 / 0.2 wall
    assert log.phase_totals() == {"dispatch": 0.15, "counter_sync": 0.05}


def test_async_is_snapshot_not_mutation():
    op, _ = _counting_op()
    ex = _executor()
    m = MaRe(_data(), plan_cache=PlanCache(), executor=ex).map(op=op)
    h = m.collect(asynchronous=True)
    h.result(timeout=60)
    assert not m.plan.empty                 # handle left lazy


# -- reports & diagnostics ----------------------------------------------------

def _key_first(recs):
    return recs[0]


def _val_second(recs):
    return (recs[1],)


def test_report_diagnostics_survive_chaining():
    keys = np.array([0, 1, 2, 3] * 8, np.int32)
    vals = np.ones(32, np.float32)
    ex = _executor()
    m = MaRe((keys, vals), plan_cache=PlanCache(),
             executor=ex).reduce_by_key(_key_first, value_by=_val_second,
                                        op="sum", num_keys=4)
    m.collect()
    diag = m.report().diagnostics
    assert diag["stage0.exchanged_records"] > 0

    chained = m.map(op=_ident_op())         # pre-runtime: history vanished
    assert chained.report().diagnostics == diag
    chained.collect()
    assert len(chained.reports()) == 2
    assert chained.reports()[0].counters == diag
    assert chained.report().diagnostics == {}  # map-only action: no counters


def test_report_counters_keep_absolute_stage_indices_after_prefix_hit():
    """A suffix executed after a cached prefix reports counters under the
    ORIGINAL stage indices, not suffix-relative ones."""
    op, _ = _counting_op()
    keys = np.array([0, 1, 2, 3] * 8, np.int32)
    vals = np.ones(32, np.float32)
    ex = _executor()
    base = MaRe((keys, vals), plan_cache=PlanCache(), executor=ex)
    base.map(op=op).persist()
    q = base.map(op=op).reduce_by_key(_key_first, value_by=_val_second,
                                      op="sum", num_keys=4)
    q.collect()
    report = q.report()
    assert report.cached_stages == 1
    assert "stage1.exchanged_records" in report.counters
    assert q.reports().total("exchanged_records") > 0


def test_describe_lists_keyed_reduce_counter_specs():
    m = MaRe((np.array([0, 1] * 16, np.int32), np.ones(32, np.float32)),
             plan_cache=PlanCache(), executor=_executor()
             ).reduce_by_key(_key_first, value_by=_val_second, op="sum",
                             num_keys=2)
    d = m.describe()
    assert "counters=[" in d
    assert "stage0.key_overflow" in d
    assert "stage0.exchanged_records" in d


# -- golden describe ----------------------------------------------------------

def test_describe_annotates_cached_lineage_nodes_golden():
    mesh = compat.make_mesh((1,), ("data",))
    ds = from_host((np.arange(8, dtype=np.int32),), mesh)
    ex = _executor()
    cache = PlanCache()
    op = _ident_op()
    base = MaRe(ds, plan_cache=cache, executor=ex)
    base.map(op=op).persist()

    q = base.map(op=op).repartition_by(_key_mod3)
    assert q.describe() == (
        "MaRe(shards=1, cap=8, schema=(i32)#8, "
        "plan=[map[rt/id:latest] : ?#? [cached] -> "
        "shuffle(cap=None) : ?#?], counters=[stage1.shuffle_dropped])")
    # the persisted node is marked; the suffix is not
    fresh = MaRe(from_host((np.arange(8, dtype=np.int32),), mesh),
                 plan_cache=cache, executor=ex).map(op=op)
    assert "[cached]" not in fresh.describe()
