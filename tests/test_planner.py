"""Lazy stage-DAG planner: whole-pipeline fusion, compile cache,
shuffle-overflow accounting, keyed aggregation (single device; multi-device
coverage lives in tests/distributed/mare_e2e.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (KeyedReduceStage, MaRe, MapStage, Plan, PlanCache,
                        ShuffleStage, from_host, hash_keys,
                        keyed_bucket_capacity, shuffle_partition)
from repro.core import planner as planner_lib
from repro.core.container import ContainerOp, make_partition
from jax.sharding import PartitionSpec as P


def _counting_op(name="trace/counter"):
    """An op whose fn counts how many times it is TRACED (not executed)."""
    traces = {"n": 0}

    def fn(part, **kw):
        traces["n"] += 1
        return part

    return ContainerOp(image=name, fn=fn), traces


def _key_mod5(recs):
    return recs[0] % 5


# -- laziness & fusion --------------------------------------------------------

def test_chain_is_lazy_until_action():
    op, traces = _counting_op()
    m = (MaRe((np.arange(32, dtype=np.int32),), plan_cache=PlanCache())
         .map(op=op)
         .repartition_by(_key_mod5)
         .map(op=op))
    assert traces["n"] == 0                    # nothing traced yet
    assert [type(s) for s in m.plan.stages] == [MapStage, ShuffleStage,
                                                MapStage]
    got = m.collect()
    assert sorted(got[0].tolist()) == list(range(32))
    assert traces["n"] == 2                    # one trace, op appears twice


def test_whole_chain_compiles_one_program():
    cache = PlanCache()
    scores = np.random.default_rng(0).normal(size=64).astype(np.float32)
    ids = np.arange(64, dtype=np.int32)
    m = (MaRe((scores, ids), plan_cache=cache)
         .map(image="toolbox/concat")
         .repartition_by(lambda recs: recs[1] % 3)
         .reduce(image="toolbox/topk", k=8))
    _, top_ids = m.collect(shard=0)
    true_top = set(np.argsort(-scores)[:8].tolist())
    assert set(top_ids.tolist()) == true_top
    assert cache.stats() == {"programs": 1, "hits": 0, "misses": 1}


def test_fused_equals_stage_at_a_time():
    data = (np.arange(48, dtype=np.int32),)

    def run(fuse):
        cache = PlanCache()
        m = (MaRe(data, plan_cache=cache, fuse=fuse)
             .map(image="toolbox/concat")
             .repartition_by(_key_mod5)
             .reduce(image="toolbox/sum"))
        out = m.collect(shard=0)
        return out, cache.stats()

    fused, fused_stats = run(True)
    eager, eager_stats = run(False)
    np.testing.assert_array_equal(fused[0], eager[0])
    assert fused_stats["misses"] == 1
    assert eager_stats["misses"] == 3          # one program per stage


# -- compile cache ------------------------------------------------------------

def test_compile_cache_hits_on_identical_pipeline():
    cache = PlanCache()
    op, traces = _counting_op()
    data = (np.arange(16, dtype=np.int32),)

    def build():
        return (MaRe(data, plan_cache=cache)
                .map(op=op)
                .repartition_by(_key_mod5))

    build().collect()
    assert cache.stats() == {"programs": 1, "hits": 0, "misses": 1}
    first_traces = traces["n"]

    build().collect()                          # fresh MaRe, same pipeline
    assert cache.stats() == {"programs": 1, "hits": 1, "misses": 1}
    assert traces["n"] == first_traces         # zero re-trace

    # same program OBJECT is reused for the same key
    ds = from_host(data, compat.make_mesh((1,), ("data",)))
    plan = build().plan
    p1 = planner_lib.compile_plan(plan, ds, cache)
    p2 = planner_lib.compile_plan(plan, ds, cache)
    assert p1 is p2


def test_numpy_params_key_on_content_not_identity():
    """Array params are baked into the traced program, so the cache must
    key them by content: equal arrays share a program, and mutating one
    in place misses the cache instead of serving stale constants."""
    cache = PlanCache()
    table = np.full((4,), 10, np.int32)

    def add_table(part, table=None, **kw):
        return make_partition((part.records[0] + jnp.asarray(table)[0],),
                              part.count)

    def run():
        op = ContainerOp(image="t/add", fn=add_table,
                         params={"table": table})
        m = MaRe((np.zeros(8, np.int32),), plan_cache=cache).map(op=op)
        return int(m.collect()[0][0])

    assert run() == 10
    assert run() == 10                         # same content -> cache hit
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 1
    table += 90                                # in-place mutation
    assert run() == 100                        # new digest -> recompile
    assert cache.stats()["misses"] == 2


def test_compile_cache_misses_on_shape_or_structure_change():
    cache = PlanCache()
    op, _ = _counting_op()

    def run(n, twice):
        m = MaRe((np.arange(n, dtype=np.int32),), plan_cache=cache).map(op=op)
        if twice:
            m = m.map(op=op)
        m.collect()

    run(16, False)
    run(32, False)                             # shape change -> new program
    run(16, True)                              # structure change -> new one
    assert cache.stats()["misses"] == 3


# -- shuffle overflow ---------------------------------------------------------

def test_shuffle_partition_dropped_accounting():
    """All records hash to one destination; capacity caps what arrives and
    the remainder is counted, never silently lost."""
    mesh = compat.make_mesh((1,), ("data",))

    def interior(records, counts):
        part = make_partition(records, counts[0])
        keys = jnp.zeros((part.capacity,), jnp.int32)   # all -> shard 0
        res = shuffle_partition(part, keys, axis_name="data", axis_size=1,
                                capacity=3)
        return res.part.records, res.part.count[None], res.dropped[None]

    fn = jax.jit(compat.shard_map(
        interior, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data"))))
    records = (jnp.arange(10, dtype=jnp.int32),)
    counts = jnp.asarray([10], jnp.int32)
    out_records, out_counts, dropped = fn(records, counts)
    assert int(dropped[0]) == 7                # 10 sent, 3 fit
    assert int(out_counts[0]) == 3
    # survivors are a prefix of the stable destination order
    assert out_records[0][:3].tolist() == [0, 1, 2]


def test_repartition_overflow_raises_at_action():
    # capacity=1: any source shard holding >1 record overflows its
    # per-destination send buffer (everything keys to one destination)
    m = (MaRe((np.arange(4 * jax.device_count(), dtype=np.int32),),
              plan_cache=PlanCache())
         .repartition_by(lambda recs: jnp.zeros_like(recs[0]), capacity=1))
    with pytest.raises(RuntimeError, match="overflow"):
        m.collect()


def test_lossless_shuffle_never_raises():
    m = (MaRe((np.arange(12, dtype=np.int32),), plan_cache=PlanCache())
         .repartition_by(lambda recs: jnp.zeros_like(recs[0])))
    got = m.collect()
    assert sorted(got[0].tolist()) == list(range(12))


# -- keyed aggregation (reduce_by_key) ---------------------------------------

def _kv_data(n=64, num_keys=8, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, num_keys, size=n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    return keys, vals


def _key_first(recs):
    return recs[0]


def _val_second(recs):
    return (recs[1],)


def _expected_groupby(keys, vals):
    return {int(k): (float(vals[keys == k].sum()), int((keys == k).sum()))
            for k in np.unique(keys)}


def _keyed(data, num_keys=8, cache=None, **kw):
    # NB `or` would discard an empty cache: PlanCache.__len__ makes it falsy
    cache = cache if cache is not None else PlanCache()
    return MaRe(data, plan_cache=cache).reduce_by_key(
        _key_first, value_by=_val_second, op="sum", num_keys=num_keys, **kw)


@pytest.mark.parametrize("combiner", [True, False])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_reduce_by_key_matches_groupby(combiner, use_kernel):
    keys, vals = _kv_data()
    m = _keyed((keys, vals), combiner=combiner, use_kernel=use_kernel)
    out_keys, (out_sum,), out_cnt = m.collect()
    got = {int(k): (float(s), int(c))
           for k, s, c in zip(out_keys, out_sum, out_cnt)}
    exp = _expected_groupby(keys, vals)
    assert set(got) == set(exp)
    for k, (s, c) in exp.items():
        assert got[k][1] == c
        assert abs(got[k][0] - s) < 1e-4


def test_reduce_by_key_combiner_shrinks_exchange():
    keys, vals = _kv_data(n=256, num_keys=4)
    on = _keyed((keys, vals), num_keys=4, combiner=True)
    on.collect()
    off = _keyed((keys, vals), num_keys=4, combiner=False)
    off.collect()
    ex_on = on.report().diagnostics["stage0.exchanged_records"]
    ex_off = off.report().diagnostics["stage0.exchanged_records"]
    assert ex_off == 256                   # every record crosses the wire
    # at most one partial per key per shard (CI runs 8 simulated devices)
    assert ex_on <= 4 * jax.device_count()
    assert ex_on < ex_off
    assert on.report().diagnostics["stage0.key_overflow"] == 0


def test_reduce_by_key_is_lazy_and_fuses_to_one_program():
    keys, vals = _kv_data()
    cache = PlanCache()
    m = (MaRe((keys, vals), plan_cache=cache)
         .map(image="toolbox/concat")
         .reduce_by_key(_key_first, value_by=_val_second, op="sum",
                        num_keys=8))
    assert [type(s) for s in m.plan.stages] == [MapStage, KeyedReduceStage]
    assert cache.stats()["misses"] == 0    # nothing compiled yet
    m.collect()
    assert cache.stats() == {"programs": 1, "hits": 0, "misses": 1}


def test_reduce_by_key_cache_hit_on_rerun():
    keys, vals = _kv_data()
    cache = PlanCache()
    _keyed((keys, vals), cache=cache).collect()
    _keyed((keys, vals), cache=cache).collect()
    assert cache.stats() == {"programs": 1, "hits": 1, "misses": 1}


def test_reduce_by_key_max_monoid():
    keys, vals = _kv_data()
    m = MaRe((keys, vals), plan_cache=PlanCache()).reduce_by_key(
        _key_first, value_by=_val_second, op="max", num_keys=8)
    out_keys, (out_max,), _ = m.collect()
    for k, v in zip(out_keys, out_max):
        assert abs(float(v) - float(vals[keys == int(k)].max())) < 1e-6


def test_reduce_by_key_single_distinct_key():
    vals = np.arange(16, dtype=np.float32)
    keys = np.full(16, 3, np.int32)
    m = _keyed((keys, vals), num_keys=8)
    out_keys, (out_sum,), out_cnt = m.collect()
    assert out_keys.tolist() == [3]
    assert out_cnt.tolist() == [16]
    assert float(out_sum[0]) == float(vals.sum())


def test_reduce_by_key_empty_partitions():
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    ds = from_host((np.zeros(0, np.int32), np.zeros(0, np.float32)),
                   mesh, capacity=8)
    m = MaRe(ds).reduce_by_key(_key_first, value_by=_val_second, op="sum",
                               num_keys=8)
    out_keys, (out_sum,), out_cnt = m.collect()
    assert out_keys.shape[0] == 0 and out_cnt.shape[0] == 0


def test_reduce_by_key_all_records_masked_out():
    keys, vals = _kv_data(n=16)
    mesh = compat.make_mesh((jax.device_count(),), ("data",))
    ds = from_host((keys, vals), mesh)
    ds = dataclasses.replace(ds, counts=ds.counts * 0)   # mask everything
    m = MaRe(ds).reduce_by_key(_key_first, value_by=_val_second, op="sum",
                               num_keys=8)
    out_keys, (out_sum,), out_cnt = m.collect()
    assert out_keys.shape[0] == 0
    assert m.report().diagnostics["stage0.key_overflow"] == 0


@pytest.mark.parametrize("combiner", [True, False])
def test_reduce_by_key_overflow_raises_at_action_not_trace(combiner):
    keys = np.array([0, 1, 200, 300], np.int32)   # two keys out of range
    vals = np.ones(4, np.float32)
    m = _keyed((keys, vals), num_keys=4, combiner=combiner)
    # building + describing the plan must not raise (laziness)
    assert "reduce_by_key[sum, keys=4" in m.describe()
    with pytest.raises(RuntimeError, match="key-table overflow"):
        m.collect()


def test_reduce_by_key_monoid_validation_and_image_spelling():
    keys, vals = _kv_data()
    with pytest.raises(ValueError, match="unknown reduce_by_key op"):
        MaRe((keys, vals)).reduce_by_key(_key_first, op="mean", num_keys=8)
    with pytest.raises(ValueError, match="not a known keyed-reduce monoid"):
        MaRe((keys, vals)).reduce_by_key(_key_first, image="toolbox/topk",
                                         num_keys=8)
    m = MaRe((keys, vals), plan_cache=PlanCache()).reduce_by_key(
        _key_first, value_by=_val_second, image="ubuntu", command="awk-sum",
        num_keys=8)
    assert m.plan.stages[-1].op == "sum"
    out_keys, (out_sum,), _ = m.collect()
    exp = _expected_groupby(keys, vals)
    for k, s in zip(out_keys, out_sum):
        assert abs(float(s) - exp[int(k)][0]) < 1e-4


def test_keyed_bucket_capacity_matches_device_hash():
    num_keys, n = 97, 4
    caps = np.zeros(n, np.int64)
    dest = np.asarray(
        hash_keys(jnp.arange(num_keys, dtype=jnp.int32))) % n
    np.add.at(caps, dest.astype(np.int64), 1)
    assert keyed_bucket_capacity(num_keys, n) == int(caps.max())


def test_keyed_bucket_capacities_partition_the_key_space():
    from repro.core.shuffle import keyed_bucket_capacities
    caps = keyed_bucket_capacities(1000, 8)
    assert caps.shape == (8,)
    assert int(caps.sum()) == 1000            # every key owned exactly once
    assert int(caps.max()) == keyed_bucket_capacity(1000, 8)


# -- hot-key skew: the salted two-hop exchange --------------------------------

def _hot_key_data(n=2048, num_keys=32, hot=7, frac=0.9):
    rng = np.random.default_rng(5)
    keys = np.where(rng.random(n) < frac, hot,
                    rng.integers(0, num_keys, n)).astype(np.int32)
    vals = rng.integers(0, 10, n).astype(np.int32)
    return keys, vals


def test_reduce_by_key_salted_hot_key_matches_groupby():
    keys, vals = _hot_key_data()
    sal = _keyed((keys, vals), num_keys=32, combiner=False, salt=8)
    out_keys, (out_sum,), out_cnt = sal.collect()
    got = {int(k): (int(s), int(c))
           for k, s, c in zip(out_keys, out_sum, out_cnt)}
    exp = {int(k): (int(vals[keys == k].sum()), int((keys == k).sum()))
           for k in np.unique(keys)}
    assert got == exp
    assert sal.report().diagnostics["stage0.shuffle_dropped"] == 0
    assert sal.report().diagnostics["stage0.key_overflow"] == 0


def test_salted_diagnostics_present_and_lossless():
    # Buffer-SHRINK properties of salting need a multi-device mesh (there
    # is nowhere to spread on 1 device) and live in
    # tests/distributed/keyed_skew.py; here: the diagnostics contract.
    keys, vals = _hot_key_data()
    sal = _keyed((keys, vals), num_keys=32, combiner=False, salt=8)
    sal.collect()
    d = sal.report().diagnostics
    assert d["stage0.shuffle_dropped"] == 0
    assert 0 < d["stage0.max_send_count"] <= len(keys)
    assert d["stage0.exchange_buffer_rows"] > 0


def test_salt_validation():
    keys, vals = _kv_data()
    with pytest.raises(ValueError, match="salt must be >= 1"):
        _keyed((keys, vals), salt=0)
    with pytest.raises(ValueError, match="requires combiner=False"):
        _keyed((keys, vals), combiner=True, salt=4)


# -- plan structure & describe ------------------------------------------------

def test_plan_builder_fuses_adjacent_maps():
    op, _ = _counting_op()
    p = Plan().then(op).then(op).then_shuffle(_key_mod5).then(op)
    assert [type(s) for s in p.stages] == [MapStage, ShuffleStage, MapStage]
    assert len(p.stages[0].ops) == 2
    assert len(p.ops) == 3                     # legacy flat view
    assert p.num_shuffles == 1


def test_describe_shows_stage_dag():
    m = (MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache())
         .map(image="toolbox/concat")
         .repartition_by(_key_mod5)
         .reduce(image="toolbox/sum", depth=1))
    d = m.describe()
    assert "map[toolbox/concat:latest]" in d
    assert "shuffle" in d
    assert "reduce[toolbox/sum:latest, depth=1]" in d


def test_describe_shows_keyed_stage_and_counter_specs():
    m = (MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache())
         .repartition_by(_key_mod5)
         .reduce_by_key(_key_first, op="sum", num_keys=5))
    assert "reduce_by_key[sum, keys=5, combiner=on]" in m.describe()
    assert m.plan.counter_specs() == (
        (0, "shuffle_dropped"),
        (1, "key_overflow"), (1, "shuffle_dropped"),
        (1, "exchanged_records"), (1, "max_send_count"),
        (1, "exchange_buffer_rows"))


def test_dataset_property_materializes_pending_plan():
    op, traces = _counting_op()
    m = MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache()).map(
        op=op)
    assert traces["n"] == 0
    ds = m.dataset                             # action: runs the plan
    assert traces["n"] == 1
    assert m.plan.empty
    assert ds.num_shards == jax.device_count()
