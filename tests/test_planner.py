"""Lazy stage-DAG planner: whole-pipeline fusion, compile cache,
shuffle-overflow accounting (single device; multi-device coverage lives in
tests/distributed/mare_e2e.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (MaRe, MapStage, Plan, PlanCache, ReduceStage,
                        ShuffleStage, execute, from_host, shuffle_partition)
from repro.core import planner as planner_lib
from repro.core.container import ContainerOp, Partition, make_partition
from jax.sharding import PartitionSpec as P


def _counting_op(name="trace/counter"):
    """An op whose fn counts how many times it is TRACED (not executed)."""
    traces = {"n": 0}

    def fn(part, **kw):
        traces["n"] += 1
        return part

    return ContainerOp(image=name, fn=fn), traces


def _key_mod5(recs):
    return recs[0] % 5


# -- laziness & fusion --------------------------------------------------------

def test_chain_is_lazy_until_action():
    op, traces = _counting_op()
    m = (MaRe((np.arange(32, dtype=np.int32),), plan_cache=PlanCache())
         .map(op=op)
         .repartition_by(_key_mod5)
         .map(op=op))
    assert traces["n"] == 0                    # nothing traced yet
    assert [type(s) for s in m.plan.stages] == [MapStage, ShuffleStage,
                                                MapStage]
    got = m.collect()
    assert sorted(got[0].tolist()) == list(range(32))
    assert traces["n"] == 2                    # one trace, op appears twice


def test_whole_chain_compiles_one_program():
    cache = PlanCache()
    scores = np.random.default_rng(0).normal(size=64).astype(np.float32)
    ids = np.arange(64, dtype=np.int32)
    m = (MaRe((scores, ids), plan_cache=cache)
         .map(image="toolbox/concat")
         .repartition_by(lambda recs: recs[1] % 3)
         .reduce(image="toolbox/topk", k=8))
    _, top_ids = m.collect_first_shard()
    true_top = set(np.argsort(-scores)[:8].tolist())
    assert set(top_ids.tolist()) == true_top
    assert cache.stats() == {"programs": 1, "hits": 0, "misses": 1}


def test_fused_equals_stage_at_a_time():
    data = (np.arange(48, dtype=np.int32),)

    def run(fuse):
        cache = PlanCache()
        m = (MaRe(data, plan_cache=cache, fuse=fuse)
             .map(image="toolbox/concat")
             .repartition_by(_key_mod5)
             .reduce(image="toolbox/sum"))
        out = m.collect_first_shard()
        return out, cache.stats()

    fused, fused_stats = run(True)
    eager, eager_stats = run(False)
    np.testing.assert_array_equal(fused[0], eager[0])
    assert fused_stats["misses"] == 1
    assert eager_stats["misses"] == 3          # one program per stage


# -- compile cache ------------------------------------------------------------

def test_compile_cache_hits_on_identical_pipeline():
    cache = PlanCache()
    op, traces = _counting_op()
    data = (np.arange(16, dtype=np.int32),)

    def build():
        return (MaRe(data, plan_cache=cache)
                .map(op=op)
                .repartition_by(_key_mod5))

    build().collect()
    assert cache.stats() == {"programs": 1, "hits": 0, "misses": 1}
    first_traces = traces["n"]

    build().collect()                          # fresh MaRe, same pipeline
    assert cache.stats() == {"programs": 1, "hits": 1, "misses": 1}
    assert traces["n"] == first_traces         # zero re-trace

    # same program OBJECT is reused for the same key
    ds = from_host(data, compat.make_mesh((1,), ("data",)))
    plan = build().plan
    p1 = planner_lib.compile_plan(plan, ds, cache)
    p2 = planner_lib.compile_plan(plan, ds, cache)
    assert p1 is p2


def test_numpy_params_key_on_content_not_identity():
    """Array params are baked into the traced program, so the cache must
    key them by content: equal arrays share a program, and mutating one
    in place misses the cache instead of serving stale constants."""
    cache = PlanCache()
    table = np.full((4,), 10, np.int32)

    def add_table(part, table=None, **kw):
        return make_partition((part.records[0] + jnp.asarray(table)[0],),
                              part.count)

    def run():
        op = ContainerOp(image="t/add", fn=add_table,
                         params={"table": table})
        m = MaRe((np.zeros(8, np.int32),), plan_cache=cache).map(op=op)
        return int(m.collect()[0][0])

    assert run() == 10
    assert run() == 10                         # same content -> cache hit
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 1
    table += 90                                # in-place mutation
    assert run() == 100                        # new digest -> recompile
    assert cache.stats()["misses"] == 2


def test_compile_cache_misses_on_shape_or_structure_change():
    cache = PlanCache()
    op, _ = _counting_op()

    def run(n, twice):
        m = MaRe((np.arange(n, dtype=np.int32),), plan_cache=cache).map(op=op)
        if twice:
            m = m.map(op=op)
        m.collect()

    run(16, False)
    run(32, False)                             # shape change -> new program
    run(16, True)                              # structure change -> new one
    assert cache.stats()["misses"] == 3


# -- shuffle overflow ---------------------------------------------------------

def test_shuffle_partition_dropped_accounting():
    """All records hash to one destination; capacity caps what arrives and
    the remainder is counted, never silently lost."""
    mesh = compat.make_mesh((1,), ("data",))

    def interior(records, counts):
        part = make_partition(records, counts[0])
        keys = jnp.zeros((part.capacity,), jnp.int32)   # all -> shard 0
        res = shuffle_partition(part, keys, axis_name="data", axis_size=1,
                                capacity=3)
        return res.part.records, res.part.count[None], res.dropped[None]

    fn = jax.jit(compat.shard_map(
        interior, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"), P("data"))))
    records = (jnp.arange(10, dtype=jnp.int32),)
    counts = jnp.asarray([10], jnp.int32)
    out_records, out_counts, dropped = fn(records, counts)
    assert int(dropped[0]) == 7                # 10 sent, 3 fit
    assert int(out_counts[0]) == 3
    # survivors are a prefix of the stable destination order
    assert out_records[0][:3].tolist() == [0, 1, 2]


def test_repartition_overflow_raises_at_action():
    # capacity=1: any source shard holding >1 record overflows its
    # per-destination send buffer (everything keys to one destination)
    m = (MaRe((np.arange(4 * jax.device_count(), dtype=np.int32),),
              plan_cache=PlanCache())
         .repartition_by(lambda recs: jnp.zeros_like(recs[0]), capacity=1))
    with pytest.raises(RuntimeError, match="overflow"):
        m.collect()


def test_lossless_shuffle_never_raises():
    m = (MaRe((np.arange(12, dtype=np.int32),), plan_cache=PlanCache())
         .repartition_by(lambda recs: jnp.zeros_like(recs[0])))
    got = m.collect()
    assert sorted(got[0].tolist()) == list(range(12))


# -- plan structure & describe ------------------------------------------------

def test_plan_builder_fuses_adjacent_maps():
    op, _ = _counting_op()
    p = Plan().then(op).then(op).then_shuffle(_key_mod5).then(op)
    assert [type(s) for s in p.stages] == [MapStage, ShuffleStage, MapStage]
    assert len(p.stages[0].ops) == 2
    assert len(p.ops) == 3                     # legacy flat view
    assert p.num_shuffles == 1


def test_describe_shows_stage_dag():
    m = (MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache())
         .map(image="toolbox/concat")
         .repartition_by(_key_mod5)
         .reduce(image="toolbox/sum", depth=1))
    d = m.describe()
    assert "map[toolbox/concat:latest]" in d
    assert "shuffle" in d
    assert "reduce[toolbox/sum:latest, depth=1]" in d


def test_dataset_property_materializes_pending_plan():
    op, traces = _counting_op()
    m = MaRe((np.arange(8, dtype=np.int32),), plan_cache=PlanCache()).map(
        op=op)
    assert traces["n"] == 0
    ds = m.dataset                             # action: runs the plan
    assert traces["n"] == 1
    assert m.plan.empty
    assert ds.num_shards == jax.device_count()
