"""Regression tests for the trip-count-aware HLO cost walker — the
roofline numbers depend on it (launch/hlo_cost.py)."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze


def test_matmul_flops_exact():
    a = jnp.zeros((256, 256), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
    r = analyze(c.as_text())
    assert abs(r["flops"] - 2 * 256 ** 3) / (2 * 256 ** 3) < 0.01


def test_scan_trip_count_multiplies():
    """XLA cost_analysis counts a while body once; the walker must
    multiply by the trip count (scan-of-13 == unrolled-13)."""
    a = jnp.zeros((128, 128), jnp.float32)

    def scanned(a):
        def body(x, _):
            return jnp.tanh(x @ a), None
        x, _ = jax.lax.scan(body, a, None, length=13)
        return x

    def unrolled(a):
        x = a
        for _ in range(13):
            x = jnp.tanh(x @ a)
        return x

    fs = analyze(jax.jit(scanned).lower(a).compile().as_text())["flops"]
    fu = analyze(jax.jit(unrolled).lower(a).compile().as_text())["flops"]
    from repro.compat import cost_analysis
    xla = cost_analysis(jax.jit(scanned).lower(a).compile())["flops"]
    assert abs(fs - fu) / fu < 0.02
    assert xla < fs / 5          # demonstrates the undercount being fixed


def test_nested_scan():
    a = jnp.zeros((64, 64), jnp.float32)

    def nested(a):
        def outer(x, _):
            def inner(y, _):
                return y @ a, None
            y, _ = jax.lax.scan(inner, x, None, length=4)
            return y, None
        x, _ = jax.lax.scan(outer, a, None, length=3)
        return x

    r = analyze(jax.jit(nested).lower(a).compile().as_text())
    expect = 12 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05


def test_slice_bytes_not_full_buffer():
    """dynamic-slice of a big stacked buffer must count the slice, not
    the stack (the per-layer weight slicing pattern)."""
    w = jnp.zeros((30, 256, 256), jnp.float32)
    x = jnp.zeros((4, 256), jnp.float32)

    def f(w, x):
        def body(x, wi):
            return x @ wi, None
        x, _ = jax.lax.scan(body, x, w)
        return x

    r = analyze(jax.jit(f).lower(w, x).compile().as_text())
    # full-stack-per-iteration would be 30 * 7.8MB = 236MB; actual
    # traffic is ~30 * (slice 256KB + x 4KB) ≈ 8MB
    assert r["bytes"] < 60e6, r["bytes"]
