"""Optimizers, schedules, clipping, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adafactor, adamw, apply_updates,
                         clip_by_global_norm, cosine_warmup, global_norm,
                         linear_warmup)
from repro.optim.compression import error_feedback_compress, init_residual


def _quad_problem():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0]),
              "b": jnp.asarray([[0.5, 0.5], [1.0, -1.0]])}
    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss


def test_adamw_converges():
    params, loss = _quad_problem()
    opt = adamw(weight_decay=0.0)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        up, state = opt.update(g, state, params, 0.05)
        params = apply_updates(params, up)
    assert float(loss(params)) < 1e-2


def test_adafactor_converges_and_is_factored():
    params, loss = _quad_problem()
    opt = adafactor()
    state = opt.init(params)
    # factored second moment: 2-D leaf stores row+col, not full
    assert state.v_row["b"].shape == (2,)
    assert state.v_col["b"].shape == (2,)
    for _ in range(300):
        g = jax.grad(loss)(params)
        up, state = opt.update(g, state, params, 0.05)
        params = apply_updates(params, up)
    assert float(loss(params)) < 1e-2


def test_adafactor_memory_is_sublinear():
    p = {"big": jnp.zeros((128, 256))}
    st = adafactor().init(p)
    factored = st.v_row["big"].size + st.v_col["big"].size
    assert factored == 128 + 256          # not 128*256


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - 20.0) < 1e-4
    # below threshold: untouched
    tree2 = {"a": jnp.full((4,), 0.1)}
    clipped2, _ = clip_by_global_norm(tree2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 0.1, rtol=1e-6)


def test_schedules():
    lw = linear_warmup(1.0, 10)
    assert float(lw(jnp.int32(0))) < 0.2
    assert abs(float(lw(jnp.int32(100))) - 1.0) < 1e-6
    cw = cosine_warmup(1.0, 10, 100, min_ratio=0.1)
    assert float(cw(jnp.int32(99))) <= float(cw(jnp.int32(50)))
    assert float(cw(jnp.int32(9999))) >= 0.099


def test_error_feedback_carries_residual():
    grads = {"w": jnp.asarray([1.0, 1e-4, -1.0])}
    res = init_residual(grads)
    _, deq, res = error_feedback_compress(grads, res)
    # residual holds what quantization lost; next round recovers it
    assert float(jnp.max(jnp.abs(deq["w"] + res["w"] - grads["w"]))) < 1e-6
