"""Checkpoint manager: roundtrip, retention, atomicity, async."""
import os

import jax
from repro import compat
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.optim import adamw
from repro.train import init_train_state

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  dtype="float32", remat=False)


def _state():
    return init_train_state(build_model(CFG), adamw(),
                            jax.random.PRNGKey(0))


def test_roundtrip(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state, blocking=True)
    got = mgr.restore(state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert mgr.latest_step() == 4
    steps = sorted(mgr.latest_steps())
    assert steps == [3, 4]               # keep-last-2 enforced


def test_async_save_then_restore(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(7, state)          # async
    mgr.wait()
    assert mgr.latest_step() == 7
    got = mgr.restore(state, step=7)
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(got)[0]),
        np.asarray(jax.tree.leaves(state)[0]))


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_no_tmp_dirs_left(tmp_path):
    state = _state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_shardings_for_params_divisibility(tmp_path):
    """Elastic restore builds divisibility-safe shardings from logical
    axes (the N->M mesh rescale path)."""
    import jax
    from repro.checkpoint import shardings_for_params
    from repro.models import build_model
    from repro.sharding import make_rules

    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    sh = shardings_for_params(params, model.logical_axes(), mesh,
                              make_rules(mesh))
    flat = jax.tree.leaves(sh)
    assert all(hasattr(s, "spec") for s in flat)
    # every spec's sharded dims divide the param dims
    for p, s in zip(jax.tree.leaves(params), flat):
        for dim, ax in zip(p.shape, tuple(s.spec) + (None,) * 8):
            if ax is not None:
                size = mesh.shape[ax] if isinstance(ax, str) else 1
                assert dim % size == 0
