"""Out-of-core wave execution: planning, folding, end-to-end exactness."""
import numpy as np
import pytest

from repro.core import PlanCache
from repro.io import (WaveRunner, fasta_source, make_backend, plan_waves,
                      text_source, unpack_records)
from repro.io.splits import InputSplit


def _mk_splits(lengths):
    out, off = [], 0
    for ln in lengths:
        out.append(InputSplit(path="f", start=off, stop=off + ln,
                              file_size=sum(lengths)))
        off += ln
    return out


def test_plan_waves_respects_budget_and_order():
    splits = _mk_splits([100, 100, 100, 100, 100])
    waves = plan_waves(splits, wave_bytes=250)
    assert [len(w) for w in waves] == [2, 2, 1]
    flat = [s for w in waves for s in w]
    assert flat == splits                       # order preserved
    assert plan_waves(splits, wave_bytes=None) == [splits]
    # oversized split still gets its own wave
    waves = plan_waves(_mk_splits([500, 10]), wave_bytes=100)
    assert [len(w) for w in waves] == [1, 1]


@pytest.fixture
def genome(tmp_path):
    rng = np.random.default_rng(7)
    seq = "".join(np.array(list("ATGC"))[rng.integers(0, 4, 6000)])
    p = tmp_path / "genome.fa"
    p.write_text(">chr1\n" + "\n".join(
        seq[i:i + 60] for i in range(0, len(seq), 60)) + "\n")
    return str(p), seq


@pytest.mark.parametrize("backend", ["local", "hdfs", "swift", "s3"])
def test_gc_count_out_of_core_matches_reference(genome, backend):
    """Acceptance: Listing-1 GC count over an on-disk FASTA, ingested via
    each storage backend and executed in >= 2 out-of-core waves, matches
    the numpy reference exactly."""
    path, seq = genome
    src = fasta_source(path, backend=make_backend(backend, path),
                       split_bytes=512)
    runner = (WaveRunner(src, wave_bytes=1 << 11)
              .map(image="ubuntu", command="grep-chars GC")
              .reduce(image="ubuntu", command="awk-sum"))
    (total,) = runner.collect()
    assert runner.stats["num_waves"] >= 2
    assert int(total[0]) == seq.count("G") + seq.count("C")


def test_map_only_waves_concatenate_all_records(tmp_path):
    lines = [f"line-{i:03d}" for i in range(100)]
    p = tmp_path / "d.txt"
    p.write_text("\n".join(lines) + "\n")
    runner = WaveRunner(text_source(str(p), split_bytes=128),
                        wave_bytes=256, width=16)
    out = runner.collect()
    assert runner.stats["num_waves"] >= 2
    got = sorted(r for r in unpack_records(out) if r)
    assert got == sorted(ln.encode() for ln in lines)


def test_single_wave_equals_multi_wave(genome):
    path, _ = genome
    def run(wave_bytes):
        r = (WaveRunner(fasta_source(path, split_bytes=512),
                        wave_bytes=wave_bytes, prefetch=False)
             .map(image="ubuntu", command="grep-chars GC")
             .reduce(image="ubuntu", command="awk-sum"))
        (t,) = r.collect()
        return int(t[0]), r.stats["num_waves"]
    one, n1 = run(None)
    many, nm = run(1 << 11)
    assert n1 == 1 and nm >= 2
    assert one == many


def test_wave_pipeline_compile_amortizes_across_runs(genome):
    """The plan compile cache is keyed on (stage structure, shapes, mesh):
    same-shaped waves share one program, and a second identical run
    compiles nothing at all."""
    path, seq = genome
    cache = PlanCache()

    def run():
        r = (WaveRunner(fasta_source(path, split_bytes=512),
                        wave_bytes=1 << 11, prefetch=False,
                        plan_cache=cache)
             .map(image="ubuntu", command="grep-chars GC")
             .reduce(image="ubuntu", command="awk-sum"))
        (t,) = r.collect()
        assert int(t[0]) == seq.count("G") + seq.count("C")
        return r.stats

    s1 = run()
    assert s1["num_waves"] >= 2
    # same-shaped waves share a program within the first run (compiled
    # programs: wave-pipeline shapes + the cross-wave fold)
    assert s1["programs_compiled"] <= s1["num_waves"]
    assert s1["program_cache_hits"] >= 1
    s2 = run()
    assert s2["programs_compiled"] == 0        # fully amortized
    assert s2["program_cache_hits"] == s2["num_waves"] + 1   # waves + fold


def test_wave_runner_rejects_map_after_reduce(genome):
    path, _ = genome
    r = WaveRunner(fasta_source(path)).reduce(image="ubuntu",
                                              command="awk-sum")
    with pytest.raises(ValueError):
        r.map(image="ubuntu", command="grep-chars GC")
